//! Serving metrics substrate: counters, latency histograms (p50/p90/p99),
//! throughput accounting, and per-request decode statistics.

pub mod rouge;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Reservoir cap for [`Histogram`]: below this every sample is stored and
/// quantiles are exact; past it, seeded reservoir downsampling (Algorithm R)
/// keeps a uniform subsample so a long-running server's per-round
/// histograms (`batch_size`, `ttft_ms`, ...) stop growing. Count, sum,
/// mean, min, and max stay exact regardless.
pub const HIST_RESERVOIR_CAP: usize = 65_536;

/// Typed percentile summary of one [`Histogram`] — what
/// `ServerHandle::hist_summary` / `Registry::report_json` hand to the bench
/// harness and operators so nobody needs raw-sample access. Empty
/// histograms summarize to all-zero (count = 0).
#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
    /// the source histogram exceeded [`HIST_RESERVOIR_CAP`]: quantiles are
    /// reservoir estimates (count/mean/min/max remain exact).
    pub overflowed: bool,
}

impl HistSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p90", Json::num(self.p90)),
            ("p99", Json::num(self.p99)),
            ("min", Json::num(self.min)),
            ("max", Json::num(self.max)),
            ("overflowed", Json::Bool(self.overflowed)),
        ])
    }
}

/// hits / (hits + misses), 0 when no observations — the one hit-rate
/// convention shared by pools, caches, suites, and per-request stats.
pub fn hit_rate(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Streaming histogram over f64 samples. Exact quantiles via a sorted store
/// up to [`HIST_RESERVOIR_CAP`]; past that, seeded reservoir downsampling
/// bounds memory on long-running servers (the reservoir Rng is fixed-seed,
/// so two histograms fed the same stream summarize identically).
#[derive(Debug, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
    /// total samples ever recorded (exact, unlike the reservoir's length).
    seen: u64,
    /// exact running sum over every recorded sample.
    total: f64,
    lo: f64,
    hi: f64,
    rng: Rng,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            samples: Vec::new(),
            sorted: false,
            seen: 0,
            total: 0.0,
            lo: f64::INFINITY,
            hi: f64::NEG_INFINITY,
            rng: Rng::new(0x4849_5354), // "HIST" — deterministic reservoir
        }
    }

    pub fn record(&mut self, v: f64) {
        self.seen += 1;
        self.total += v;
        self.lo = self.lo.min(v);
        self.hi = self.hi.max(v);
        if self.samples.len() < HIST_RESERVOIR_CAP {
            self.samples.push(v);
            self.sorted = false;
        } else {
            // Algorithm R: keep each of the `seen` samples with equal
            // probability by overwriting a uniform slot
            let j = self.rng.below(self.seen as usize);
            if j < HIST_RESERVOIR_CAP {
                self.samples[j] = v;
                self.sorted = false;
            }
        }
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64() * 1e3); // ms
    }

    /// Total samples ever recorded (exact — NOT the reservoir's size).
    pub fn count(&self) -> usize {
        self.seen as usize
    }

    /// Samples currently held (== count until the reservoir cap is hit).
    pub fn samples_held(&self) -> usize {
        self.samples.len()
    }

    /// The reservoir cap was exceeded: quantiles are now estimates over a
    /// uniform subsample (count/sum/mean/min/max stay exact).
    pub fn overflowed(&self) -> bool {
        self.seen as usize > self.samples.len()
    }

    pub fn sum(&self) -> f64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.total / self.seen as f64
    }

    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let idx = ((self.samples.len() - 1) as f64 * q).floor() as usize;
        self.samples[idx]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&mut self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn min(&self) -> f64 {
        self.lo
    }

    pub fn max(&self) -> f64 {
        self.hi
    }

    /// Typed percentile snapshot; all-zero when empty.
    pub fn summarize(&mut self) -> HistSummary {
        if self.seen == 0 {
            return HistSummary {
                count: 0,
                mean: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                min: 0.0,
                max: 0.0,
                overflowed: false,
            };
        }
        HistSummary {
            count: self.count(),
            mean: self.mean(),
            p50: self.p50(),
            p90: self.p90(),
            p99: self.p99(),
            min: self.min(),
            max: self.max(),
            overflowed: self.overflowed(),
        }
    }

    pub fn summary(&mut self) -> String {
        let s = self.summarize();
        if s.count == 0 {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:.2} p50={:.2} p90={:.2} p99={:.2} max={:.2}",
            s.count, s.mean, s.p50, s.p90, s.p99, s.max
        )
    }
}

/// Named counters + histograms for a serving process.
#[derive(Debug, Default)]
pub struct Registry {
    pub counters: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    /// Gauge semantics: overwrite the value (e.g. `suspended_sessions`).
    pub fn set(&mut self, name: &str, v: u64) {
        self.counters.insert(name.to_string(), v);
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Percentile summary of one named histogram (None when never observed).
    pub fn summary(&mut self, name: &str) -> Option<HistSummary> {
        self.histograms.get_mut(name).map(Histogram::summarize)
    }

    pub fn report(&mut self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("counter {k} = {v}\n"));
        }
        let names: Vec<String> = self.histograms.keys().cloned().collect();
        for k in names {
            let line = self.histograms.get_mut(&k).unwrap().summary();
            s.push_str(&format!("hist    {k}: {line}\n"));
        }
        s
    }

    /// Machine-readable twin of [`Registry::report`]:
    /// `{"counters": {..}, "histograms": {name: {count,mean,p50,p90,p99,min,max}}}`.
    /// This is what the `{"report": true}` TCP control line returns and what
    /// the serving bench harness scrapes.
    pub fn report_json(&mut self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                .collect(),
        );
        let hists = Json::Obj(
            self.histograms
                .iter_mut()
                .map(|(k, h)| (k.clone(), h.summarize().to_json()))
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("histograms", hists)])
    }

    /// Render the registry in Prometheus text exposition format (the
    /// `{"metrics": "prometheus"}` control line / `client --metrics-prom`):
    /// counters and gauges as scalar samples, histograms as summaries —
    /// quantile-labeled samples plus `_sum`/`_count`. Names are prefixed
    /// `lookahead_` and sanitized to the metric-name charset.
    pub fn prometheus(&mut self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            let name = prom_name(k);
            let kind = if prom_is_gauge(k) { "gauge" } else { "counter" };
            s.push_str(&format!("# TYPE {name} {kind}\n{name} {v}\n"));
        }
        let names: Vec<String> = self.histograms.keys().cloned().collect();
        for k in names {
            let name = prom_name(&k);
            let h = self.histograms.get_mut(&k).unwrap();
            let (sum, count) = (h.sum(), h.count());
            let sm = h.summarize();
            s.push_str(&format!("# TYPE {name} summary\n"));
            for (q, v) in [("0.5", sm.p50), ("0.9", sm.p90), ("0.99", sm.p99)] {
                s.push_str(&format!("{name}{{quantile=\"{q}\"}} {v}\n"));
            }
            s.push_str(&format!("{name}_sum {sum}\n"));
            s.push_str(&format!("{name}_count {count}\n"));
        }
        s
    }
}

/// `lookahead_`-prefixed metric name with non-charset bytes mapped to `_`.
fn prom_name(raw: &str) -> String {
    let mut s = String::with_capacity(raw.len() + 10);
    s.push_str("lookahead_");
    for c in raw.chars() {
        s.push(if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' });
    }
    s
}

/// The registry's counter map doubles as the gauge store
/// ([`Registry::set`]); these name prefixes are the gauge-semantics
/// entries, typed accordingly in the exposition output.
fn prom_is_gauge(name: &str) -> bool {
    ["queue_depth", "cancel_marks", "live_sessions", "suspended_sessions",
     "prefix_entries", "trace_"]
        .iter()
        .any(|g| name.starts_with(g))
}

/// Per-request decode statistics — the paper's core measurables.
#[derive(Debug, Clone, Default)]
pub struct DecodeStats {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub decode_steps: usize,
    pub accepted_by_len: Vec<usize>, // index = tokens accepted in a step
    pub pool_hits: usize,
    pub pool_misses: usize,
    /// the n-gram store already held entries when this request started
    /// (only possible with a cross-request shared cache).
    pub pool_warm_start: bool,
    /// the request used a shared (cross-request) n-gram cache.
    pub pool_shared: bool,
    pub pool_entries_start: usize,
    pub pool_entries_end: usize,
    pub wall: Duration,
    pub prefill_wall: Duration,
    /// Time to first token: session start -> end of the first decode step
    /// (includes prefill). Zero until the first step commits.
    pub ttft: Duration,
}

impl DecodeStats {
    /// Step compression ratio S = generated tokens / decode steps (Eq. 6).
    pub fn compression(&self) -> f64 {
        if self.decode_steps == 0 {
            return 1.0;
        }
        self.generated_tokens as f64 / self.decode_steps as f64
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / s
    }

    pub fn record_accept(&mut self, n: usize) {
        if self.accepted_by_len.len() <= n {
            self.accepted_by_len.resize(n + 1, 0);
        }
        self.accepted_by_len[n] += 1;
        self.decode_steps += 1;
        self.generated_tokens += n;
    }

    /// Per-request pool hit rate (0 when the engine keeps no pool).
    pub fn pool_hit_rate(&self) -> f64 {
        hit_rate(self.pool_hits as u64, self.pool_misses as u64)
    }

    pub fn merge(&mut self, other: &DecodeStats) {
        self.prompt_tokens += other.prompt_tokens;
        self.generated_tokens += other.generated_tokens;
        self.decode_steps += other.decode_steps;
        self.pool_hits += other.pool_hits;
        self.pool_misses += other.pool_misses;
        self.pool_warm_start |= other.pool_warm_start;
        self.pool_shared |= other.pool_shared;
        self.pool_entries_start += other.pool_entries_start;
        self.pool_entries_end += other.pool_entries_end;
        self.wall += other.wall;
        self.prefill_wall += other.prefill_wall;
        self.ttft = self.ttft.max(other.ttft);
        for (i, &c) in other.accepted_by_len.iter().enumerate() {
            if self.accepted_by_len.len() <= i {
                self.accepted_by_len.resize(i + 1, 0);
            }
            self.accepted_by_len[i] += c;
        }
    }
}

/// Simple stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p99(), 99.0);
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_empty_safe() {
        let mut h = Histogram::new();
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn registry_counts() {
        let mut r = Registry::new();
        r.inc("requests", 1);
        r.inc("requests", 2);
        r.observe("latency_ms", 4.0);
        assert_eq!(r.counter("requests"), 3);
        assert!(r.report().contains("requests = 3"));
    }

    #[test]
    fn summarize_matches_accessors() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.summarize();
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        // empty histogram -> all-zero, no NaN/inf leaks
        let s0 = Histogram::new().summarize();
        assert_eq!(s0.count, 0);
        assert_eq!(s0.min, 0.0);
        assert_eq!(s0.max, 0.0);
    }

    #[test]
    fn registry_summary_and_json() {
        let mut r = Registry::new();
        r.inc("requests", 3);
        r.observe("latency_ms", 4.0);
        r.observe("latency_ms", 8.0);
        let s = r.summary("latency_ms").unwrap();
        assert_eq!(s.count, 2);
        assert!((s.mean - 6.0).abs() < 1e-12);
        assert!(r.summary("nope").is_none());
        let j = r.report_json();
        assert_eq!(j.path("counters.requests").unwrap().as_usize(), Some(3));
        assert_eq!(
            j.path("histograms.latency_ms.count").unwrap().as_usize(),
            Some(2)
        );
        // round-trips through the writer/parser
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn histogram_reservoir_caps_memory_and_stays_exact_below_cap() {
        // below the cap: exact, not overflowed
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert!(!h.overflowed());
        assert_eq!(h.count(), 100);
        assert_eq!(h.samples_held(), 100);
        assert!(!h.summarize().overflowed);

        // past the cap: memory bounded, exact aggregates, sane quantiles
        let n = HIST_RESERVOIR_CAP + 5_000;
        let mut h = Histogram::new();
        for i in 0..n {
            h.record(i as f64);
        }
        assert!(h.overflowed());
        assert_eq!(h.count(), n);
        assert_eq!(h.samples_held(), HIST_RESERVOIR_CAP,
                   "reservoir must stop growing at the cap");
        let s = h.summarize();
        assert!(s.overflowed);
        assert_eq!(s.count, n);
        assert!((s.mean - (n - 1) as f64 / 2.0).abs() < 1e-3,
                "mean must stay exact under downsampling: {}", s.mean);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, (n - 1) as f64);
        assert!(s.p50 > 0.0 && s.p99 < n as f64);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99,
                "estimated quantiles must stay ordered");

        // the seeded reservoir is deterministic: same stream, same summary
        let mut h2 = Histogram::new();
        for i in 0..n {
            h2.record(i as f64);
        }
        assert_eq!(h.summarize(), h2.summarize());
    }

    #[test]
    fn prometheus_rendering_is_pinned() {
        let mut r = Registry::new();
        r.inc("responses_ok", 3);
        r.set("queue_depth", 2); // gauge semantics
        r.observe("ttft_ms", 4.0);
        r.observe("ttft_ms", 8.0);
        r.histograms.entry("empty_ms".to_string()).or_default();
        let want = "\
# TYPE lookahead_queue_depth gauge
lookahead_queue_depth 2
# TYPE lookahead_responses_ok counter
lookahead_responses_ok 3
# TYPE lookahead_empty_ms summary
lookahead_empty_ms{quantile=\"0.5\"} 0
lookahead_empty_ms{quantile=\"0.9\"} 0
lookahead_empty_ms{quantile=\"0.99\"} 0
lookahead_empty_ms_sum 0
lookahead_empty_ms_count 0
# TYPE lookahead_ttft_ms summary
lookahead_ttft_ms{quantile=\"0.5\"} 4
lookahead_ttft_ms{quantile=\"0.9\"} 4
lookahead_ttft_ms{quantile=\"0.99\"} 4
lookahead_ttft_ms_sum 12
lookahead_ttft_ms_count 2
";
        assert_eq!(r.prometheus(), want);
        // rendering must be idempotent (summarize sorts in place)
        assert_eq!(r.prometheus(), want);
    }

    #[test]
    fn registry_gauge_overwrites() {
        let mut r = Registry::new();
        r.set("suspended_sessions", 3);
        r.set("suspended_sessions", 1);
        assert_eq!(r.counter("suspended_sessions"), 1);
    }

    #[test]
    fn compression_ratio() {
        let mut s = DecodeStats::default();
        s.record_accept(1);
        s.record_accept(3);
        s.record_accept(2);
        assert_eq!(s.generated_tokens, 6);
        assert_eq!(s.decode_steps, 3);
        assert!((s.compression() - 2.0).abs() < 1e-12);
        assert_eq!(s.accepted_by_len, vec![0, 1, 1, 1]);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = DecodeStats::default();
        a.record_accept(2);
        let mut b = DecodeStats::default();
        b.record_accept(1);
        b.record_accept(4);
        a.merge(&b);
        assert_eq!(a.generated_tokens, 7);
        assert_eq!(a.decode_steps, 3);
    }
}
