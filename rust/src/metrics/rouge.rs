//! ROUGE-1/2/L for Tab. 2 (sampling quality): word-level n-gram recall /
//! precision / F1 against a reference, matching the standard definitions.

use std::collections::HashMap;

fn words(s: &str) -> Vec<&str> {
    s.split(|c: char| !c.is_alphanumeric()).filter(|w| !w.is_empty()).collect()
}

fn ngram_counts<'a>(ws: &[&'a str], n: usize) -> HashMap<Vec<&'a str>, usize> {
    let mut m = HashMap::new();
    if ws.len() >= n {
        for win in ws.windows(n) {
            *m.entry(win.to_vec()).or_insert(0) += 1;
        }
    }
    m
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Score {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

impl Score {
    fn from_counts(overlap: usize, cand: usize, refr: usize) -> Score {
        let p = if cand == 0 { 0.0 } else { overlap as f64 / cand as f64 };
        let r = if refr == 0 { 0.0 } else { overlap as f64 / refr as f64 };
        let f1 = if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) };
        Score { precision: p, recall: r, f1 }
    }
}

/// ROUGE-N (clipped n-gram overlap).
pub fn rouge_n(candidate: &str, reference: &str, n: usize) -> Score {
    let cw = words(candidate);
    let rw = words(reference);
    let cc = ngram_counts(&cw, n);
    let rc = ngram_counts(&rw, n);
    let overlap: usize =
        cc.iter().map(|(g, &c)| c.min(rc.get(g).copied().unwrap_or(0))).sum();
    let cand_total: usize = cc.values().sum();
    let ref_total: usize = rc.values().sum();
    Score::from_counts(overlap, cand_total, ref_total)
}

/// ROUGE-L via longest common subsequence of words.
pub fn rouge_l(candidate: &str, reference: &str) -> Score {
    let c = words(candidate);
    let r = words(reference);
    let lcs = lcs_len(&c, &r);
    Score::from_counts(lcs, c.len(), r.len())
}

fn lcs_len(a: &[&str], b: &[&str]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        for j in 1..=b.len() {
            cur[j] = if a[i - 1] == b[j - 1] {
                prev[j - 1] + 1
            } else {
                prev[j].max(cur[j - 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Average F1 of rouge-1/2/L over (candidate, reference) pairs — the three
/// columns of the paper's Tab. 2.
pub fn rouge_suite(pairs: &[(String, String)]) -> (f64, f64, f64) {
    if pairs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let n = pairs.len() as f64;
    let mut r1 = 0.0;
    let mut r2 = 0.0;
    let mut rl = 0.0;
    for (c, r) in pairs {
        r1 += rouge_n(c, r, 1).f1;
        r2 += rouge_n(c, r, 2).f1;
        rl += rouge_l(c, r).f1;
    }
    (100.0 * r1 / n, 100.0 * r2 / n, 100.0 * rl / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_text_perfect() {
        let s = rouge_n("the cat sat on the mat", "the cat sat on the mat", 1);
        assert!((s.f1 - 1.0).abs() < 1e-12);
        let l = rouge_l("the cat sat", "the cat sat");
        assert!((l.f1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_text_zero() {
        assert_eq!(rouge_n("aa bb", "cc dd", 1).f1, 0.0);
        assert_eq!(rouge_l("aa bb", "cc dd").f1, 0.0);
    }

    #[test]
    fn partial_overlap() {
        // candidate: "the cat", ref: "the cat sat" -> R1 p=1, r=2/3
        let s = rouge_n("the cat", "the cat sat", 1);
        assert!((s.precision - 1.0).abs() < 1e-12);
        assert!((s.recall - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rouge2_needs_adjacent() {
        let s = rouge_n("the mat cat sat", "the cat sat on", 2);
        // bigrams cand: (the,mat)(mat,cat)(cat,sat); ref: (the,cat)(cat,sat)(sat,on)
        assert!((s.precision - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lcs_subsequence_not_substring() {
        let l = rouge_l("a x b y c", "a b c");
        assert!((l.recall - 1.0).abs() < 1e-12); // a b c is a subsequence
    }

    #[test]
    fn clipping_repeated_ngrams() {
        // candidate repeats "the" 4x, ref has it once -> overlap clipped to 1
        let s = rouge_n("the the the the", "the", 1);
        assert!((s.precision - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(rouge_n("", "x", 1).f1, 0.0);
        assert_eq!(rouge_l("x", "").f1, 0.0);
        assert_eq!(rouge_suite(&[]), (0.0, 0.0, 0.0));
    }
}
