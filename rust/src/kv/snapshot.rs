//! Versioned session snapshots: the host-resident, serializable form of a
//! suspended [`crate::engine::DecodeSession`].
//!
//! A snapshot captures everything a deterministic engine needs to continue
//! byte-identically: the committed output and stats, the generation params,
//! the engine's own state (window, RNG stream, current token), the n-gram
//! pool, and the [`HostKv`] image of the device cache. In-memory snapshots
//! keep the live [`PoolHandle`] (exact resume, shared caches included);
//! the on-disk form ([`SessionSnapshot::to_bytes`]) serializes private-pool
//! contents and re-binds (or cold-starts) shared caches on load — pool
//! contents affect accept length, never output bytes, so the on-disk round
//! trip stays byte-identical for tokens and deltas in every case, and for
//! stats whenever the pool was private (the suite pins this).
//!
//! ## On-disk format (version 2)
//!
//! ```text
//! LAKV1\n
//! <one JSON header line: model, engine state, params, output, stats, pool>\n
//! <raw HostKv payload bytes>[<raw draft HostKv payload bytes>]
//! ```
//!
//! The header carries `kv.bytes` (and `draft_kv.bytes` for two-model
//! engines) so the payload length is validated on load; 64-bit values
//! (seed, RNG state) are hex strings because the JSON substrate is
//! f64-backed. Version 2 adds the optional `draft_kv` section — the draft
//! model's cache image a suspended spec-decode session needs — appended
//! after the target payload; version-1 snapshots (no `draft_kv` key) still
//! load. Snapshots are worker- and process-portable: resuming on another
//! worker only requires the same model artifacts.

use std::rc::Rc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::engine::session::SessionCore;
use crate::engine::{DecodeSession, GenParams, SamplingParams};
use crate::metrics::DecodeStats;
use crate::ngram::{NgramCacheRegistry, PoolHandle};
use crate::runtime::{HostKv, ModelRuntime};
use crate::util::json::Json;
use crate::util::rng::Rng;

const MAGIC: &[u8] = b"LAKV1\n";
pub const SNAPSHOT_VERSION: u32 = 2;
/// Oldest header version [`SessionSnapshot::from_bytes`] still reads.
pub const SNAPSHOT_MIN_VERSION: u32 = 1;

/// FNV-1a 64 over a byte slice — the checksum for wire-transferred snapshot
/// chunks and the whole-payload transfer id (duplicate suppression). Chosen
/// to match the repo's other stable fingerprints (schedule fingerprint in
/// `bench/load.rs`): dependency-free, deterministic across platforms.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One checksummed range of a wire-transferred snapshot payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireFrame {
    /// byte offset into the payload slice handed to [`wire_chunks`]
    pub off: usize,
    pub len: usize,
    /// FNV-1a 64 of the `len` bytes at `off`
    pub sum: u64,
}

/// Split a snapshot payload into checksummed frames of at most `chunk`
/// bytes. An empty payload yields no frames (the transfer's `end` frame
/// still carries the whole-payload checksum).
pub fn wire_chunks(payload: &[u8], chunk: usize) -> Vec<WireFrame> {
    let chunk = chunk.max(1);
    payload
        .chunks(chunk)
        .enumerate()
        .map(|(i, c)| WireFrame { off: i * chunk, len: c.len(), sum: fnv64(c) })
        .collect()
}

/// Engine-specific resumable state. Every engine is snapshotable: the
/// deterministic inter-step state is the current token plus the engine's
/// own speculation source — RNG-fed trajectory rows (lookahead/Jacobi),
/// the token history (prompt-lookup), or the draft model's cache
/// (spec-decode, carried as the snapshot's `draft_kv` section).
#[derive(Debug, Clone, PartialEq)]
pub enum EngineState {
    Autoregressive {
        cur: u32,
        rng: [u64; 4],
    },
    Lookahead {
        w: usize,
        n: usize,
        g: usize,
        attn: String,
        force_generic: bool,
        /// the 2D lookahead window (N-1 rows x W columns).
        rows: Vec<Vec<u32>>,
        cur: u32,
        rng: [u64; 4],
    },
    Jacobi {
        /// chain length (decode_lin_k).
        k: usize,
        /// trajectory guesses y_1..y_{k-1} for the next positions.
        guesses: Vec<u32>,
        cur: u32,
        rng: [u64; 4],
    },
    PromptLookup {
        k: usize,
        match_len: usize,
        /// prompt + every accepted token (untrimmed — the speculation
        /// source; the candidate window is re-derived from it each step).
        history: Vec<u32>,
    },
    SpecDecode {
        gamma: usize,
        cur: u32,
        /// draft model name; resume needs a runtime for it (plus the
        /// snapshot's `draft_kv` cache image).
        draft: String,
    },
}

/// A suspended session: host-resident, serializable, resumable on any
/// runtime loaded from the same model artifacts.
pub struct SessionSnapshot {
    pub model: String,
    pub engine: EngineState,
    pub kv: HostKv,
    /// the draft model's cache image (spec-decode only): the second
    /// `cache_io` pass of a two-model suspend.
    pub draft_kv: Option<HostKv>,
    pub params: GenParams,
    /// committed (budget/EOS-trimmed) output so far.
    pub out: Vec<u32>,
    pub stats: DecodeStats,
    /// decode wall-clock accumulated before the suspend (suspended time is
    /// excluded from the resumed session's `stats.wall`).
    pub wall_offset: Duration,
    pub pool: PoolHandle,
}

fn hex_u64(v: u64) -> Json {
    Json::str(format!("{v:016x}"))
}

fn parse_hex(j: &Json, what: &str) -> Result<u64> {
    let s = j.as_str().ok_or_else(|| anyhow!("snapshot: {what} not a hex string"))?;
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("snapshot: bad {what}: {e}"))
}

fn rng_json(s: &[u64; 4]) -> Json {
    Json::arr(s.iter().map(|&v| hex_u64(v)).collect())
}

fn parse_rng(j: &Json, what: &str) -> Result<[u64; 4]> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("snapshot: {what} not an array"))?;
    if arr.len() != 4 {
        bail!("snapshot: {what} must have 4 words");
    }
    let mut out = [0u64; 4];
    for (i, v) in arr.iter().enumerate() {
        out[i] = parse_hex(v, what)?;
    }
    Ok(out)
}

fn u32s_json(v: &[u32]) -> Json {
    Json::arr(v.iter().map(|&x| Json::num(x as f64)).collect())
}

fn parse_u32s(j: &Json, what: &str) -> Result<Vec<u32>> {
    j.usize_vec()
        .map(|v| v.into_iter().map(|x| x as u32).collect())
        .ok_or_else(|| anyhow!("snapshot: {what} not a token array"))
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("snapshot: missing '{key}'"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize().ok_or_else(|| anyhow!("snapshot: '{key}' not usize"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?.as_f64().ok_or_else(|| anyhow!("snapshot: '{key}' not a number"))
}

fn req_bool(j: &Json, key: &str) -> Result<bool> {
    req(j, key)?.as_bool().ok_or_else(|| anyhow!("snapshot: '{key}' not a bool"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("snapshot: '{key}' not a string"))?
        .to_string())
}

fn dur_us(d: Duration) -> Json {
    Json::num(d.as_micros() as f64)
}

fn parse_dur(j: &Json, key: &str) -> Result<Duration> {
    Ok(Duration::from_micros(req_f64(j, key)? as u64))
}

impl SessionSnapshot {
    /// Serialize to the versioned on-disk format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let engine = match &self.engine {
            EngineState::Autoregressive { cur, rng } => Json::obj(vec![
                ("kind", Json::str("autoregressive")),
                ("cur", Json::num(*cur as f64)),
                ("rng", rng_json(rng)),
            ]),
            EngineState::Lookahead { w, n, g, attn, force_generic, rows, cur, rng } => {
                Json::obj(vec![
                    ("kind", Json::str("lookahead")),
                    ("w", Json::num(*w as f64)),
                    ("n", Json::num(*n as f64)),
                    ("g", Json::num(*g as f64)),
                    ("attn", Json::str(attn.clone())),
                    ("force_generic", Json::Bool(*force_generic)),
                    ("rows", Json::arr(rows.iter().map(|r| u32s_json(r)).collect())),
                    ("cur", Json::num(*cur as f64)),
                    ("rng", rng_json(rng)),
                ])
            }
            EngineState::Jacobi { k, guesses, cur, rng } => Json::obj(vec![
                ("kind", Json::str("jacobi")),
                ("k", Json::num(*k as f64)),
                ("guesses", u32s_json(guesses)),
                ("cur", Json::num(*cur as f64)),
                ("rng", rng_json(rng)),
            ]),
            EngineState::PromptLookup { k, match_len, history } => Json::obj(vec![
                ("kind", Json::str("prompt_lookup")),
                ("k", Json::num(*k as f64)),
                ("match_len", Json::num(*match_len as f64)),
                ("history", u32s_json(history)),
            ]),
            EngineState::SpecDecode { gamma, cur, draft } => Json::obj(vec![
                ("kind", Json::str("spec_decode")),
                ("gamma", Json::num(*gamma as f64)),
                ("cur", Json::num(*cur as f64)),
                ("draft", Json::str(draft.clone())),
            ]),
        };
        let p = &self.params;
        let params = Json::obj(vec![
            ("max_new_tokens", Json::num(p.max_new_tokens as f64)),
            ("temperature", Json::num(p.sampling.temperature)),
            ("top_k", Json::num(p.sampling.top_k as f64)),
            ("top_p", Json::num(p.sampling.top_p)),
            ("stop_at_eos", Json::Bool(p.stop_at_eos)),
            ("seed", hex_u64(p.seed)),
        ]);
        let s = &self.stats;
        let stats = Json::obj(vec![
            ("prompt_tokens", Json::num(s.prompt_tokens as f64)),
            ("generated_tokens", Json::num(s.generated_tokens as f64)),
            ("decode_steps", Json::num(s.decode_steps as f64)),
            ("accepted_by_len",
             Json::arr(s.accepted_by_len.iter().map(|&c| Json::num(c as f64)).collect())),
            ("pool_hits", Json::num(s.pool_hits as f64)),
            ("pool_misses", Json::num(s.pool_misses as f64)),
            ("pool_warm_start", Json::Bool(s.pool_warm_start)),
            ("pool_shared", Json::Bool(s.pool_shared)),
            ("pool_entries_start", Json::num(s.pool_entries_start as f64)),
            ("pool_entries_end", Json::num(s.pool_entries_end as f64)),
            ("prefill_us", dur_us(s.prefill_wall)),
            ("ttft_us", dur_us(s.ttft)),
        ]);
        let pe = self.pool.export();
        let pool = Json::obj(vec![
            ("shared", Json::Bool(pe.shared)),
            ("tenant", match &pe.tenant {
                Some(t) => Json::str(t.clone()),
                None => Json::Null,
            }),
            ("spec", match &pe.spec {
                Some((n, pk, tot, kind)) => Json::arr(vec![
                    Json::num(*n as f64),
                    Json::num(*pk as f64),
                    Json::num(*tot as f64),
                    Json::str(kind.clone()),
                ]),
                None => Json::Null,
            }),
            ("entries", Json::arr(pe.entries.iter().map(|g| u32s_json(g)).collect())),
            ("hits", Json::num(pe.hits as f64)),
            ("misses", Json::num(pe.misses as f64)),
            ("warm_start", Json::Bool(pe.warm_start)),
            ("entries_start", Json::num(pe.entries_start as f64)),
        ]);
        let header = Json::obj(vec![
            ("version", Json::num(SNAPSHOT_VERSION as f64)),
            ("model", Json::str(self.model.clone())),
            ("engine", engine),
            ("params", params),
            ("out", u32s_json(&self.out)),
            ("stats", stats),
            ("wall_offset_us", dur_us(self.wall_offset)),
            ("pool", pool),
            ("kv", Json::obj(vec![
                ("len", Json::num(self.kv.len as f64)),
                ("elem", Json::str(self.kv.elem.clone())),
                ("bytes", Json::num(self.kv.data.len() as f64)),
            ])),
            // v2: the draft model's cache image, appended after the target
            // payload (spec-decode only; Null elsewhere)
            ("draft_kv", match &self.draft_kv {
                Some(d) => Json::obj(vec![
                    ("len", Json::num(d.len as f64)),
                    ("elem", Json::str(d.elem.clone())),
                    ("bytes", Json::num(d.data.len() as f64)),
                ]),
                None => Json::Null,
            }),
        ]);
        let draft_len = self.draft_kv.as_ref().map_or(0, |d| d.data.len());
        let mut bytes =
            Vec::with_capacity(MAGIC.len() + self.kv.data.len() + draft_len + 512);
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(header.dump().as_bytes());
        bytes.push(b'\n');
        bytes.extend_from_slice(&self.kv.data);
        if let Some(d) = &self.draft_kv {
            bytes.extend_from_slice(&d.data);
        }
        bytes
    }

    /// Deserialize; shared pools degrade to cold private pools (pass a
    /// registry via [`SessionSnapshot::from_bytes_with`] to re-bind).
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionSnapshot> {
        Self::from_bytes_with(bytes, None)
    }

    /// Deserialize, re-binding a shared n-gram pool to `registry`'s cache
    /// for the snapshot's model when one is provided.
    pub fn from_bytes_with(bytes: &[u8], registry: Option<&NgramCacheRegistry>)
                           -> Result<SessionSnapshot> {
        let Some(rest) = bytes.strip_prefix(MAGIC) else {
            bail!("not a session snapshot (bad magic)");
        };
        let nl = rest
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| anyhow!("snapshot: truncated header"))?;
        let header = std::str::from_utf8(&rest[..nl])
            .map_err(|_| anyhow!("snapshot: header not UTF-8"))?;
        let data = &rest[nl + 1..];
        let j = Json::parse(header).map_err(|e| anyhow!("snapshot header: {e}"))?;
        let version = req_usize(&j, "version")? as u32;
        if !(SNAPSHOT_MIN_VERSION..=SNAPSHOT_VERSION).contains(&version) {
            bail!("snapshot version {version} unsupported \
                   (want {SNAPSHOT_MIN_VERSION}..={SNAPSHOT_VERSION})");
        }
        let model = req_str(&j, "model")?;

        let ej = req(&j, "engine")?;
        let engine = match req_str(ej, "kind")?.as_str() {
            "autoregressive" => EngineState::Autoregressive {
                cur: req_usize(ej, "cur")? as u32,
                rng: parse_rng(req(ej, "rng")?, "engine.rng")?,
            },
            "lookahead" => {
                let rows_j = req(ej, "rows")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("snapshot: rows not an array"))?;
                let rows = rows_j
                    .iter()
                    .map(|r| parse_u32s(r, "engine.rows"))
                    .collect::<Result<Vec<_>>>()?;
                EngineState::Lookahead {
                    w: req_usize(ej, "w")?,
                    n: req_usize(ej, "n")?,
                    g: req_usize(ej, "g")?,
                    attn: req_str(ej, "attn")?,
                    force_generic: req_bool(ej, "force_generic")?,
                    rows,
                    cur: req_usize(ej, "cur")? as u32,
                    rng: parse_rng(req(ej, "rng")?, "engine.rng")?,
                }
            }
            "jacobi" => EngineState::Jacobi {
                k: req_usize(ej, "k")?,
                guesses: parse_u32s(req(ej, "guesses")?, "engine.guesses")?,
                cur: req_usize(ej, "cur")? as u32,
                rng: parse_rng(req(ej, "rng")?, "engine.rng")?,
            },
            "prompt_lookup" => EngineState::PromptLookup {
                k: req_usize(ej, "k")?,
                match_len: req_usize(ej, "match_len")?,
                history: parse_u32s(req(ej, "history")?, "engine.history")?,
            },
            "spec_decode" => EngineState::SpecDecode {
                gamma: req_usize(ej, "gamma")?,
                cur: req_usize(ej, "cur")? as u32,
                draft: req_str(ej, "draft")?,
            },
            other => bail!("snapshot: unknown engine kind '{other}'"),
        };

        let pj = req(&j, "params")?;
        let params = GenParams {
            max_new_tokens: req_usize(pj, "max_new_tokens")?,
            sampling: SamplingParams {
                temperature: req_f64(pj, "temperature")?,
                top_k: req_usize(pj, "top_k")?,
                top_p: req_f64(pj, "top_p")?,
            },
            stop_at_eos: req_bool(pj, "stop_at_eos")?,
            seed: parse_hex(req(pj, "seed")?, "params.seed")?,
        };

        let sj = req(&j, "stats")?;
        let stats = DecodeStats {
            prompt_tokens: req_usize(sj, "prompt_tokens")?,
            generated_tokens: req_usize(sj, "generated_tokens")?,
            decode_steps: req_usize(sj, "decode_steps")?,
            accepted_by_len: req(sj, "accepted_by_len")?
                .usize_vec()
                .ok_or_else(|| anyhow!("snapshot: accepted_by_len"))?,
            pool_hits: req_usize(sj, "pool_hits")?,
            pool_misses: req_usize(sj, "pool_misses")?,
            pool_warm_start: req_bool(sj, "pool_warm_start")?,
            pool_shared: req_bool(sj, "pool_shared")?,
            pool_entries_start: req_usize(sj, "pool_entries_start")?,
            pool_entries_end: req_usize(sj, "pool_entries_end")?,
            wall: Duration::ZERO, // stamped at finish from wall_offset + timer
            prefill_wall: parse_dur(sj, "prefill_us")?,
            ttft: parse_dur(sj, "ttft_us")?,
        };

        let plj = req(&j, "pool")?;
        let export = crate::ngram::shared::PoolExport {
            spec: match req(plj, "spec")? {
                Json::Null => None,
                sp => {
                    let arr = sp.as_arr().ok_or_else(|| anyhow!("snapshot: pool.spec"))?;
                    if arr.len() != 4 {
                        bail!("snapshot: pool.spec arity");
                    }
                    Some((
                        arr[0].as_usize().ok_or_else(|| anyhow!("pool.spec n"))?,
                        arr[1].as_usize().ok_or_else(|| anyhow!("pool.spec per_key"))?,
                        arr[2].as_usize().ok_or_else(|| anyhow!("pool.spec total"))?,
                        arr[3]
                            .as_str()
                            .ok_or_else(|| anyhow!("pool.spec kind"))?
                            .to_string(),
                    ))
                }
            },
            shared: req_bool(plj, "shared")?,
            tenant: req(plj, "tenant")?.as_str().map(str::to_string),
            entries: req(plj, "entries")?
                .as_arr()
                .ok_or_else(|| anyhow!("snapshot: pool.entries"))?
                .iter()
                .map(|g| parse_u32s(g, "pool.entries"))
                .collect::<Result<Vec<_>>>()?,
            hits: req_usize(plj, "hits")?,
            misses: req_usize(plj, "misses")?,
            warm_start: req_bool(plj, "warm_start")?,
            entries_start: req_usize(plj, "entries_start")?,
        };
        let pool = export.restore(registry.map(|r| (r, model.as_str())));

        let kj = req(&j, "kv")?;
        let kv_len = req_usize(kj, "len")?;
        let kv_elem = req_str(kj, "elem")?;
        let kv_bytes = req_usize(kj, "bytes")?;
        // v2 appends the draft payload after the target payload; a missing
        // key (v1 header) and an explicit Null both mean "no draft cache"
        let draft_hdr = match j.get("draft_kv") {
            None | Some(Json::Null) => None,
            Some(dj) => Some((
                req_usize(dj, "len")?,
                req_str(dj, "elem")?,
                req_usize(dj, "bytes")?,
            )),
        };
        let draft_bytes = draft_hdr.as_ref().map_or(0, |(_, _, b)| *b);
        if data.len() != kv_bytes + draft_bytes {
            bail!("snapshot: payload is {} bytes, header says {kv_bytes}+{draft_bytes}",
                  data.len());
        }
        let draft_kv = draft_hdr.map(|(len, elem, _)| HostKv {
            len,
            elem,
            data: data[kv_bytes..].to_vec(),
        });

        Ok(SessionSnapshot {
            model,
            engine,
            kv: HostKv { len: kv_len, elem: kv_elem, data: data[..kv_bytes].to_vec() },
            draft_kv,
            params,
            out: parse_u32s(req(&j, "out")?, "out")?,
            stats,
            wall_offset: parse_dur(&j, "wall_offset_us")?,
            pool,
        })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow!("writing snapshot {path:?}: {e}"))
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<SessionSnapshot> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).map_err(|e| anyhow!("reading snapshot {path:?}: {e}"))?;
        Self::from_bytes(&bytes)
    }

    /// The draft model this snapshot needs a runtime for at resume time
    /// (`Some` only for spec-decode sessions). Callers holding one — the
    /// worker keeps a per-model draft-runtime cache — resume through
    /// [`SessionSnapshot::resume_with`].
    pub fn draft_model(&self) -> Option<&str> {
        match &self.engine {
            EngineState::SpecDecode { draft, .. } => Some(draft),
            _ => None,
        }
    }

    /// Reopen the session on `rt` (same model artifacts required) and
    /// continue exactly where it was suspended: the KV cache is restored to
    /// a fresh device buffer and the engine state (window/trajectory/
    /// history, RNG stream, current token) picks up mid-generation —
    /// tokens, deltas, and stats are byte-identical to a never-suspended
    /// run (`rust/tests/kv_manager.rs`). Spec-decode snapshots additionally
    /// need a draft runtime: use [`SessionSnapshot::resume_with`].
    pub fn resume<'rt>(self, rt: &'rt ModelRuntime)
                       -> Result<Box<dyn DecodeSession + 'rt>> {
        self.resume_with(rt, None)
    }

    /// [`SessionSnapshot::resume`] with a draft runtime for two-model
    /// engines. `draft` must serve the snapshot's [`SessionSnapshot::
    /// draft_model`]; it is ignored for single-model engines.
    pub fn resume_with<'rt>(self, rt: &'rt ModelRuntime,
                            draft: Option<Rc<ModelRuntime>>)
                            -> Result<Box<dyn DecodeSession + 'rt>> {
        if self.model != rt.mm.name {
            bail!("snapshot is for model '{}', runtime serves '{}'",
                  self.model, rt.mm.name);
        }
        let SessionSnapshot { engine, kv, draft_kv, params, out, stats, wall_offset,
                              pool, .. } = self;
        let cache = rt.cache_from_host(&kv)?;
        let core = SessionCore::resumed(params, stats, out, wall_offset);
        match engine {
            EngineState::Autoregressive { cur, rng } => {
                Ok(crate::engine::autoregressive::resume_session(
                    rt, core, cache, cur, Rng::from_state(rng), pool))
            }
            EngineState::Lookahead { w, n, g, attn, force_generic, rows, cur, rng } => {
                crate::engine::lookahead::resume_session(
                    rt, core, cache, (w, n, g), attn, force_generic, rows, cur,
                    Rng::from_state(rng), pool)
            }
            EngineState::Jacobi { k, guesses, cur, rng } => {
                crate::engine::jacobi::resume_session(
                    rt, core, cache, k, guesses, cur, Rng::from_state(rng), pool)
            }
            EngineState::PromptLookup { k, match_len, history } => {
                crate::engine::prompt_lookup::resume_session(
                    rt, core, cache, k, match_len, history, pool)
            }
            EngineState::SpecDecode { gamma, cur, draft: draft_name } => {
                let draft_rt = draft.ok_or_else(|| {
                    anyhow!("spec_decode snapshot needs a runtime for draft model \
                             '{draft_name}': resume via resume_with")
                })?;
                if draft_rt.mm.name != draft_name {
                    bail!("snapshot drafts with model '{draft_name}', runtime serves \
                           '{}'", draft_rt.mm.name);
                }
                let dkv = draft_kv.ok_or_else(|| {
                    anyhow!("spec_decode snapshot is missing its draft_kv section")
                })?;
                let dcache = draft_rt.cache_from_host(&dkv)?;
                crate::engine::spec_decode::resume_session(
                    rt, draft_rt, core, cache, dcache, gamma, cur, pool)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SessionSnapshot {
        let mut pool = PoolHandle::private(crate::ngram::PoolSpec::new(3, 4, 64));
        pool.insert(&[1, 2, 3]);
        let _ = pool.lookup(1, 4);
        let mut stats = DecodeStats { prompt_tokens: 5, ..Default::default() };
        stats.record_accept(2);
        stats.record_accept(3);
        stats.ttft = Duration::from_micros(1500);
        SessionSnapshot {
            model: "tiny".into(),
            engine: EngineState::Lookahead {
                w: 5,
                n: 3,
                g: 5,
                attn: "jnp".into(),
                force_generic: false,
                rows: vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7, 6, 5]],
                cur: 42,
                rng: [u64::MAX, 1, 0x1234_5678_9abc_def0, 7],
            },
            kv: HostKv { len: 9, elem: "i32".into(), data: vec![0xAB; 40] },
            draft_kv: None,
            params: GenParams {
                max_new_tokens: 64,
                sampling: SamplingParams { temperature: 0.7, top_k: 5, top_p: 0.9 },
                stop_at_eos: true,
                seed: u64::MAX - 3,
            },
            out: vec![10, 11, 12],
            stats,
            wall_offset: Duration::from_micros(2500),
            pool,
        }
    }

    #[test]
    fn fnv64_matches_reference_vectors() {
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn wire_chunks_cover_payload_and_checksums_verify() {
        let payload: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let frames = wire_chunks(&payload, 256);
        assert_eq!(frames.len(), 4);
        let mut off = 0;
        for f in &frames {
            assert_eq!(f.off, off);
            assert_eq!(f.sum, fnv64(&payload[f.off..f.off + f.len]));
            off += f.len;
        }
        assert_eq!(off, payload.len(), "frames must tile the payload exactly");
        // a resumed transfer re-chunks the tail; checksums stay verifiable
        let resume = wire_chunks(&payload[300..], 256);
        assert_eq!(resume[0].off, 0);
        assert_eq!(resume[0].sum, fnv64(&payload[300..556]));
        // degenerate inputs
        assert!(wire_chunks(&[], 256).is_empty());
        assert_eq!(wire_chunks(&payload, 0).len(), payload.len());
    }

    #[test]
    fn disk_format_roundtrips() {
        let snap = sample();
        let bytes = snap.to_bytes();
        assert!(bytes.starts_with(MAGIC));
        let back = SessionSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.model, "tiny");
        assert_eq!(back.engine, snap.engine);
        assert_eq!(back.kv, snap.kv);
        assert_eq!(back.out, snap.out);
        assert_eq!(back.params.seed, u64::MAX - 3, "64-bit seed must survive");
        assert_eq!(back.params.sampling, snap.params.sampling);
        assert_eq!(back.stats.generated_tokens, 5);
        assert_eq!(back.stats.accepted_by_len, snap.stats.accepted_by_len);
        assert_eq!(back.stats.ttft, snap.stats.ttft);
        assert_eq!(back.wall_offset, snap.wall_offset);
        // restored pool reproduces lookups and counters
        let mut p = back.pool;
        assert_eq!(p.lookup(1, 4), vec![vec![2, 3]]);
        assert_eq!((p.hits, p.misses), (2, 0));
    }

    #[test]
    fn spec_snapshot_roundtrips_with_draft_payload() {
        let mut snap = sample();
        snap.engine =
            EngineState::SpecDecode { gamma: 4, cur: 99, draft: "draft".into() };
        snap.draft_kv =
            Some(HostKv { len: 9, elem: "i32".into(), data: vec![0xCD; 24] });
        assert_eq!(snap.draft_model(), Some("draft"));
        let bytes = snap.to_bytes();
        let back = SessionSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.engine, snap.engine);
        // the concatenated payload splits back into the two cache images
        assert_eq!(back.kv, snap.kv);
        assert_eq!(back.draft_kv, snap.draft_kv);
        // truncating inside the draft section is caught by the length check
        assert!(SessionSnapshot::from_bytes(&bytes[..bytes.len() - 5]).is_err());
    }

    #[test]
    fn jacobi_and_prompt_lookup_states_roundtrip() {
        for engine in [
            EngineState::Jacobi {
                k: 8,
                guesses: vec![3, 1, 4, 1, 5, 9, 2],
                cur: 6,
                rng: [5, 6, 7, 8],
            },
            EngineState::PromptLookup {
                k: 8,
                match_len: 1,
                history: vec![257, 10, 20, 30, 10, 20],
            },
        ] {
            let mut snap = sample();
            snap.engine = engine.clone();
            let back = SessionSnapshot::from_bytes(&snap.to_bytes()).unwrap();
            assert_eq!(back.engine, engine);
            assert_eq!(back.draft_model(), None);
        }
    }

    #[test]
    fn version_1_snapshots_still_load() {
        // reconstruct a v1 image: same layout, header without the
        // `draft_kv` key and with the old version number
        let snap = sample();
        let bytes = snap.to_bytes();
        let rest = &bytes[MAGIC.len()..];
        let nl = rest.iter().position(|&b| b == b'\n').unwrap();
        let header = std::str::from_utf8(&rest[..nl]).unwrap();
        assert!(header.contains("\"version\":2"), "writer must stamp v2");
        // the JSON substrate sorts keys, so tolerate either comma side
        let v1 = header
            .replace("\"version\":2", "\"version\":1")
            .replace("\"draft_kv\":null,", "")
            .replace(",\"draft_kv\":null", "");
        assert!(!v1.contains("draft_kv"), "surgery failed: {v1}");
        let mut old = Vec::new();
        old.extend_from_slice(MAGIC);
        old.extend_from_slice(v1.as_bytes());
        old.push(b'\n');
        old.extend_from_slice(&rest[nl + 1..]);
        let back = SessionSnapshot::from_bytes(&old).unwrap();
        assert_eq!(back.engine, snap.engine);
        assert_eq!(back.kv, snap.kv);
        assert_eq!(back.draft_kv, None);
        // versions beyond the writer's are rejected, not misparsed
        let v3 = header.replace("\"version\":2", "\"version\":3");
        let mut future = Vec::new();
        future.extend_from_slice(MAGIC);
        future.extend_from_slice(v3.as_bytes());
        future.push(b'\n');
        future.extend_from_slice(&rest[nl + 1..]);
        assert!(SessionSnapshot::from_bytes(&future).is_err());
    }

    #[test]
    fn rejects_corrupt_snapshots() {
        let snap = sample();
        let bytes = snap.to_bytes();
        assert!(SessionSnapshot::from_bytes(b"nope").is_err());
        // truncated payload
        assert!(SessionSnapshot::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // wrong magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(SessionSnapshot::from_bytes(&bad).is_err());
    }

    #[test]
    fn save_load_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("la-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s1.kvsnap");
        let snap = sample();
        snap.save(&path).unwrap();
        let back = SessionSnapshot::load(&path).unwrap();
        assert_eq!(back.engine, snap.engine);
        assert_eq!(back.kv, snap.kv);
    }
}
