//! Versioned session snapshots: the host-resident, serializable form of a
//! suspended [`crate::engine::DecodeSession`].
//!
//! A snapshot captures everything a deterministic engine needs to continue
//! byte-identically: the committed output and stats, the generation params,
//! the engine's own state (window, RNG stream, current token), the n-gram
//! pool, and the [`HostKv`] image of the device cache. In-memory snapshots
//! keep the live [`PoolHandle`] (exact resume, shared caches included);
//! the on-disk form ([`SessionSnapshot::to_bytes`]) serializes private-pool
//! contents and re-binds (or cold-starts) shared caches on load — pool
//! contents affect accept length, never output bytes, so the on-disk round
//! trip stays byte-identical for tokens and deltas in every case, and for
//! stats whenever the pool was private (the suite pins this).
//!
//! ## On-disk format (version 1)
//!
//! ```text
//! LAKV1\n
//! <one JSON header line: model, engine state, params, output, stats, pool>\n
//! <raw HostKv payload bytes>
//! ```
//!
//! The header carries `kv.bytes` so the payload length is validated on
//! load; 64-bit values (seed, RNG state) are hex strings because the JSON
//! substrate is f64-backed. Snapshots are worker- and process-portable:
//! resuming on another worker only requires the same model artifacts.

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::engine::session::SessionCore;
use crate::engine::{DecodeSession, GenParams, SamplingParams};
use crate::metrics::DecodeStats;
use crate::ngram::{NgramCacheRegistry, PoolHandle};
use crate::runtime::{HostKv, ModelRuntime};
use crate::util::json::Json;
use crate::util::rng::Rng;

const MAGIC: &[u8] = b"LAKV1\n";
pub const SNAPSHOT_VERSION: u32 = 1;

/// Engine-specific resumable state. Only deterministic engines whose whole
/// step state lives between steps are snapshotable (autoregressive and
/// lookahead — jointly the serving default and the paper's contribution);
/// the other baselines report `suspendable() == false` and are simply never
/// parked by the worker.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineState {
    Autoregressive {
        cur: u32,
        rng: [u64; 4],
    },
    Lookahead {
        w: usize,
        n: usize,
        g: usize,
        attn: String,
        force_generic: bool,
        /// the 2D lookahead window (N-1 rows x W columns).
        rows: Vec<Vec<u32>>,
        cur: u32,
        rng: [u64; 4],
    },
}

/// A suspended session: host-resident, serializable, resumable on any
/// runtime loaded from the same model artifacts.
pub struct SessionSnapshot {
    pub model: String,
    pub engine: EngineState,
    pub kv: HostKv,
    pub params: GenParams,
    /// committed (budget/EOS-trimmed) output so far.
    pub out: Vec<u32>,
    pub stats: DecodeStats,
    /// decode wall-clock accumulated before the suspend (suspended time is
    /// excluded from the resumed session's `stats.wall`).
    pub wall_offset: Duration,
    pub pool: PoolHandle,
}

fn hex_u64(v: u64) -> Json {
    Json::str(format!("{v:016x}"))
}

fn parse_hex(j: &Json, what: &str) -> Result<u64> {
    let s = j.as_str().ok_or_else(|| anyhow!("snapshot: {what} not a hex string"))?;
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("snapshot: bad {what}: {e}"))
}

fn rng_json(s: &[u64; 4]) -> Json {
    Json::arr(s.iter().map(|&v| hex_u64(v)).collect())
}

fn parse_rng(j: &Json, what: &str) -> Result<[u64; 4]> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("snapshot: {what} not an array"))?;
    if arr.len() != 4 {
        bail!("snapshot: {what} must have 4 words");
    }
    let mut out = [0u64; 4];
    for (i, v) in arr.iter().enumerate() {
        out[i] = parse_hex(v, what)?;
    }
    Ok(out)
}

fn u32s_json(v: &[u32]) -> Json {
    Json::arr(v.iter().map(|&x| Json::num(x as f64)).collect())
}

fn parse_u32s(j: &Json, what: &str) -> Result<Vec<u32>> {
    j.usize_vec()
        .map(|v| v.into_iter().map(|x| x as u32).collect())
        .ok_or_else(|| anyhow!("snapshot: {what} not a token array"))
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("snapshot: missing '{key}'"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize().ok_or_else(|| anyhow!("snapshot: '{key}' not usize"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?.as_f64().ok_or_else(|| anyhow!("snapshot: '{key}' not a number"))
}

fn req_bool(j: &Json, key: &str) -> Result<bool> {
    req(j, key)?.as_bool().ok_or_else(|| anyhow!("snapshot: '{key}' not a bool"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("snapshot: '{key}' not a string"))?
        .to_string())
}

fn dur_us(d: Duration) -> Json {
    Json::num(d.as_micros() as f64)
}

fn parse_dur(j: &Json, key: &str) -> Result<Duration> {
    Ok(Duration::from_micros(req_f64(j, key)? as u64))
}

impl SessionSnapshot {
    /// Serialize to the versioned on-disk format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let engine = match &self.engine {
            EngineState::Autoregressive { cur, rng } => Json::obj(vec![
                ("kind", Json::str("autoregressive")),
                ("cur", Json::num(*cur as f64)),
                ("rng", rng_json(rng)),
            ]),
            EngineState::Lookahead { w, n, g, attn, force_generic, rows, cur, rng } => {
                Json::obj(vec![
                    ("kind", Json::str("lookahead")),
                    ("w", Json::num(*w as f64)),
                    ("n", Json::num(*n as f64)),
                    ("g", Json::num(*g as f64)),
                    ("attn", Json::str(attn.clone())),
                    ("force_generic", Json::Bool(*force_generic)),
                    ("rows", Json::arr(rows.iter().map(|r| u32s_json(r)).collect())),
                    ("cur", Json::num(*cur as f64)),
                    ("rng", rng_json(rng)),
                ])
            }
        };
        let p = &self.params;
        let params = Json::obj(vec![
            ("max_new_tokens", Json::num(p.max_new_tokens as f64)),
            ("temperature", Json::num(p.sampling.temperature)),
            ("top_k", Json::num(p.sampling.top_k as f64)),
            ("top_p", Json::num(p.sampling.top_p)),
            ("stop_at_eos", Json::Bool(p.stop_at_eos)),
            ("seed", hex_u64(p.seed)),
        ]);
        let s = &self.stats;
        let stats = Json::obj(vec![
            ("prompt_tokens", Json::num(s.prompt_tokens as f64)),
            ("generated_tokens", Json::num(s.generated_tokens as f64)),
            ("decode_steps", Json::num(s.decode_steps as f64)),
            ("accepted_by_len",
             Json::arr(s.accepted_by_len.iter().map(|&c| Json::num(c as f64)).collect())),
            ("pool_hits", Json::num(s.pool_hits as f64)),
            ("pool_misses", Json::num(s.pool_misses as f64)),
            ("pool_warm_start", Json::Bool(s.pool_warm_start)),
            ("pool_shared", Json::Bool(s.pool_shared)),
            ("pool_entries_start", Json::num(s.pool_entries_start as f64)),
            ("pool_entries_end", Json::num(s.pool_entries_end as f64)),
            ("prefill_us", dur_us(s.prefill_wall)),
            ("ttft_us", dur_us(s.ttft)),
        ]);
        let pe = self.pool.export();
        let pool = Json::obj(vec![
            ("shared", Json::Bool(pe.shared)),
            ("tenant", match &pe.tenant {
                Some(t) => Json::str(t.clone()),
                None => Json::Null,
            }),
            ("spec", match &pe.spec {
                Some((n, pk, tot, kind)) => Json::arr(vec![
                    Json::num(*n as f64),
                    Json::num(*pk as f64),
                    Json::num(*tot as f64),
                    Json::str(kind.clone()),
                ]),
                None => Json::Null,
            }),
            ("entries", Json::arr(pe.entries.iter().map(|g| u32s_json(g)).collect())),
            ("hits", Json::num(pe.hits as f64)),
            ("misses", Json::num(pe.misses as f64)),
            ("warm_start", Json::Bool(pe.warm_start)),
            ("entries_start", Json::num(pe.entries_start as f64)),
        ]);
        let header = Json::obj(vec![
            ("version", Json::num(SNAPSHOT_VERSION as f64)),
            ("model", Json::str(self.model.clone())),
            ("engine", engine),
            ("params", params),
            ("out", u32s_json(&self.out)),
            ("stats", stats),
            ("wall_offset_us", dur_us(self.wall_offset)),
            ("pool", pool),
            ("kv", Json::obj(vec![
                ("len", Json::num(self.kv.len as f64)),
                ("elem", Json::str(self.kv.elem.clone())),
                ("bytes", Json::num(self.kv.data.len() as f64)),
            ])),
        ]);
        let mut bytes = Vec::with_capacity(MAGIC.len() + self.kv.data.len() + 512);
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(header.dump().as_bytes());
        bytes.push(b'\n');
        bytes.extend_from_slice(&self.kv.data);
        bytes
    }

    /// Deserialize; shared pools degrade to cold private pools (pass a
    /// registry via [`SessionSnapshot::from_bytes_with`] to re-bind).
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionSnapshot> {
        Self::from_bytes_with(bytes, None)
    }

    /// Deserialize, re-binding a shared n-gram pool to `registry`'s cache
    /// for the snapshot's model when one is provided.
    pub fn from_bytes_with(bytes: &[u8], registry: Option<&NgramCacheRegistry>)
                           -> Result<SessionSnapshot> {
        let Some(rest) = bytes.strip_prefix(MAGIC) else {
            bail!("not a session snapshot (bad magic)");
        };
        let nl = rest
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| anyhow!("snapshot: truncated header"))?;
        let header = std::str::from_utf8(&rest[..nl])
            .map_err(|_| anyhow!("snapshot: header not UTF-8"))?;
        let data = &rest[nl + 1..];
        let j = Json::parse(header).map_err(|e| anyhow!("snapshot header: {e}"))?;
        let version = req_usize(&j, "version")? as u32;
        if version != SNAPSHOT_VERSION {
            bail!("snapshot version {version} unsupported (want {SNAPSHOT_VERSION})");
        }
        let model = req_str(&j, "model")?;

        let ej = req(&j, "engine")?;
        let engine = match req_str(ej, "kind")?.as_str() {
            "autoregressive" => EngineState::Autoregressive {
                cur: req_usize(ej, "cur")? as u32,
                rng: parse_rng(req(ej, "rng")?, "engine.rng")?,
            },
            "lookahead" => {
                let rows_j = req(ej, "rows")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("snapshot: rows not an array"))?;
                let rows = rows_j
                    .iter()
                    .map(|r| parse_u32s(r, "engine.rows"))
                    .collect::<Result<Vec<_>>>()?;
                EngineState::Lookahead {
                    w: req_usize(ej, "w")?,
                    n: req_usize(ej, "n")?,
                    g: req_usize(ej, "g")?,
                    attn: req_str(ej, "attn")?,
                    force_generic: req_bool(ej, "force_generic")?,
                    rows,
                    cur: req_usize(ej, "cur")? as u32,
                    rng: parse_rng(req(ej, "rng")?, "engine.rng")?,
                }
            }
            other => bail!("snapshot: unknown engine kind '{other}'"),
        };

        let pj = req(&j, "params")?;
        let params = GenParams {
            max_new_tokens: req_usize(pj, "max_new_tokens")?,
            sampling: SamplingParams {
                temperature: req_f64(pj, "temperature")?,
                top_k: req_usize(pj, "top_k")?,
                top_p: req_f64(pj, "top_p")?,
            },
            stop_at_eos: req_bool(pj, "stop_at_eos")?,
            seed: parse_hex(req(pj, "seed")?, "params.seed")?,
        };

        let sj = req(&j, "stats")?;
        let stats = DecodeStats {
            prompt_tokens: req_usize(sj, "prompt_tokens")?,
            generated_tokens: req_usize(sj, "generated_tokens")?,
            decode_steps: req_usize(sj, "decode_steps")?,
            accepted_by_len: req(sj, "accepted_by_len")?
                .usize_vec()
                .ok_or_else(|| anyhow!("snapshot: accepted_by_len"))?,
            pool_hits: req_usize(sj, "pool_hits")?,
            pool_misses: req_usize(sj, "pool_misses")?,
            pool_warm_start: req_bool(sj, "pool_warm_start")?,
            pool_shared: req_bool(sj, "pool_shared")?,
            pool_entries_start: req_usize(sj, "pool_entries_start")?,
            pool_entries_end: req_usize(sj, "pool_entries_end")?,
            wall: Duration::ZERO, // stamped at finish from wall_offset + timer
            prefill_wall: parse_dur(sj, "prefill_us")?,
            ttft: parse_dur(sj, "ttft_us")?,
        };

        let plj = req(&j, "pool")?;
        let export = crate::ngram::shared::PoolExport {
            spec: match req(plj, "spec")? {
                Json::Null => None,
                sp => {
                    let arr = sp.as_arr().ok_or_else(|| anyhow!("snapshot: pool.spec"))?;
                    if arr.len() != 4 {
                        bail!("snapshot: pool.spec arity");
                    }
                    Some((
                        arr[0].as_usize().ok_or_else(|| anyhow!("pool.spec n"))?,
                        arr[1].as_usize().ok_or_else(|| anyhow!("pool.spec per_key"))?,
                        arr[2].as_usize().ok_or_else(|| anyhow!("pool.spec total"))?,
                        arr[3]
                            .as_str()
                            .ok_or_else(|| anyhow!("pool.spec kind"))?
                            .to_string(),
                    ))
                }
            },
            shared: req_bool(plj, "shared")?,
            tenant: req(plj, "tenant")?.as_str().map(str::to_string),
            entries: req(plj, "entries")?
                .as_arr()
                .ok_or_else(|| anyhow!("snapshot: pool.entries"))?
                .iter()
                .map(|g| parse_u32s(g, "pool.entries"))
                .collect::<Result<Vec<_>>>()?,
            hits: req_usize(plj, "hits")?,
            misses: req_usize(plj, "misses")?,
            warm_start: req_bool(plj, "warm_start")?,
            entries_start: req_usize(plj, "entries_start")?,
        };
        let pool = export.restore(registry.map(|r| (r, model.as_str())));

        let kj = req(&j, "kv")?;
        let kv_len = req_usize(kj, "len")?;
        let kv_elem = req_str(kj, "elem")?;
        let kv_bytes = req_usize(kj, "bytes")?;
        if data.len() != kv_bytes {
            bail!("snapshot: payload is {} bytes, header says {kv_bytes}", data.len());
        }

        Ok(SessionSnapshot {
            model,
            engine,
            kv: HostKv { len: kv_len, elem: kv_elem, data: data.to_vec() },
            params,
            out: parse_u32s(req(&j, "out")?, "out")?,
            stats,
            wall_offset: parse_dur(&j, "wall_offset_us")?,
            pool,
        })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow!("writing snapshot {path:?}: {e}"))
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<SessionSnapshot> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).map_err(|e| anyhow!("reading snapshot {path:?}: {e}"))?;
        Self::from_bytes(&bytes)
    }

    /// Reopen the session on `rt` (same model artifacts required) and
    /// continue exactly where it was suspended: the KV cache is restored to
    /// a fresh device buffer and the engine state (window, RNG stream,
    /// current token) picks up mid-generation — tokens, deltas, and stats
    /// are byte-identical to a never-suspended run (`rust/tests/kv_manager.rs`).
    pub fn resume<'rt>(self, rt: &'rt ModelRuntime)
                       -> Result<Box<dyn DecodeSession + 'rt>> {
        if self.model != rt.mm.name {
            bail!("snapshot is for model '{}', runtime serves '{}'",
                  self.model, rt.mm.name);
        }
        let cache = rt.cache_from_host(&self.kv)?;
        let core =
            SessionCore::resumed(self.params, self.stats, self.out, self.wall_offset);
        match self.engine {
            EngineState::Autoregressive { cur, rng } => {
                Ok(crate::engine::autoregressive::resume_session(
                    rt, core, cache, cur, Rng::from_state(rng), self.pool))
            }
            EngineState::Lookahead { w, n, g, attn, force_generic, rows, cur, rng } => {
                crate::engine::lookahead::resume_session(
                    rt, core, cache, (w, n, g), attn, force_generic, rows, cur,
                    Rng::from_state(rng), self.pool)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SessionSnapshot {
        let mut pool = PoolHandle::private(crate::ngram::PoolSpec::new(3, 4, 64));
        pool.insert(&[1, 2, 3]);
        let _ = pool.lookup(1, 4);
        let mut stats = DecodeStats { prompt_tokens: 5, ..Default::default() };
        stats.record_accept(2);
        stats.record_accept(3);
        stats.ttft = Duration::from_micros(1500);
        SessionSnapshot {
            model: "tiny".into(),
            engine: EngineState::Lookahead {
                w: 5,
                n: 3,
                g: 5,
                attn: "jnp".into(),
                force_generic: false,
                rows: vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7, 6, 5]],
                cur: 42,
                rng: [u64::MAX, 1, 0x1234_5678_9abc_def0, 7],
            },
            kv: HostKv { len: 9, elem: "i32".into(), data: vec![0xAB; 40] },
            params: GenParams {
                max_new_tokens: 64,
                sampling: SamplingParams { temperature: 0.7, top_k: 5, top_p: 0.9 },
                stop_at_eos: true,
                seed: u64::MAX - 3,
            },
            out: vec![10, 11, 12],
            stats,
            wall_offset: Duration::from_micros(2500),
            pool,
        }
    }

    #[test]
    fn disk_format_roundtrips() {
        let snap = sample();
        let bytes = snap.to_bytes();
        assert!(bytes.starts_with(MAGIC));
        let back = SessionSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.model, "tiny");
        assert_eq!(back.engine, snap.engine);
        assert_eq!(back.kv, snap.kv);
        assert_eq!(back.out, snap.out);
        assert_eq!(back.params.seed, u64::MAX - 3, "64-bit seed must survive");
        assert_eq!(back.params.sampling, snap.params.sampling);
        assert_eq!(back.stats.generated_tokens, 5);
        assert_eq!(back.stats.accepted_by_len, snap.stats.accepted_by_len);
        assert_eq!(back.stats.ttft, snap.stats.ttft);
        assert_eq!(back.wall_offset, snap.wall_offset);
        // restored pool reproduces lookups and counters
        let mut p = back.pool;
        assert_eq!(p.lookup(1, 4), vec![vec![2, 3]]);
        assert_eq!((p.hits, p.misses), (2, 0));
    }

    #[test]
    fn rejects_corrupt_snapshots() {
        let snap = sample();
        let bytes = snap.to_bytes();
        assert!(SessionSnapshot::from_bytes(b"nope").is_err());
        // truncated payload
        assert!(SessionSnapshot::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // wrong magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(SessionSnapshot::from_bytes(&bad).is_err());
    }

    #[test]
    fn save_load_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("la-snap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s1.kvsnap");
        let snap = sample();
        snap.save(&path).unwrap();
        let back = SessionSnapshot::load(&path).unwrap();
        assert_eq!(back.engine, snap.engine);
        assert_eq!(back.kv, snap.kv);
    }
}
