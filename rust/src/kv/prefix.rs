//! Prefix-reuse trie: host-resident KV snapshots keyed by committed prompt
//! token sequences.
//!
//! Production prompt traffic is dominated by shared prefixes (system
//! prompts, few-shot templates, conversation history). Every byte of a
//! shared prefix still pays a full prefill per request because the KV cache
//! is private to the session. The [`PrefixCache`] closes that gap at the
//! *host* level: after a prefill, the runtime stores a [`HostKv`] image of
//! the cache keyed by the full prompt; a later request walks the trie along
//! its own prompt and, from the deepest reachable node, forks any stored
//! snapshot that shares that prefix — restore (fresh device buffer =
//! copy-on-write) plus a token-by-token extension for the unshared tail.
//! Bit-exactness: a cache row holds the KV of exactly one committed token,
//! so rows `0..d` of any snapshot whose key shares a `d`-token prefix with
//! the new prompt are identical to what a cold prefill would produce.
//!
//! Invalidation rules (see DESIGN.md §4): entries are only ever evicted —
//! never mutated — because keys are immutable token sequences; eviction is
//! LRU by last fork/insert with a `max_entries` cap, and interior trie
//! nodes are pruned as soon as they lead to no entry. A `min_prefix` floor
//! keeps short prompts (where prefill is cheap and reuse pollutes the trie)
//! out entirely.
//!
//! The trie stores host data only, so one `Arc<PrefixCache>` is shared by
//! all workers of a model (interior `Mutex`); device restore happens on the
//! worker's own runtime.

use std::collections::HashMap;
use std::sync::Arc;

use crate::runtime::HostKv;
use crate::util::sync::{rank, RankedMutex};

/// Default minimum shared-prefix length (tokens) for storing/forking.
pub const DEFAULT_MIN_PREFIX: usize = 32;
/// Default snapshot-count cap.
pub const DEFAULT_MAX_ENTRIES: usize = 64;

/// Point-in-time counters of a [`PrefixCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// lookups that forked a snapshot (the request skipped its prefill).
    pub hits: u64,
    /// lookups that fell through to a full prefill.
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// stored snapshots.
    pub entries: usize,
    /// bytes held by stored snapshots.
    pub bytes: usize,
    /// cumulative snapshot bytes served from the trie instead of prefill.
    pub bytes_reused: u64,
}

impl PrefixStats {
    pub fn hit_rate(&self) -> f64 {
        crate::metrics::hit_rate(self.hits, self.misses)
    }
}

#[derive(Default)]
struct Node {
    children: HashMap<u32, Node>,
    /// snapshot stored at this exact key depth, with its LRU stamp.
    entry: Option<(Arc<HostKv>, u64)>,
}

impl Node {
    /// Most-recently-used snapshot anywhere in this subtree.
    fn best(&self) -> Option<(Arc<HostKv>, u64)> {
        let mut best = self.entry.clone();
        for c in self.children.values() {
            if let Some(b) = c.best() {
                if best.as_ref().is_none_or(|(_, s)| b.1 > *s) {
                    best = Some(b);
                }
            }
        }
        best
    }

    /// Path (from here) to the least-recently-used entry in this subtree.
    fn lru_path(&self, path: &mut Vec<u32>, out: &mut Option<(Vec<u32>, u64)>) {
        if let Some((_, stamp)) = &self.entry {
            if out.as_ref().is_none_or(|(_, s)| *stamp < *s) {
                *out = Some((path.clone(), *stamp));
            }
        }
        for (&t, c) in &self.children {
            path.push(t);
            c.lru_path(path, out);
            path.pop();
        }
    }
}

struct Trie {
    /// one root per namespace: tenants must never observe (or time) each
    /// other's prefixes — a shared-prefix cache is a classic cross-tenant
    /// probing side channel. "" is the default (no-tenant) namespace.
    roots: HashMap<String, Node>,
    clock: u64,
    entries: usize,
    bytes: usize,
    hits: u64,
    misses: u64,
    inserts: u64,
    evictions: u64,
    bytes_reused: u64,
}

/// Thread-safe prefix-reuse trie shared by all workers serving one model.
pub struct PrefixCache {
    min_prefix: usize,
    max_entries: usize,
    /// [`rank::KV`]: workers probe the trie from their serve loop with at
    /// most the hub/scheduler tier outstanding; nothing below NGRAM/LEAF is
    /// ever acquired while the trie is held.
    inner: RankedMutex<Trie>,
}

impl PrefixCache {
    pub fn new(min_prefix: usize, max_entries: usize) -> PrefixCache {
        PrefixCache {
            min_prefix: min_prefix.max(1),
            max_entries: max_entries.max(1),
            inner: RankedMutex::new(rank::KV, "kv.prefix", Trie {
                roots: HashMap::new(),
                clock: 0,
                entries: 0,
                bytes: 0,
                hits: 0,
                misses: 0,
                inserts: 0,
                evictions: 0,
                bytes_reused: 0,
            }),
        }
    }

    pub fn with_defaults() -> PrefixCache {
        PrefixCache::new(DEFAULT_MIN_PREFIX, DEFAULT_MAX_ENTRIES)
    }

    pub fn min_prefix(&self) -> usize {
        self.min_prefix
    }

    /// The longest stored prefix usable for `tokens` in namespace `ns`
    /// (the serving layer passes the request tenant; "" = default): walks
    /// that namespace's trie along the prompt to the deepest reachable
    /// node (depth `d` = the shared committed prefix) and returns the
    /// most-recent snapshot in that node's subtree — every snapshot there
    /// shares exactly `d` leading tokens with the prompt, so its first `d`
    /// cache rows are the rows a cold prefill would write.
    /// `allow_partial = false` restricts to full-prompt coverage
    /// (`d == tokens.len()`) for callers that cannot extend a forked cache
    /// token-by-token. Hit/miss counters reflect whether the caller skips
    /// its prefill.
    pub fn lookup(&self, ns: &str, tokens: &[u32], allow_partial: bool)
                  -> Option<(usize, Arc<HostKv>)> {
        let mut t = self.inner.lock();
        let Some(root) = t.roots.get(ns) else {
            t.misses += 1;
            return None;
        };
        let mut node = root;
        let mut depth = 0usize;
        for &tok in tokens {
            match node.children.get(&tok) {
                Some(c) => {
                    node = c;
                    depth += 1;
                }
                None => break,
            }
        }
        if depth < self.min_prefix || (!allow_partial && depth < tokens.len()) {
            t.misses += 1;
            return None;
        }
        let Some((kv, _)) = node.best() else {
            // a trie node always leads to >= 1 entry (pruned on eviction),
            // but stay defensive
            t.misses += 1;
            return None;
        };
        t.hits += 1;
        t.bytes_reused += kv.bytes() as u64;
        // touch for LRU: restamp the chosen entry wherever it lives
        t.clock += 1;
        let stamp = t.clock;
        if let Some(root) = t.roots.get_mut(ns) {
            Self::restamp(root, &kv, stamp);
        }
        Some((depth, kv))
    }

    /// Restamp the entry holding `kv` (pointer identity) to `stamp`.
    fn restamp(node: &mut Node, kv: &Arc<HostKv>, stamp: u64) -> bool {
        if let Some((e, s)) = &mut node.entry {
            if Arc::ptr_eq(e, kv) {
                *s = stamp;
                return true;
            }
        }
        for c in node.children.values_mut() {
            if Self::restamp(c, kv, stamp) {
                return true;
            }
        }
        false
    }

    /// Store a snapshot keyed by the full prompt under namespace `ns`.
    /// No-ops on short prompts and existing keys (first snapshot wins —
    /// identical by construction).
    pub fn insert(&self, ns: &str, tokens: &[u32], kv: HostKv) {
        if tokens.len() < self.min_prefix {
            return;
        }
        let mut t = self.inner.lock();
        t.clock += 1;
        let stamp = t.clock;
        let bytes = kv.bytes();
        let mut node = t.roots.entry(ns.to_string()).or_default();
        for &tok in tokens {
            node = node.children.entry(tok).or_default();
        }
        if node.entry.is_some() {
            return;
        }
        node.entry = Some((Arc::new(kv), stamp));
        t.entries += 1;
        t.bytes += bytes;
        t.inserts += 1;
        while t.entries > self.max_entries {
            Self::evict_lru(&mut t);
        }
    }

    fn evict_lru(t: &mut Trie) {
        // LRU across every namespace (the entry cap is global)
        let mut victim: Option<(String, Vec<u32>, u64)> = None;
        for (ns, root) in &t.roots {
            let mut path = Vec::new();
            let mut v: Option<(Vec<u32>, u64)> = None;
            root.lru_path(&mut path, &mut v);
            if let Some((key, stamp)) = v {
                if victim.as_ref().is_none_or(|(_, _, s)| stamp < *s) {
                    victim = Some((ns.clone(), key, stamp));
                }
            }
        }
        let Some((ns, key, _)) = victim else { return };
        // remove the entry, pruning nodes that lead nowhere
        fn remove(node: &mut Node, key: &[u32]) -> Option<usize> {
            match key.first() {
                None => {
                    let (kv, _) = node.entry.take()?;
                    Some(kv.bytes())
                }
                Some(&t) => {
                    let child = node.children.get_mut(&t)?;
                    let freed = remove(child, &key[1..])?;
                    if child.children.is_empty() && child.entry.is_none() {
                        node.children.remove(&t);
                    }
                    Some(freed)
                }
            }
        }
        let Some(root) = t.roots.get_mut(&ns) else { return };
        if let Some(freed) = remove(&mut *root, &key) {
            if root.children.is_empty() && root.entry.is_none() {
                t.roots.remove(&ns);
            }
            t.entries -= 1;
            t.bytes -= freed;
            t.evictions += 1;
        }
    }

    pub fn stats(&self) -> PrefixStats {
        let t = self.inner.lock();
        PrefixStats {
            hits: t.hits,
            misses: t.misses,
            inserts: t.inserts,
            evictions: t.evictions,
            entries: t.entries,
            bytes: t.bytes,
            bytes_reused: t.bytes_reused,
        }
    }

    /// One human-readable metrics line (server report format).
    pub fn report(&self) -> String {
        let s = self.stats();
        format!(
            "prefix_cache: entries={} bytes={} hits={} misses={} hit_rate={:.2} \
             inserts={} evictions={} bytes_reused={}\n",
            s.entries, s.bytes, s.hits, s.misses, s.hit_rate(), s.inserts,
            s.evictions, s.bytes_reused
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(len: usize, tag: u8) -> HostKv {
        HostKv { len, elem: "i32".into(), data: vec![tag; 64] }
    }

    fn toks(base: &[u32], tail: &[u32]) -> Vec<u32> {
        let mut v = base.to_vec();
        v.extend_from_slice(tail);
        v
    }

    #[test]
    fn exact_and_partial_hits() {
        let pc = PrefixCache::new(4, 8);
        let sys: Vec<u32> = (0..10).collect();
        let p1 = toks(&sys, &[100, 101]);
        pc.insert("", &p1, kv(p1.len() - 1, 1));

        // exact: full key walk
        let (d, got) = pc.lookup("", &p1, false).unwrap();
        assert_eq!(d, p1.len());
        assert_eq!(got.data, vec![1; 64]);

        // partial: diverges after the shared prefix
        let p2 = toks(&sys, &[200, 201, 202]);
        let (d, _) = pc.lookup("", &p2, true).unwrap();
        assert_eq!(d, sys.len(), "shared prefix depth");
        // without extension support, partial coverage is a miss
        assert!(pc.lookup("", &p2, false).is_none());

        let st = pc.stats();
        assert_eq!((st.hits, st.misses), (2, 1));
        assert!(st.bytes_reused > 0);
    }

    #[test]
    fn min_prefix_floor() {
        let pc = PrefixCache::new(8, 8);
        pc.insert("", &[1, 2, 3], kv(2, 1)); // too short: not stored
        assert_eq!(pc.stats().entries, 0);
        let long: Vec<u32> = (0..12).collect();
        pc.insert("", &long, kv(11, 2));
        // shared prefix of 5 < min_prefix: miss
        assert!(pc
            .lookup("", &[0, 1, 2, 3, 4, 99, 98, 97, 96, 95, 94, 93], true)
            .is_none());
        assert!(pc.lookup("", &long, false).is_some());
    }

    #[test]
    fn prefers_most_recent_snapshot_in_subtree() {
        let pc = PrefixCache::new(2, 8);
        let sys = [5u32, 6];
        pc.insert("", &toks(&sys, &[10, 11]), kv(3, 1));
        pc.insert("", &toks(&sys, &[20, 21]), kv(3, 2));
        // both share prefix [5,6] with the probe; the newer one wins
        let (d, got) = pc.lookup("", &toks(&sys, &[30]), true).unwrap();
        assert_eq!(d, 2);
        assert_eq!(got.data, vec![2; 64]);
    }

    #[test]
    fn lru_eviction_prunes_and_counts() {
        let pc = PrefixCache::new(2, 2);
        pc.insert("", &[1, 2, 3], kv(2, 1));
        pc.insert("", &[4, 5, 6], kv(2, 2));
        // touch the first so the second becomes LRU
        assert!(pc.lookup("", &[1, 2, 3], false).is_some());
        pc.insert("", &[7, 8, 9], kv(2, 3)); // evicts [4,5,6]
        let st = pc.stats();
        assert_eq!(st.entries, 2);
        assert_eq!(st.evictions, 1);
        assert!(pc.lookup("", &[4, 5, 6], false).is_none());
        assert!(pc.lookup("", &[1, 2, 3], false).is_some());
        assert!(pc.lookup("", &[7, 8, 9], false).is_some());
        // bytes accounting survives eviction
        assert_eq!(st.bytes, 2 * 64);
    }

    #[test]
    fn duplicate_insert_is_a_noop() {
        let pc = PrefixCache::new(2, 8);
        pc.insert("", &[1, 2, 3], kv(2, 1));
        pc.insert("", &[1, 2, 3], kv(2, 9));
        let st = pc.stats();
        assert_eq!((st.entries, st.inserts), (1, 1));
        let (_, got) = pc.lookup("", &[1, 2, 3], false).unwrap();
        assert_eq!(got.data, vec![1; 64], "first snapshot wins");
    }

    #[test]
    fn namespaces_isolate_tenants() {
        let pc = PrefixCache::new(2, 8);
        let prompt = [1u32, 2, 3, 4];
        pc.insert("acme", &prompt, kv(3, 1));
        // the exact same prefix must NOT hit from another tenant (or the
        // default namespace): that timing difference is a side channel
        assert!(pc.lookup("globex", &prompt, true).is_none());
        assert!(pc.lookup("", &prompt, true).is_none());
        assert!(pc.lookup("acme", &prompt, false).is_some());
        // eviction spans namespaces (the cap is global) and prunes empty
        // namespace roots
        let pc = PrefixCache::new(2, 1);
        pc.insert("a", &[1, 2, 3], kv(2, 1));
        pc.insert("b", &[4, 5, 6], kv(2, 2)); // evicts a's only entry
        assert!(pc.lookup("a", &[1, 2, 3], false).is_none());
        assert!(pc.lookup("b", &[4, 5, 6], false).is_some());
        assert_eq!(pc.stats().entries, 1);
        assert_eq!(pc.stats().evictions, 1);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let pc = Arc::new(PrefixCache::new(2, 16));
        let mut joins = Vec::new();
        for t in 0..4u32 {
            let pc = pc.clone();
            joins.push(std::thread::spawn(move || {
                let ns = if t % 2 == 0 { "even" } else { "odd" };
                for i in 0..200u32 {
                    let key = vec![t, i % 8, i % 5];
                    pc.insert(ns, &key, kv(2, t as u8));
                    let _ = pc.lookup(ns, &key, true);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let st = pc.stats();
        assert!(st.entries <= 16);
        assert!(st.hits > 0);
    }
}
