//! KV-cache manager (L3.5): the layer that turns the per-session device
//! cache from an unmovable, lifetime-pinned buffer into a managed resource.
//!
//! Three capabilities, all built on the runtime's `cache_io` serialization
//! hook (`ModelRuntime::cache_to_host` / `cache_from_host`):
//!
//! - **snapshot/restore** ([`snapshot::SessionSnapshot`]): a suspended
//!   session — any of the five engines — serializes to a versioned
//!   host/disk image and resumes byte-identically — later, or on another
//!   worker with the same model artifacts (the roadmap's session
//!   persistence/migration item); two-model engines (spec-decode) carry
//!   the draft cache as a second `cache_io` payload;
//! - **prefix reuse** ([`prefix::PrefixCache`]): a trie of committed-prompt
//!   KV snapshots lets requests sharing a long prompt prefix fork a stored
//!   cache (restore = fresh device buffer = copy-on-write) instead of
//!   paying a full prefill;
//! - **suspend/resume scheduling** ([`KvManager`] + the worker's park/revive
//!   loop): when live sessions exceed the device budget (`--kv-budget`),
//!   the coldest suspendable session is parked (snapshot + device free) and
//!   revived when a slot frees — `max_live` becomes a soft limit instead of
//!   an admission wall.
//!
//! The manager owns every parked cache behind a [`KvHandle`]; device-resident
//! caches stay inside their live session (the established ownership design —
//! the session borrows only the runtime) and return to the manager on park.
//! See DESIGN.md §4 for the handle lifecycle, snapshot format, and
//! prefix-trie invalidation rules.

pub mod prefix;
pub mod snapshot;

use std::collections::BTreeMap;
use std::collections::VecDeque;

use anyhow::{anyhow, Result};

pub use prefix::{PrefixCache, PrefixStats, DEFAULT_MAX_ENTRIES, DEFAULT_MIN_PREFIX};
pub use snapshot::{EngineState, SessionSnapshot, SNAPSHOT_MIN_VERSION,
                   SNAPSHOT_VERSION};

/// Names a parked (host-resident) session cache inside a [`KvManager`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct KvHandle(u64);

/// Point-in-time counters of a [`KvManager`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvStats {
    /// sessions parked (device -> host serializations).
    pub snapshots: u64,
    /// sessions revived (host -> device restores).
    pub restores: u64,
    /// currently parked sessions.
    pub parked: usize,
    /// host bytes held by parked KV images.
    pub parked_bytes: usize,
}

/// Owns parked session snapshots behind handles, in park order (FIFO revive
/// keeps the suspend/resume rotation fair). One manager per worker — the
/// snapshots are host data, so handing one to another worker (or to disk via
/// [`KvManager::save`]) is how sessions migrate.
#[derive(Default)]
pub struct KvManager {
    next: u64,
    parked: BTreeMap<u64, SessionSnapshot>,
    order: VecDeque<u64>,
    snapshots: u64,
    restores: u64,
}

impl KvManager {
    pub fn new() -> KvManager {
        KvManager::default()
    }

    /// Take ownership of a suspended session's snapshot.
    pub fn park(&mut self, snap: SessionSnapshot) -> KvHandle {
        self.next += 1;
        self.snapshots += 1;
        self.parked.insert(self.next, snap);
        self.order.push_back(self.next);
        KvHandle(self.next)
    }

    /// Give a parked snapshot back for resumption. None = unknown handle
    /// (already revived, or never parked here).
    pub fn revive(&mut self, h: KvHandle) -> Option<SessionSnapshot> {
        let snap = self.parked.remove(&h.0)?;
        self.order.retain(|&id| id != h.0);
        self.restores += 1;
        Some(snap)
    }

    /// The longest-parked session (FIFO revive order).
    pub fn oldest(&self) -> Option<KvHandle> {
        self.order.front().map(|&id| KvHandle(id))
    }

    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    pub fn stats(&self) -> KvStats {
        KvStats {
            snapshots: self.snapshots,
            restores: self.restores,
            parked: self.parked.len(),
            parked_bytes: self.parked.values().map(|s| s.kv.bytes()).sum(),
        }
    }

    /// Write a parked snapshot to disk (it stays parked — the file is a
    /// portable copy another process or worker can [`KvManager::load`]).
    pub fn save(&self, h: KvHandle, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.parked
            .get(&h.0)
            .ok_or_else(|| anyhow!("no parked session for {h:?}"))?
            .save(path)
    }

    /// Park a snapshot read from disk (the other end of a migration).
    pub fn load(&mut self, path: impl AsRef<std::path::Path>) -> Result<KvHandle> {
        Ok(self.park(SessionSnapshot::load(path)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::GenParams;
    use crate::metrics::DecodeStats;
    use crate::ngram::PoolHandle;
    use crate::runtime::HostKv;

    fn snap(tag: u8) -> SessionSnapshot {
        SessionSnapshot {
            model: "tiny".into(),
            engine: EngineState::Autoregressive { cur: tag as u32, rng: [1, 2, 3, 4] },
            kv: HostKv { len: 3, elem: "i32".into(), data: vec![tag; 16] },
            draft_kv: None,
            params: GenParams::default(),
            out: vec![tag as u32],
            stats: DecodeStats::default(),
            wall_offset: std::time::Duration::ZERO,
            pool: PoolHandle::none(),
        }
    }

    #[test]
    fn park_revive_fifo_and_counters() {
        let mut kv = KvManager::new();
        let a = kv.park(snap(1));
        let b = kv.park(snap(2));
        assert_eq!(kv.parked_count(), 2);
        assert_eq!(kv.oldest(), Some(a));
        let s = kv.revive(a).unwrap();
        assert_eq!(s.out, vec![1]);
        assert_eq!(kv.oldest(), Some(b));
        assert!(kv.revive(a).is_none(), "double revive must fail");
        let st = kv.stats();
        assert_eq!((st.snapshots, st.restores, st.parked), (2, 1, 1));
        assert_eq!(st.parked_bytes, 16);
    }

    #[test]
    fn save_load_migrates_a_parked_session() {
        let dir = std::env::temp_dir().join(format!("la-kvmgr-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("parked.kvsnap");
        let mut src = KvManager::new();
        let h = src.park(snap(7));
        src.save(h, &path).unwrap();
        // "another worker": a fresh manager loads the file
        let mut dst = KvManager::new();
        let h2 = dst.load(&path).unwrap();
        let s = dst.revive(h2).unwrap();
        assert_eq!(s.out, vec![7]);
        assert_eq!(s.kv.data, vec![7; 16]);
        assert!(src.save(KvHandle(999), &path).is_err());
    }
}
