//! `lookahead` — CLI for the Lookahead Decoding serving stack.
//!
//! Subcommands:
//!   generate   one-shot generation from a prompt
//!   serve      TCP JSON-lines serving front
//!   client     send one request to a running server
//!   inspect    summarize the artifact manifest
//!   lp         lookahead-parallelism simulation report

use anyhow::{bail, Result};

use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::jacobi::Jacobi;
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::engine::spec_decode::SpecDecode;
use lookahead::engine::{Decoder, GenParams, SamplingParams};
use lookahead::layout::Wng;
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::server::{serve_tcp, Policy, ServerConfig};
use lookahead::tokenizer::ByteTokenizer;
use lookahead::util::cli::{usage, Args, Opt};

fn main() -> Result<()> {
    lookahead::util::log::set_from_env();
    let args = Args::parse_env();
    match args.positional().first().map(String::as_str) {
        Some("generate") => cmd_generate(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("lp") => cmd_lp(&args),
        _ => {
            print_usage(&args);
            Ok(())
        }
    }
}

fn print_usage(args: &Args) {
    let opts = [
        Opt { name: "artifacts", default: Some("artifacts"),
              help: "sim | sim-slow | artifact directory (serve resolves the sims)" },
        Opt { name: "model", default: Some("tiny"), help: "model name (tiny/small)" },
        Opt { name: "method", default: Some("lookahead"),
              help: "lookahead|autoregressive|jacobi|spec_decode|prompt_lookup" },
        Opt { name: "wng", default: Some("5,3,5"), help: "lookahead W,N,G" },
        Opt { name: "prompt", default: None, help: "prompt text (generate)" },
        Opt { name: "max-tokens", default: Some("64"), help: "generation budget" },
        Opt { name: "temperature", default: Some("0"), help: "0 = greedy" },
        Opt { name: "addr", default: Some("127.0.0.1:7878"), help: "serve/client address" },
        Opt { name: "workers", default: Some("1"), help: "serving workers" },
        Opt { name: "policy", default: Some("fifo"), help: "fifo | sjf" },
        Opt { name: "share-ngrams", default: Some("true"),
              help: "cross-request shared n-gram cache (serve)" },
        Opt { name: "ngram-ttl-ms", default: None,
              help: "TTL decay for shared n-gram caches (serve)" },
        Opt { name: "time-slice", default: Some("4"),
              help: "decode steps per session per scheduling round (serve)" },
        Opt { name: "max-live", default: Some("4"),
              help: "interleaved sessions per worker (serve)" },
        Opt { name: "batch-decode", default: Some("true"),
              help: "fuse compatible live sessions into one batched \
                     decode call per round (serve)" },
        Opt { name: "kv-budget", default: Some("0"),
              help: "device KV budget per worker: sessions beyond it are \
                     suspended (snapshot+free) and resumed round-robin; \
                     0 = unlimited (serve)" },
        Opt { name: "prefix-cache", default: Some("true"),
              help: "fork cached KV snapshots for requests sharing a \
                     long prompt prefix instead of re-prefilling (serve)" },
        Opt { name: "rebalance", default: Some("false"),
              help: "move parked session snapshots from overloaded to \
                     idle workers (serve; needs workers > 1, pairs with \
                     --kv-budget)" },
        Opt { name: "rebalance-interval-ms", default: Some("50"),
              help: "how often the rebalancer compares per-worker \
                     live+parked depth (serve)" },
        Opt { name: "controller", default: Some("static"),
              help: "static | adaptive — adaptive re-tunes each greedy \
                     session's engine live from observed accept lengths \
                     (serve; requests can override per-request)" },
        Opt { name: "peers", default: None,
              help: "comma-separated peer listener addresses this server \
                     may donate parked sessions to over the wire (serve)" },
        Opt { name: "peer-addr", default: None,
              help: "bind a peer listener here: other servers can hand \
                     sessions off to this one (serve)" },
        Opt { name: "heartbeat-ms", default: Some("100"),
              help: "peer liveness/load probe interval (serve; with --peers)" },
        Opt { name: "prefill-only", default: Some("false"),
              help: "prefill tier: commit prompt KV locally, then ship \
                     every session to a decode peer instead of stepping \
                     it (serve; needs --peers)" },
        Opt { name: "trace", default: Some("false"),
              help: "serve: record span-level timelines (scrape with \
                     client --trace); client: scrape the Chrome trace dump" },
        Opt { name: "trace-sample", default: Some("1"),
              help: "trace every Nth admitted session (serve; 1 = all)" },
        Opt { name: "trace-buf", default: Some("65536"),
              help: "bounded span-ring capacity per lane; overflow drops \
                     the oldest spans and counts them (serve)" },
        Opt { name: "trace-out", default: None,
              help: "write the Chrome trace-event JSON here on clean \
                     exit (serve; pairs with --trace)" },
        Opt { name: "stream", default: Some("false"),
              help: "stream chunk lines before the final record (client)" },
        Opt { name: "report", default: Some("false"),
              help: "scrape the server metrics report as JSON (client)" },
        Opt { name: "metrics-prom", default: Some("false"),
              help: "scrape the server metrics in Prometheus text \
                     exposition format (client)" },
        Opt { name: "devices", default: Some("4"), help: "LP simulated devices" },
    ];
    println!("{}", usage(args.program(),
        "lookahead — Lookahead Decoding (ICML 2024) serving stack.\n\
         COMMANDS: generate | serve | client | inspect | lp", &opts));
}

fn build_engine(args: &Args, manifest: &Manifest, rt: &ModelRuntime)
                -> Result<Box<dyn Decoder>> {
    let (w, n, g) = args.wng("wng", (5, 3, 5));
    Ok(match args.str_or("method", "lookahead").as_str() {
        "lookahead" => Box::new(Lookahead::with_wng(w, n, g)),
        "autoregressive" | "ar" => Box::new(AutoRegressive::new()),
        "jacobi" => Box::new(Jacobi::new(8)),
        "prompt_lookup" => Box::new(PromptLookup::new(8, 1)),
        "spec_decode" => {
            let draft = ModelRuntime::load(&rt.client, manifest, "draft")?;
            Box::new(SpecDecode::new(draft, 4))
        }
        other => bail!("unknown method '{other}'"),
    })
}

fn cmd_generate(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&client, &manifest, &args.str_or("model", "tiny"))?;
    let mut engine = build_engine(args, &manifest, &rt)?;

    let prompt = match args.get("prompt") {
        Some(p) => p.to_string(),
        None => {
            // no prompt: read stdin
            let mut s = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut s)?;
            s
        }
    };
    let tok = ByteTokenizer::new();
    let ids = tok.encode_with_bos(&prompt);
    let params = GenParams {
        max_new_tokens: args.usize_or("max-tokens", 64),
        sampling: SamplingParams {
            temperature: args.f64_or("temperature", 0.0),
            top_k: args.usize_or("top-k", 0),
            top_p: args.f64_or("top-p", 1.0),
        },
        stop_at_eos: true,
        seed: args.u64_or("seed", 0),
    };
    let out = engine.generate(&rt, &ids, &params)?;
    println!("{}", out.text);
    eprintln!(
        "--- {} | {} tokens in {} steps (S = {:.2}) | {:.1} tok/s | pool hit-rate {:.0}%",
        engine.name(),
        out.stats.generated_tokens,
        out.stats.decode_steps,
        out.stats.compression(),
        out.stats.tokens_per_sec(),
        100.0 * out.stats.pool_hits as f64
            / (out.stats.pool_hits + out.stats.pool_misses).max(1) as f64,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // `sim` / `sim-slow` resolve to the generated simulated artifact sets
    // (mirrors serve_bench), so multi-process topologies run without PJRT.
    let artifacts = match args.str_or("artifacts", "artifacts").as_str() {
        "sim" => lookahead::runtime::sim::ensure_sim_artifacts()?
            .to_string_lossy()
            .into_owned(),
        "sim-slow" => lookahead::runtime::sim::ensure_slow_sim_artifacts()?
            .to_string_lossy()
            .into_owned(),
        dir => dir.to_string(),
    };
    let cfg = ServerConfig::builder()
        .workers(args.usize_or("workers", 1))
        .policy(Policy::parse(&args.str_or("policy", "fifo")))
        .queue_depth(args.usize_or("queue-depth", 256))
        .share_ngrams(args.bool_or("share-ngrams", true))
        .ngram_ttl_ms(args.get("ngram-ttl-ms").and_then(|v| v.parse().ok()))
        .batch_decode(args.bool_or("batch-decode", true))
        .rebalance(args.bool_or("rebalance", false))
        .rebalance_interval_ms(args.u64_or("rebalance-interval-ms", 50))
        .artifacts_dir(artifacts)
        .model(args.str_or("model", "tiny"))
        .wng(args.wng("wng", (5, 3, 5)))
        .time_slice(args.usize_or("time-slice", 4))
        .max_live(args.usize_or("max-live", 4))
        .kv_budget(args.usize_or("kv-budget", 0))
        .prefix_cache(args.bool_or("prefix-cache", true))
        .controller(args.str_or("controller", "static"))
        .peers(args.get("peers").map(|p| {
            p.split(',').map(str::trim).filter(|s| !s.is_empty())
                .map(String::from).collect()
        }).unwrap_or_default())
        .peer_addr(args.get("peer-addr").map(String::from))
        .heartbeat_ms(args.u64_or("heartbeat-ms", 100))
        .prefill_only(args.bool_or("prefill-only", false))
        .trace(args.bool_or("trace", false))
        .trace_sample(args.u64_or("trace-sample", 1))
        .trace_buf(args.usize_or("trace-buf", lookahead::trace::DEFAULT_TRACE_BUF))
        .trace_out(args.get("trace-out").map(String::from))
        .build();
    let max_conns = args.get("max-conns").and_then(|v| v.parse().ok());
    serve_tcp(&args.str_or("addr", "127.0.0.1:7878"), cfg, max_conns)
}

fn cmd_client(args: &Args) -> Result<()> {
    use lookahead::util::json::Json;
    let addr = args.str_or("addr", "127.0.0.1:7878");
    // --report: scrape the one-line machine-readable metrics report
    // instead of sending a generation request
    if args.bool_or("report", false) {
        let resp = lookahead::server::client_request(&addr, r#"{"report": true}"#)?;
        println!("{resp}");
        return Ok(());
    }
    // --trace: scrape the server's Chrome trace-event dump (prints the
    // bare trace object, so the output loads straight into Perfetto)
    if args.bool_or("trace", false) {
        let resp = lookahead::server::client_request(&addr, r#"{"trace": true}"#)?;
        let j = Json::parse(&resp)
            .map_err(|e| anyhow::anyhow!("bad trace reply: {e}"))?;
        let trace = j.get("trace").cloned().unwrap_or(Json::Null);
        println!("{}", trace.dump());
        return Ok(());
    }
    // --metrics-prom: scrape the Prometheus text exposition (the reply
    // wraps it in one JSON line; print the decoded inner text)
    if args.bool_or("metrics-prom", false) {
        let resp = lookahead::server::client_request(&addr,
                                                     r#"{"metrics": "prometheus"}"#)?;
        let j = Json::parse(&resp)
            .map_err(|e| anyhow::anyhow!("bad metrics reply: {e}"))?;
        match j.get("metrics_prom").and_then(Json::as_str) {
            Some(text) => print!("{text}"),
            None => println!("{resp}"),
        }
        return Ok(());
    }
    let stream = args.bool_or("stream", false);
    let req = Json::obj(vec![
        ("prompt", Json::str(args.str_or("prompt", "hello"))),
        ("max_tokens", Json::num(args.usize_or("max-tokens", 64) as f64)),
        ("method", Json::str(args.str_or("method", "lookahead"))),
        ("temperature", Json::num(args.f64_or("temperature", 0.0))),
        ("stream", Json::Bool(stream)),
    ]);
    let resp = if stream {
        lookahead::server::client_request_stream(&addr, &req.dump(),
                                                 |chunk| println!("{chunk}"))?
    } else {
        lookahead::server::client_request(&addr, &req.dump())?
    };
    println!("{resp}");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let manifest = Manifest::load(args.str_or("artifacts", "artifacts"))?;
    println!("profile: {}   prefill_len: {}   commit_slots: {}",
             manifest.profile, manifest.prefill_len, manifest.commit_slots);
    for (name, mm) in &manifest.models {
        println!("\nmodel {name}: {} layers, d={}, {} heads, {:.2}M params, \
                  cache {:?} (junk row {})",
                 mm.n_layers, mm.d_model, mm.n_heads, mm.params as f64 / 1e6,
                 mm.cache_shape, mm.junk_row);
        for (ename, spec) in &mm.executables {
            println!("  {:<28} {:?}", ename, spec.kind);
        }
    }
    Ok(())
}

fn cmd_lp(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&client, &manifest, &args.str_or("model", "tiny"))?;
    let (w, n, g) = args.wng("wng", (15, 5, 15));
    let wng = Wng::new(w, n, g);
    let tok = ByteTokenizer::new();
    let ids = tok.encode_with_bos("def warm(cache, token):\n    return cache");
    let (_, cache) = rt.prefill(&ids)?;
    let devices = args.usize_or("devices", 4);
    let s = args.f64_or("s", 2.0);
    let rep = lookahead::lp::simulate(&rt, &cache, wng, devices, s, 5)?;
    println!("LP simulation for {:?} on {} devices (S={s:.2}):", wng, devices);
    for (i, (sh, ms)) in rep.shards.iter().zip(&rep.shard_ms).enumerate() {
        println!("  device {i}: cols {:?} cands {:?} t_in {:>3} -> {:.2} ms",
                 sh.col_range, sh.cand_range, sh.t_in, ms);
    }
    println!("  step = {:.2} ms (comm {:.4} ms) -> {:.1} tok/s",
             rep.step_ms, rep.comm_ms, rep.tokens_per_sec);
    Ok(())
}
