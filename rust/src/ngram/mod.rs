//! The n-gram pool (paper §3.1/§3.2): caches n-grams harvested from the
//! Jacobi trajectory (and optionally the prompt — "prompt as reference",
//! Tab. 3), keyed by first token. Lookup returns up to G candidate suffixes
//! for the verification branch.
//!
//! Two storage strategies implement the [`NgramSource`] trait:
//! [`NgramPool`] (per-request, single-threaded — the paper's setting) and
//! [`shared::SharedNgramCache`] (cross-request, sharded + locked — the
//! serving setting). Engines receive either through a
//! [`shared::PoolHandle`] and cannot tell them apart.

pub mod shared;

pub use shared::{
    NgramCacheRegistry, PoolExport, PoolHandle, PoolSpec, SharedCacheStats,
    SharedNgramCache,
};

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Anything that can store and retrieve n-grams for the verification branch.
///
/// `lookup` takes `&mut self` so single-threaded implementations can keep
/// plain hit/miss counters; concurrent implementations use interior
/// mutability and implement the trait on `Arc<Self>`.
pub trait NgramSource {
    /// n-gram length N (stored suffixes are N-1 tokens).
    fn n(&self) -> usize;

    /// Total stored suffixes.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a full n-gram (length N; other lengths are ignored).
    fn insert(&mut self, ngram: &[u32]);

    /// Up to `max` suffixes whose n-gram starts with `key`, best first.
    fn lookup(&mut self, key: u32, max: usize) -> Vec<Vec<u32>>;

    /// Seed with every n-gram window of `tokens` ("prompt as reference").
    fn seed_from(&mut self, tokens: &[u32]) {
        let n = self.n();
        if tokens.len() < n {
            return;
        }
        for win in tokens.windows(n) {
            self.insert(win);
        }
    }

    /// Export every stored n-gram (key + suffix) for session snapshots,
    /// grouped by key with per-key LRU order preserved oldest-first — so
    /// re-inserting the dump into a fresh pool reproduces every lookup.
    /// `None` = contents are not exportable (shared caches live server-side
    /// and are re-bound, not copied, on resume).
    fn dump(&self) -> Option<Vec<Vec<u32>>> {
        None
    }
}

/// One stored suffix plus its last-touch time (for TTL decay).
#[derive(Debug, Clone)]
struct Stored {
    suffix: Vec<u32>,
    stamp: Instant,
}

#[derive(Debug, Clone)]
pub struct NgramPool {
    /// n-gram length N (suffixes stored are length N-1).
    n: usize,
    /// per-key LRU of suffixes, most recent at the back.
    map: HashMap<u32, VecDeque<Stored>>,
    /// max suffixes retained per key.
    per_key_cap: usize,
    /// entries older than this are evicted on key access (None = keep
    /// forever — the paper's per-request setting). Serving sets it to decay
    /// stale templates out of long-lived shared caches.
    max_age: Option<Duration>,
    /// total suffixes across keys (for the global cap).
    total: usize,
    total_cap: usize,
    pub hits: usize,
    pub misses: usize,
    /// suffixes dropped by either cap (LRU pressure accounting).
    pub evictions: usize,
    /// round-robin eviction cursor over keys when the global cap is hit.
    evict_keys: VecDeque<u32>,
}

impl NgramPool {
    pub fn new(n: usize, per_key_cap: usize, total_cap: usize) -> Self {
        assert!(n >= 2);
        NgramPool {
            n,
            map: HashMap::new(),
            per_key_cap: per_key_cap.max(1),
            max_age: None,
            total: 0,
            total_cap: total_cap.max(1),
            hits: 0,
            misses: 0,
            evictions: 0,
            evict_keys: VecDeque::new(),
        }
    }

    /// Enable TTL decay: entries untouched for longer than `max_age` are
    /// evicted the next time their key shard is accessed.
    pub fn with_max_age(mut self, max_age: Duration) -> Self {
        self.max_age = Some(max_age);
        self
    }

    pub fn set_max_age(&mut self, max_age: Option<Duration>) {
        self.max_age = max_age;
    }

    /// Drop `key`'s expired entries (no-op without a `max_age`).
    fn prune_key(&mut self, key: u32) {
        let Some(ttl) = self.max_age else { return };
        let now = Instant::now();
        if let Some(q) = self.map.get_mut(&key) {
            let before = q.len();
            q.retain(|e| now.duration_since(e.stamp) <= ttl);
            let dropped = before - q.len();
            self.total -= dropped;
            self.evictions += dropped;
            if q.is_empty() {
                // retire the key from the eviction rotation too, or a
                // later re-insert would push a duplicate rotation entry
                // (unbounded growth + unfair multi-slot LRU pressure)
                self.map.remove(&key);
                self.evict_keys.retain(|&k| k != key);
            }
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Insert a full n-gram (length n). Deduplicates per key; refreshes LRU
    /// position (and TTL stamp) on re-insert.
    pub fn insert(&mut self, ngram: &[u32]) {
        if ngram.len() != self.n {
            return;
        }
        let key = ngram[0];
        self.prune_key(key);
        let suffix = ngram[1..].to_vec();
        let stored = Stored { suffix, stamp: Instant::now() };
        match self.map.entry(key) {
            Entry::Occupied(mut e) => {
                let q = e.get_mut();
                if let Some(pos) = q.iter().position(|s| s.suffix == stored.suffix) {
                    // refresh: move to back, restamp
                    q.remove(pos);
                    q.push_back(stored);
                    return;
                }
                q.push_back(stored);
                self.total += 1;
                if q.len() > self.per_key_cap {
                    q.pop_front();
                    self.total -= 1;
                    self.evictions += 1;
                }
            }
            Entry::Vacant(e) => {
                e.insert(VecDeque::from([stored]));
                self.evict_keys.push_back(key);
                self.total += 1;
            }
        }
        self.enforce_total_cap();
    }

    fn enforce_total_cap(&mut self) {
        while self.total > self.total_cap {
            let Some(key) = self.evict_keys.pop_front() else { break };
            if let Some(q) = self.map.get_mut(&key) {
                if q.pop_front().is_some() {
                    self.total -= 1;
                    self.evictions += 1;
                }
                if q.is_empty() {
                    self.map.remove(&key);
                } else {
                    self.evict_keys.push_back(key);
                }
            }
        }
    }

    /// Up to `max` suffixes whose n-gram starts with `key`, most recent first
    /// (recent trajectory n-grams are the best speculations). Expired
    /// entries are evicted before the scan ("checked on shard access").
    pub fn lookup(&mut self, key: u32, max: usize) -> Vec<Vec<u32>> {
        self.prune_key(key);
        match self.map.get(&key) {
            Some(q) if !q.is_empty() => {
                self.hits += 1;
                q.iter().rev().take(max).map(|s| s.suffix.clone()).collect()
            }
            _ => {
                self.misses += 1;
                Vec::new()
            }
        }
    }

    /// Seed the pool with every n-gram window of `tokens` ("prompt as
    /// reference", paper §5.4 configs ③⑥⑨).
    pub fn seed_from(&mut self, tokens: &[u32]) {
        if tokens.len() < self.n {
            return;
        }
        for win in tokens.windows(self.n) {
            self.insert(win);
        }
    }

    pub fn hit_rate(&self) -> f64 {
        crate::metrics::hit_rate(self.hits as u64, self.misses as u64)
    }

    /// Every stored n-gram, keys sorted, per-key LRU order oldest-first
    /// (see [`NgramSource::dump`]). The global eviction rotation is not
    /// captured — irrelevant unless the restored pool is re-filled past its
    /// caps.
    pub fn dump_grams(&self) -> Vec<Vec<u32>> {
        let mut keys: Vec<u32> = self.map.keys().copied().collect();
        keys.sort_unstable();
        let mut out = Vec::with_capacity(self.total);
        for k in keys {
            for s in &self.map[&k] {
                let mut g = Vec::with_capacity(self.n);
                g.push(k);
                g.extend_from_slice(&s.suffix);
                out.push(g);
            }
        }
        out
    }
}

impl NgramSource for NgramPool {
    fn n(&self) -> usize {
        NgramPool::n(self)
    }

    fn len(&self) -> usize {
        NgramPool::len(self)
    }

    fn insert(&mut self, ngram: &[u32]) {
        NgramPool::insert(self, ngram)
    }

    fn lookup(&mut self, key: u32, max: usize) -> Vec<Vec<u32>> {
        NgramPool::lookup(self, key, max)
    }

    fn seed_from(&mut self, tokens: &[u32]) {
        NgramPool::seed_from(self, tokens)
    }

    fn dump(&self) -> Option<Vec<Vec<u32>>> {
        Some(self.dump_grams())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Rng;

    #[test]
    fn insert_and_lookup() {
        let mut p = NgramPool::new(3, 4, 100);
        p.insert(&[1, 2, 3]);
        p.insert(&[1, 4, 5]);
        p.insert(&[2, 9, 9]);
        let got = p.lookup(1, 10);
        assert_eq!(got, vec![vec![4, 5], vec![2, 3]]); // most recent first
        assert_eq!(p.lookup(7, 10), Vec::<Vec<u32>>::new());
        assert_eq!(p.hits, 1);
        assert_eq!(p.misses, 1);
    }

    #[test]
    fn dedup_refreshes_lru() {
        let mut p = NgramPool::new(2, 2, 100);
        p.insert(&[1, 10]);
        p.insert(&[1, 11]);
        p.insert(&[1, 10]); // refresh, not duplicate
        assert_eq!(p.len(), 2);
        assert_eq!(p.lookup(1, 1), vec![vec![10]]);
        p.insert(&[1, 12]); // evicts 11 (oldest)
        let got = p.lookup(1, 10);
        assert!(!got.contains(&vec![11]));
    }

    #[test]
    fn per_key_cap_enforced() {
        let mut p = NgramPool::new(2, 3, 100);
        for i in 0..10 {
            p.insert(&[5, i]);
        }
        assert_eq!(p.lookup(5, 10).len(), 3);
    }

    #[test]
    fn total_cap_enforced() {
        let mut p = NgramPool::new(2, 10, 5);
        for i in 0..20u32 {
            p.insert(&[i, i + 1]);
        }
        assert!(p.len() <= 5);
    }

    #[test]
    fn seed_from_prompt() {
        let mut p = NgramPool::new(3, 8, 100);
        p.seed_from(&[1, 2, 3, 4]);
        assert_eq!(p.lookup(1, 4), vec![vec![2, 3]]);
        assert_eq!(p.lookup(2, 4), vec![vec![3, 4]]);
    }

    #[test]
    fn wrong_length_ignored() {
        let mut p = NgramPool::new(3, 8, 100);
        p.insert(&[1, 2]);
        p.insert(&[1, 2, 3, 4]);
        assert!(p.is_empty());
    }

    #[test]
    fn trait_object_dispatch() {
        let mut p = NgramPool::new(3, 4, 100);
        let src: &mut dyn NgramSource = &mut p;
        assert_eq!(src.n(), 3);
        src.insert(&[1, 2, 3]);
        assert_eq!(src.lookup(1, 4), vec![vec![2, 3]]);
        assert_eq!(src.len(), 1);
        assert!(!src.is_empty());
    }

    #[test]
    fn dump_reproduces_lookups_in_a_fresh_pool() {
        let mut p = NgramPool::new(3, 4, 100);
        p.insert(&[1, 2, 3]);
        p.insert(&[1, 4, 5]);
        p.insert(&[9, 8, 7]);
        let mut q = NgramPool::new(3, 4, 100);
        for g in p.dump_grams() {
            q.insert(&g);
        }
        assert_eq!(q.lookup(1, 8), p.lookup(1, 8), "per-key LRU order lost");
        assert_eq!(q.lookup(9, 8), p.lookup(9, 8));
        assert_eq!(q.len(), p.len());
    }

    #[test]
    fn evictions_counted() {
        let mut p = NgramPool::new(2, 2, 100);
        p.insert(&[1, 10]);
        p.insert(&[1, 11]);
        p.insert(&[1, 12]); // per-key cap evicts 10
        assert_eq!(p.evictions, 1);
        let mut p = NgramPool::new(2, 10, 3);
        for i in 0..6u32 {
            p.insert(&[i, i + 1]);
        }
        assert_eq!(p.evictions, 3); // global cap evicted the overflow
    }

    #[test]
    fn ttl_evicts_stale_entries_on_access() {
        let mut p = NgramPool::new(3, 8, 100).with_max_age(Duration::from_millis(15));
        p.insert(&[1, 2, 3]);
        assert_eq!(p.lookup(1, 4), vec![vec![2, 3]], "fresh entry must survive");
        crate::util::sync::nap(Duration::from_millis(30));
        assert!(p.lookup(1, 4).is_empty(), "stale entry must decay");
        assert_eq!(p.evictions, 1);
        assert!(p.is_empty());
        // re-insert after decay works (key bookkeeping stays consistent)
        p.insert(&[1, 4, 5]);
        assert_eq!(p.lookup(1, 4), vec![vec![4, 5]]);
        // the eviction rotation must not accumulate duplicate key entries
        // across expire/re-learn cycles
        assert_eq!(p.evict_keys.iter().filter(|&&k| k == 1).count(), 1);
    }

    #[test]
    fn ttl_refresh_on_reinsert_keeps_entry_alive() {
        let mut p = NgramPool::new(2, 8, 100).with_max_age(Duration::from_millis(40));
        p.insert(&[7, 8]);
        crate::util::sync::nap(Duration::from_millis(25));
        p.insert(&[7, 8]); // refresh restamps
        crate::util::sync::nap(Duration::from_millis(25));
        assert_eq!(p.lookup(7, 4), vec![vec![8]], "refreshed entry must survive");
    }

    #[test]
    fn prop_pool_invariants() {
        // total == sum over keys; caps always hold; lookup never exceeds max.
        forall(
            150,
            33,
            gen::vec_of(0, 120, |r: &mut Rng| {
                (r.below(8) as u32, r.below(8) as u32, r.below(8) as u32)
            }),
            |grams| {
                let mut p = NgramPool::new(3, 3, 20);
                for &(a, b, c) in grams {
                    p.insert(&[a, b, c]);
                }
                let sum: usize = p.map.values().map(|q| q.len()).sum();
                if sum != p.total {
                    return Err(format!("total {} != sum {}", p.total, sum));
                }
                if p.total > 20 {
                    return Err("total cap violated".into());
                }
                for q in p.map.values() {
                    if q.len() > 3 {
                        return Err("per-key cap violated".into());
                    }
                }
                let mut p2 = p.clone();
                if p2.lookup(3, 2).len() > 2 {
                    return Err("lookup exceeded max".into());
                }
                Ok(())
            },
        );
    }
}
