//! Cross-request shared n-gram cache — the serving-level extension of the
//! paper's per-request pool (§3.1/§3.2, Tab. 3 "prompt as ref").
//!
//! Production traffic is heavily templated: repeated system prompts, shared
//! boilerplate, near-duplicate code completions. A per-request pool re-learns
//! those n-grams from scratch on every call; [`SharedNgramCache`] persists
//! them across requests and across worker threads, so request k+1 starts
//! with the trajectory n-grams harvested by requests 1..k ("warm" start).
//!
//! Exactness: greedy verification (Alg. 3) accepts only tokens the model
//! itself would emit, so *greedy* outputs are byte-identical warm or cold —
//! sharing changes accept length only. Sampling verification (Alg. 4)
//! preserves the output *distribution* with any candidate set, but the
//! per-seed token sequence depends on cache contents; the serving layer
//! therefore defaults sampled requests to private pools (see
//! `Worker::bind_pool_for`).
//!
//! Concurrency: the cache is sharded by first-token key; each shard is an
//! independently locked [`NgramPool`] with its own slice of the global cap.
//! Workers therefore contend only when operating on the same key shard.
//! Counters are lock-free atomics.
//!
//! Ownership: a [`NgramCacheRegistry`] (one per server) hands out one cache
//! per (model, engine kind, n) triple; engines access it through a
//! per-request [`PoolHandle`] that also tracks per-request hit/miss/warm
//! statistics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::metrics::DecodeStats;
use crate::ngram::{NgramPool, NgramSource};
use crate::util::sync::{rank, RankedMutex};

/// Shape of an engine's n-gram pool: n-gram length + LRU capacities.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolSpec {
    /// n-gram length N (keys are 1 token, stored suffixes are N-1).
    pub n: usize,
    /// max suffixes retained per key.
    pub per_key_cap: usize,
    /// global suffix capacity.
    pub total_cap: usize,
    /// engine family the pool belongs to. Part of the registry key:
    /// engines of different kinds with coinciding N must not share a cache
    /// (their harvesting strategies and cap intents differ).
    pub kind: &'static str,
}

impl PoolSpec {
    pub fn new(n: usize, per_key_cap: usize, total_cap: usize) -> PoolSpec {
        PoolSpec {
            n,
            per_key_cap: per_key_cap.max(1),
            total_cap: total_cap.max(1),
            kind: "ngram",
        }
    }

    /// Tag the spec with its engine family (used in the registry key).
    pub fn with_kind(mut self, kind: &'static str) -> PoolSpec {
        self.kind = kind;
        self
    }
}

/// Default shard count: enough to keep worker threads off each other's keys
/// without bloating per-shard cap granularity.
pub const DEFAULT_SHARDS: usize = 16;

/// Aggregate counters of a [`SharedNgramCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SharedCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub entries: usize,
}

impl SharedCacheStats {
    pub fn hit_rate(&self) -> f64 {
        crate::metrics::hit_rate(self.hits, self.misses)
    }
}

/// Thread-safe, sharded, LRU-capped n-gram store shared by all workers
/// serving one model.
pub struct SharedNgramCache {
    spec: PoolSpec,
    /// [`rank::NGRAM_SHARD`]: shards are locked one at a time; the registry
    /// ([`rank::NGRAM_REGISTRY`]) legitimately holds its map while warming a
    /// fresh cache's shards, hence shard > registry.
    shards: Vec<RankedMutex<NgramPool>>,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
}

impl SharedNgramCache {
    pub fn new(spec: PoolSpec, shards: usize) -> SharedNgramCache {
        let shards = shards.max(1);
        let per_shard_cap = spec.total_cap.div_ceil(shards).max(1);
        SharedNgramCache {
            spec,
            shards: (0..shards)
                .map(|_| {
                    RankedMutex::new(
                        rank::NGRAM_SHARD,
                        "ngram.shard",
                        NgramPool::new(spec.n, spec.per_key_cap, per_shard_cap),
                    )
                })
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
        }
    }

    pub fn with_defaults(spec: PoolSpec) -> SharedNgramCache {
        SharedNgramCache::new(spec, DEFAULT_SHARDS)
    }

    /// TTL decay for stale templates: entries untouched for longer than
    /// `max_age` are evicted the next time their shard is accessed (inserts
    /// and lookups both prune). `None` disables decay. Long-lived serving
    /// caches use this so yesterday's templates stop occupying LRU slots.
    pub fn set_max_age(&self, max_age: Option<Duration>) {
        for s in &self.shards {
            s.lock().set_max_age(max_age);
        }
    }

    pub fn spec(&self) -> PoolSpec {
        self.spec
    }

    pub fn n(&self) -> usize {
        self.spec.n
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Fibonacci-hash the key so dense byte-token keys spread over shards.
    fn shard_for(&self, key: u32) -> &RankedMutex<NgramPool> {
        let h = (key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        &self.shards[(h as usize) % self.shards.len()]
    }

    /// Insert one n-gram (length must equal `spec.n`; others are ignored,
    /// matching `NgramPool::insert`).
    pub fn insert(&self, ngram: &[u32]) {
        if ngram.len() != self.spec.n {
            return;
        }
        self.inserts.fetch_add(1, Ordering::Relaxed);
        self.shard_for(ngram[0]).lock().insert(ngram);
    }

    /// Up to `max` suffixes for `key`, most recent first.
    pub fn lookup(&self, key: u32, max: usize) -> Vec<Vec<u32>> {
        let got = self.shard_for(key).lock().lookup(key, max);
        if got.is_empty() {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// Seed with every n-gram window of `tokens` (cross-request
    /// "prompt as reference").
    pub fn seed_from(&self, tokens: &[u32]) {
        if tokens.len() < self.spec.n {
            return;
        }
        for win in tokens.windows(self.spec.n) {
            self.insert(win);
        }
    }

    /// Total stored suffixes (sums shard lengths; a point-in-time value
    /// under concurrent mutation).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> SharedCacheStats {
        let mut entries = 0usize;
        let mut evictions = 0u64;
        for s in &self.shards {
            let p = s.lock();
            entries += p.len();
            evictions += p.evictions as u64;
        }
        SharedCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions,
            entries,
        }
    }
}

impl NgramSource for Arc<SharedNgramCache> {
    fn n(&self) -> usize {
        SharedNgramCache::n(self)
    }

    fn len(&self) -> usize {
        SharedNgramCache::len(self)
    }

    fn insert(&mut self, ngram: &[u32]) {
        SharedNgramCache::insert(self, ngram)
    }

    fn lookup(&mut self, key: u32, max: usize) -> Vec<Vec<u32>> {
        SharedNgramCache::lookup(self, key, max)
    }

    fn seed_from(&mut self, tokens: &[u32]) {
        SharedNgramCache::seed_from(self, tokens)
    }
}

/// Server-level registry: one shared cache per (tenant, model, engine kind,
/// n-gram length). Workers with different models, engine families, or
/// lookahead configs with different N must never cross-pollinate pools, so
/// the key includes all three — and multi-tenant deployments additionally
/// namespace by the request's `tenant` field (n-gram contents leak prompt
/// material, so tenants must never warm each other's caches). Requests
/// without a tenant share the default namespace, preserving the
/// single-tenant behavior.
pub struct NgramCacheRegistry {
    shards: usize,
    /// TTL applied to every cache this registry creates (None = no decay).
    max_age: Option<Duration>,
    /// [`rank::NGRAM_REGISTRY`]: held across first-use cache construction,
    /// which locks the new cache's shards (see `get_or_create_scoped`).
    caches: RankedMutex<HashMap<String, Arc<SharedNgramCache>>>,
}

impl NgramCacheRegistry {
    pub fn new() -> NgramCacheRegistry {
        let caches =
            RankedMutex::new(rank::NGRAM_REGISTRY, "ngram.registry", HashMap::new());
        NgramCacheRegistry { shards: DEFAULT_SHARDS, max_age: None, caches }
    }

    pub fn with_shards(shards: usize) -> NgramCacheRegistry {
        NgramCacheRegistry { shards: shards.max(1), ..NgramCacheRegistry::new() }
    }

    /// Builder: TTL decay for every cache created by this registry
    /// (`ServerConfig::ngram_ttl_ms` lands here).
    pub fn with_max_age(mut self, max_age: Option<Duration>) -> NgramCacheRegistry {
        self.max_age = max_age;
        self
    }

    fn key(tenant: Option<&str>, model: &str, spec: &PoolSpec) -> String {
        format!("{}/{model}:{}:n{}", tenant.unwrap_or("_shared"), spec.kind, spec.n)
    }

    /// The shared cache for `(default tenant, model, spec.kind, spec.n)`,
    /// created on first use. The first caller's capacities win; later specs
    /// with the same key reuse the existing cache (capacity is a
    /// server-level property, not per-request).
    pub fn get_or_create(&self, model: &str, spec: PoolSpec) -> Arc<SharedNgramCache> {
        self.get_or_create_scoped(None, model, spec)
    }

    /// Tenant-scoped variant: `None` is the default shared namespace (the
    /// pre-namespacing behavior); `Some(tenant)` gets a fully isolated
    /// cache per tenant.
    pub fn get_or_create_scoped(&self, tenant: Option<&str>, model: &str,
                                spec: PoolSpec) -> Arc<SharedNgramCache> {
        let mut m = self.caches.lock();
        m.entry(Self::key(tenant, model, &spec))
            .or_insert_with(|| {
                let c = SharedNgramCache::new(spec, self.shards);
                c.set_max_age(self.max_age);
                Arc::new(c)
            })
            .clone()
    }

    /// Snapshot of every cache's counters, sorted by key.
    pub fn stats(&self) -> Vec<(String, SharedCacheStats)> {
        let m = self.caches.lock();
        let mut out: Vec<(String, SharedCacheStats)> =
            m.iter().map(|(k, c)| (k.clone(), c.stats())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Human-readable report for server metrics output.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (key, st) in self.stats() {
            s.push_str(&format!(
                "ngram_cache {key}: entries={} hits={} misses={} hit_rate={:.2} \
                 inserts={} evictions={}\n",
                st.entries, st.hits, st.misses, st.hit_rate(), st.inserts, st.evictions
            ));
        }
        s
    }
}

impl Default for NgramCacheRegistry {
    fn default() -> Self {
        NgramCacheRegistry::new()
    }
}

/// Per-request view of an n-gram store, handed to `Decoder::generate_with_pool`.
///
/// Storage is any [`NgramSource`] behind dynamic dispatch — a private
/// [`NgramPool`] or an `Arc<SharedNgramCache>` — or detached (`None`) for
/// engines that keep no pool. The handle tracks *this request's* hit/miss
/// counts and whether the backing store was already warm when the request
/// started, independent of the store's global counters — so per-request
/// `DecodeStats` stay exact even when many workers share one cache.
pub struct PoolHandle {
    src: Option<Box<dyn NgramSource + Send>>,
    shared: bool,
    /// shape of the backing store, kept for suspend/resume snapshots.
    spec: Option<PoolSpec>,
    /// tenant namespace of a shared backing cache (None = default ns or
    /// not shared), kept so a snapshot restored with a registry re-binds
    /// to the SAME tenant's cache — never the cross-tenant default.
    tenant: Option<String>,
    pub hits: usize,
    pub misses: usize,
    warm_start: bool,
    entries_start: usize,
}

impl PoolHandle {
    fn from_src(src: Option<Box<dyn NgramSource + Send>>, shared: bool,
                spec: Option<PoolSpec>, tenant: Option<String>) -> PoolHandle {
        let entries = src.as_ref().map_or(0, |s| s.len());
        PoolHandle {
            src,
            shared,
            spec,
            tenant,
            hits: 0,
            misses: 0,
            warm_start: entries > 0,
            entries_start: entries,
        }
    }

    /// Detached handle for engines without a pool (AR, Jacobi, spec-decode).
    pub fn none() -> PoolHandle {
        PoolHandle::from_src(None, false, None, None)
    }

    /// Cold per-request pool (the pre-sharing behavior).
    pub fn private(spec: PoolSpec) -> PoolHandle {
        let pool = NgramPool::new(spec.n, spec.per_key_cap, spec.total_cap);
        PoolHandle::from_src(Some(Box::new(pool)), false, Some(spec), None)
    }

    /// Cross-request shared cache (default tenant namespace).
    pub fn shared(cache: Arc<SharedNgramCache>) -> PoolHandle {
        PoolHandle::shared_scoped(cache, None)
    }

    /// Cross-request shared cache bound under a tenant namespace — the
    /// tenant travels with suspend/resume snapshots so a resumed session
    /// re-binds to its own tenant's cache.
    pub fn shared_scoped(cache: Arc<SharedNgramCache>, tenant: Option<String>)
                         -> PoolHandle {
        let spec = cache.spec();
        PoolHandle::from_src(Some(Box::new(cache)), true, Some(spec), tenant)
    }

    /// Build the handle an engine's [`PoolSpec`] asks for (none when the
    /// engine keeps no pool).
    pub fn for_spec(spec: Option<PoolSpec>) -> PoolHandle {
        match spec {
            Some(s) => PoolHandle::private(s),
            None => PoolHandle::none(),
        }
    }

    /// Guarantee a usable pool of n-gram length `spec.n`: engines call this
    /// first so a mismatched or absent handle degrades to a private pool
    /// instead of corrupting a shared cache of different N.
    pub fn ensure(&mut self, spec: PoolSpec) {
        if self.src.as_ref().map(|s| s.n()) != Some(spec.n) {
            *self = PoolHandle::private(spec);
        }
    }

    pub fn is_shared(&self) -> bool {
        self.shared
    }

    pub fn is_attached(&self) -> bool {
        self.src.is_some()
    }

    /// True when the backing store already held n-grams at request start.
    pub fn warm_start(&self) -> bool {
        self.warm_start
    }

    pub fn entries_start(&self) -> usize {
        self.entries_start
    }

    /// Current entry count of the backing store.
    pub fn entries(&self) -> usize {
        self.src.as_ref().map_or(0, |s| s.len())
    }

    pub fn lookup(&mut self, key: u32, max: usize) -> Vec<Vec<u32>> {
        let got = match &mut self.src {
            Some(s) => s.lookup(key, max),
            None => Vec::new(),
        };
        if got.is_empty() {
            self.misses += 1;
        } else {
            self.hits += 1;
        }
        got
    }

    pub fn insert(&mut self, ngram: &[u32]) {
        if let Some(s) = &mut self.src {
            s.insert(ngram);
        }
    }

    pub fn seed_from(&mut self, tokens: &[u32]) {
        if let Some(s) = &mut self.src {
            s.seed_from(tokens);
        }
    }

    /// Serialize this handle for a session snapshot. Private pools export
    /// their full contents; shared caches export only their shape (the
    /// contents live server-side — [`PoolExport::restore`] re-binds or
    /// degrades, see there).
    pub fn export(&self) -> PoolExport {
        PoolExport {
            spec: self.spec.map(|s| {
                (s.n, s.per_key_cap, s.total_cap, s.kind.to_string())
            }),
            shared: self.shared,
            tenant: self.tenant.clone(),
            entries: self.src.as_ref().and_then(|s| s.dump()).unwrap_or_default(),
            hits: self.hits,
            misses: self.misses,
            warm_start: self.warm_start,
            entries_start: self.entries_start,
        }
    }

    /// Fold this request's pool accounting into its `DecodeStats`.
    /// Hit/miss counts are additive so engines that also count non-pool
    /// speculation sources (e.g. prompt-lookup's history scan) keep both.
    pub fn fill_stats(&self, stats: &mut DecodeStats) {
        stats.pool_hits += self.hits;
        stats.pool_misses += self.misses;
        stats.pool_shared = self.is_shared();
        stats.pool_warm_start = self.warm_start;
        stats.pool_entries_start = self.entries_start;
        stats.pool_entries_end = self.entries();
    }
}

/// Serialized form of a [`PoolHandle`] inside a session snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolExport {
    /// (n, per_key_cap, total_cap, kind) of the backing store.
    pub spec: Option<(usize, usize, usize, String)>,
    pub shared: bool,
    /// tenant namespace of a shared backing cache.
    pub tenant: Option<String>,
    /// private-pool contents ([`NgramSource::dump`] order); empty for
    /// shared/detached handles.
    pub entries: Vec<Vec<u32>>,
    pub hits: usize,
    pub misses: usize,
    pub warm_start: bool,
    pub entries_start: usize,
}

/// Map a serialized kind tag back to the engine-family statics the registry
/// keys on (unknown tags degrade to the generic family).
fn static_kind(kind: &str) -> &'static str {
    match kind {
        "lookahead" => "lookahead",
        "prompt_lookup" => "prompt_lookup",
        _ => "ngram",
    }
}

impl PoolExport {
    /// Rebuild a live handle. A shared export re-binds to `registry`'s
    /// cache for `model` — under the export's tenant namespace, so a
    /// tenant-scoped session never resumes onto the cross-tenant default —
    /// when one is provided (in-server resume — the contents were never
    /// copied); without a registry it degrades to a private pool holding
    /// the exported entries (exact for private pools, cold for shared ones
    /// — pool contents affect speed, never bytes). The per-request
    /// counters are restored either way so resumed-session stats match an
    /// uninterrupted run.
    pub fn restore(self, registry: Option<(&NgramCacheRegistry, &str)>) -> PoolHandle {
        let spec = self
            .spec
            .map(|(n, pk, tot, kind)| PoolSpec::new(n, pk, tot).with_kind(static_kind(&kind)));
        let mut h = match (self.shared, spec, registry) {
            (true, Some(s), Some((reg, model))) => {
                let cache = reg.get_or_create_scoped(self.tenant.as_deref(), model, s);
                PoolHandle::shared_scoped(cache, self.tenant.clone())
            }
            (_, Some(s), _) => {
                let mut h = PoolHandle::private(s);
                for g in &self.entries {
                    h.insert(g);
                }
                h
            }
            _ => PoolHandle::none(),
        };
        h.hits = self.hits;
        h.misses = self.misses;
        h.warm_start = self.warm_start;
        h.entries_start = self.entries_start;
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> PoolSpec {
        PoolSpec::new(3, 4, 64)
    }

    #[test]
    fn insert_lookup_roundtrip() {
        let c = SharedNgramCache::new(spec(), 4);
        c.insert(&[1, 2, 3]);
        c.insert(&[1, 4, 5]);
        assert_eq!(c.lookup(1, 8), vec![vec![4, 5], vec![2, 3]]);
        assert!(c.lookup(9, 8).is_empty());
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.inserts), (1, 1, 2));
        assert_eq!(st.entries, 2);
    }

    #[test]
    fn wrong_length_ignored() {
        let c = SharedNgramCache::new(spec(), 2);
        c.insert(&[1, 2]);
        c.insert(&[1, 2, 3, 4]);
        assert!(c.is_empty());
        assert_eq!(c.stats().inserts, 0);
    }

    #[test]
    fn global_cap_respected_across_shards() {
        let c = SharedNgramCache::new(PoolSpec::new(2, 64, 32), 4);
        for i in 0..500u32 {
            c.insert(&[i, i + 1]);
        }
        // per-shard cap is ceil(32/4) = 8 -> at most 32 total
        assert!(c.len() <= 32, "len {}", c.len());
        assert!(c.stats().evictions > 0);
    }

    #[test]
    fn seed_from_prompt_windows() {
        let c = SharedNgramCache::with_defaults(spec());
        c.seed_from(&[1, 2, 3, 4]);
        assert_eq!(c.lookup(1, 4), vec![vec![2, 3]]);
        assert_eq!(c.lookup(2, 4), vec![vec![3, 4]]);
    }

    #[test]
    fn shared_cache_ttl_decays_stale_templates() {
        let c = SharedNgramCache::new(spec(), 4);
        c.set_max_age(Some(Duration::from_millis(15)));
        c.insert(&[1, 2, 3]);
        assert_eq!(c.lookup(1, 4), vec![vec![2, 3]], "fresh entry must survive");
        crate::util::sync::nap(Duration::from_millis(30));
        assert!(c.lookup(1, 4).is_empty(), "stale template must decay");
        let st = c.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.entries, 0);
    }

    #[test]
    fn registry_applies_ttl_to_created_caches() {
        let reg = NgramCacheRegistry::with_shards(2)
            .with_max_age(Some(Duration::from_millis(10)));
        let c = reg.get_or_create("tiny", spec());
        c.insert(&[1, 2, 3]);
        crate::util::sync::nap(Duration::from_millis(25));
        assert!(c.lookup(1, 4).is_empty(), "registry-created cache must decay");

        let no_ttl = NgramCacheRegistry::with_shards(2).get_or_create("tiny", spec());
        no_ttl.insert(&[1, 2, 3]);
        crate::util::sync::nap(Duration::from_millis(25));
        assert_eq!(no_ttl.lookup(1, 4), vec![vec![2, 3]], "no TTL -> no decay");
    }

    #[test]
    fn handle_tracks_per_request_stats() {
        let c = Arc::new(SharedNgramCache::with_defaults(spec()));
        let mut h1 = PoolHandle::shared(c.clone());
        assert!(!h1.warm_start());
        h1.insert(&[7, 8, 9]);

        // a second request sees the first request's n-grams: warm start
        let mut h2 = PoolHandle::shared(c.clone());
        assert!(h2.warm_start());
        assert_eq!(h2.entries_start(), 1);
        assert_eq!(h2.lookup(7, 4), vec![vec![8, 9]]);
        assert!(h2.lookup(1, 4).is_empty());
        assert_eq!((h2.hits, h2.misses), (1, 1));
        // h1's counters are untouched by h2's traffic
        assert_eq!((h1.hits, h1.misses), (0, 0));
    }

    #[test]
    fn handle_ensure_replaces_mismatched_backend() {
        let c = Arc::new(SharedNgramCache::with_defaults(PoolSpec::new(5, 4, 64)));
        let mut h = PoolHandle::shared(c);
        h.ensure(PoolSpec::new(3, 4, 64)); // engine wants n=3, cache is n=5
        assert!(!h.is_shared());
        h.insert(&[1, 2, 3]);
        assert_eq!(h.lookup(1, 4), vec![vec![2, 3]]);

        let mut none = PoolHandle::none();
        none.ensure(PoolSpec::new(3, 4, 64));
        assert!(none.is_attached());
    }

    #[test]
    fn registry_keys_by_model_kind_and_n() {
        let reg = NgramCacheRegistry::new();
        let a = reg.get_or_create("tiny", PoolSpec::new(3, 4, 64));
        let b = reg.get_or_create("tiny", PoolSpec::new(3, 8, 128));
        let c = reg.get_or_create("tiny", PoolSpec::new(5, 4, 64));
        let d = reg.get_or_create("small", PoolSpec::new(3, 4, 64));
        let e = reg.get_or_create("tiny", PoolSpec::new(3, 4, 64).with_kind("pl"));
        assert!(Arc::ptr_eq(&a, &b), "same (model, kind, n) must share");
        assert!(!Arc::ptr_eq(&a, &c), "different n must not share");
        assert!(!Arc::ptr_eq(&a, &d), "different model must not share");
        assert!(!Arc::ptr_eq(&a, &e), "different engine kind must not share");
        assert!(reg.report().contains("tiny:ngram:n3"));
    }

    #[test]
    fn registry_namespaces_by_tenant() {
        let reg = NgramCacheRegistry::new();
        let shared = reg.get_or_create("tiny", spec());
        let default_ns = reg.get_or_create_scoped(None, "tiny", spec());
        let a = reg.get_or_create_scoped(Some("acme"), "tiny", spec());
        let a2 = reg.get_or_create_scoped(Some("acme"), "tiny", spec());
        let b = reg.get_or_create_scoped(Some("globex"), "tiny", spec());
        assert!(Arc::ptr_eq(&shared, &default_ns),
                "no tenant must mean the default shared namespace");
        assert!(Arc::ptr_eq(&a, &a2), "same tenant must share");
        assert!(!Arc::ptr_eq(&a, &b), "different tenants must not share");
        assert!(!Arc::ptr_eq(&a, &shared), "tenants must not see the default ns");
        // isolation is real, not just pointer identity
        a.insert(&[1, 2, 3]);
        assert!(b.lookup(1, 4).is_empty());
        assert!(shared.lookup(1, 4).is_empty());
        assert!(reg.report().contains("acme/tiny:ngram:n3"));
        assert!(reg.report().contains("_shared/tiny:ngram:n3"));
    }

    #[test]
    fn export_restore_private_pool_is_exact() {
        let mut h = PoolHandle::private(spec());
        h.insert(&[1, 2, 3]);
        h.insert(&[1, 4, 5]);
        assert_eq!(h.lookup(1, 8).len(), 2); // hits = 1
        let _ = h.lookup(9, 8); // misses = 1
        let ex = h.export();
        assert!(!ex.shared);
        assert_eq!(ex.entries.len(), 2);
        let mut r = ex.restore(None);
        assert_eq!(r.lookup(1, 8), h.lookup(1, 8), "restored lookups diverged");
        // counters restored from the export, then advanced by the line above
        assert_eq!((r.hits, r.misses), (2, 1));
        assert!(!r.is_shared());
    }

    #[test]
    fn export_restore_shared_rebinds_or_degrades() {
        let reg = NgramCacheRegistry::new();
        let c = reg.get_or_create("tiny", spec());
        c.insert(&[7, 8, 9]);
        let mut h = PoolHandle::shared(c);
        assert_eq!(h.lookup(7, 4), vec![vec![8, 9]]);
        let ex = h.export();
        assert!(ex.shared && ex.entries.is_empty(), "shared contents stay server-side");
        // with a registry: re-binds to the live cache (contents visible)
        let mut rebound = ex.clone().restore(Some((&reg, "tiny")));
        assert!(rebound.is_shared());
        assert_eq!(rebound.lookup(7, 4), vec![vec![8, 9]]);
        assert_eq!(rebound.hits, 2, "exported counter + this lookup");
        // without a registry: degrades to a cold private pool, counters kept
        let mut cold = ex.restore(None);
        assert!(!cold.is_shared());
        assert_eq!((cold.hits, cold.misses), (1, 0));
        assert!(cold.lookup(7, 4).is_empty());
    }

    #[test]
    fn export_restore_preserves_tenant_namespace() {
        let reg = NgramCacheRegistry::new();
        let acme = reg.get_or_create_scoped(Some("acme"), "tiny", spec());
        acme.insert(&[7, 8, 9]);
        let h = PoolHandle::shared_scoped(acme, Some("acme".into()));
        let ex = h.export();
        assert_eq!(ex.tenant.as_deref(), Some("acme"));
        // restored with a registry: binds back to acme's cache, NOT the
        // cross-tenant default namespace
        let mut r = ex.restore(Some((&reg, "tiny")));
        assert_eq!(r.lookup(7, 4), vec![vec![8, 9]], "must rebind to acme's cache");
        let shared_ns = reg.get_or_create("tiny", spec());
        assert!(shared_ns.lookup(7, 4).is_empty(),
                "default namespace must stay unwarmed by acme's session");
    }

    #[test]
    fn concurrent_inserts_and_lookups() {
        let c = Arc::new(SharedNgramCache::new(PoolSpec::new(3, 8, 256), 8));
        let mut joins = Vec::new();
        for t in 0..8u32 {
            let c = c.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..2_000u32 {
                    let k = (t * 31 + i) % 97;
                    c.insert(&[k, i % 17, (i + t) % 13]);
                    let _ = c.lookup(i % 97, 4);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let st = c.stats();
        assert_eq!(st.inserts, 16_000);
        assert_eq!(st.hits + st.misses, 16_000);
        assert!(c.len() <= 256, "global cap violated: {}", c.len());
    }
}
