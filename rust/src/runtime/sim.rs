//! Simulated artifact set for the vendored `xla` stub's deterministic
//! backend — the test substrate for everything downstream of the runtime.
//!
//! [`write_sim_artifacts`] emits a self-contained artifact directory
//! (manifest.json + `sim` directive files + a `SIM` magic weights file)
//! that [`crate::runtime::Manifest::load`] / [`crate::runtime::ModelRuntime`]
//! consume exactly like AOT-lowered artifacts, but which the stub can
//! *execute*: the stub implements a deterministic causal LM over token ids
//! (see `rust/vendor/xla/src/lib.rs` for the model), so engines, sessions,
//! batched rounds, and the serving front all run for real — without PJRT.
//!
//! The sim model set mirrors the real profile's surface:
//!   - `tiny` and `draft` models (the sim LM is weight-free, so the draft
//!     agrees with the target — spec-decode accepts aggressively);
//!   - `prefill` (64 tokens), `decode_lin_{1,5,8}`, `decode_gen_{20,64}`,
//!     `commit_{1,5,8,20,64}`;
//!   - batched variants `decode_lin_1_b8` and `decode_gen_20_b8`
//!     (`kind: "decode_batch"`), sized for the default lookahead config
//!     W=5, N=3, G=5 (t_in = 20) and up to 8 fused sessions;
//!   - a `cache_io` executable (`kind: "cache_io"`) — the device<->host
//!     KV serialization hook the `kv` subsystem (snapshot/restore, prefix
//!     reuse, session suspend/resume) builds on.
//!
//! No specialized `decode_la` executable is included: the lookahead engine
//! falls back to the generic mask-as-input path, which is the layout the
//! batched executables fuse.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Rows in the sim KV cache (= max_seq; junk row is the last one).
pub const SIM_ROWS: usize = 256;
/// Prefill capacity of the sim artifacts.
pub const SIM_PREFILL_LEN: usize = 64;
/// Max fused sessions per batched executable.
pub const SIM_MAX_BATCH: usize = 8;

const VOCAB: usize = 264;
const WEIGHTS: usize = 2;

/// Version tag baked into the `ensure_*` directory names. Bump whenever
/// the sim format changes (directive grammar, LM constants, executable
/// set, manifest layout): the pid-keyed temp dirs survive process exit,
/// and PID reuse must never pick up a stale-format artifact set —
/// same-version content is byte-identical, so reuse of a completed dir is
/// safe (manifest.json is written last, marking completion).
const SIM_FORMAT: u32 = 2;

fn exe_files(delay_ms: u64) -> Vec<(&'static str, String)> {
    let w = WEIGHTS;
    // decode executables carry the per-launch delay (one sleep per fused
    // call); prefill/commit stay instant
    let d = if delay_ms > 0 { format!(" delay_ms={delay_ms}") } else { String::new() };
    vec![
        ("sim_prefill.hlo.txt",
         format!("sim prefill plen={SIM_PREFILL_LEN} rows={SIM_ROWS} vocab={VOCAB} weights={w}")),
        ("sim_decode_lin_1.hlo.txt", format!("sim decode_lin k=1 vocab={VOCAB} weights={w}{d}")),
        ("sim_decode_lin_5.hlo.txt", format!("sim decode_lin k=5 vocab={VOCAB} weights={w}{d}")),
        ("sim_decode_lin_8.hlo.txt", format!("sim decode_lin k=8 vocab={VOCAB} weights={w}{d}")),
        ("sim_decode_gen_20.hlo.txt", format!("sim decode_gen t_pad=20 vocab={VOCAB} weights={w}{d}")),
        ("sim_decode_gen_64.hlo.txt", format!("sim decode_gen t_pad=64 vocab={VOCAB} weights={w}{d}")),
        ("sim_decode_lin_1_b8.hlo.txt",
         format!("sim decode_lin_b k=1 batch={SIM_MAX_BATCH} vocab={VOCAB} weights={w}{d}")),
        ("sim_decode_gen_20_b8.hlo.txt",
         format!("sim decode_gen_b t_pad=20 batch={SIM_MAX_BATCH} vocab={VOCAB} weights={w}{d}")),
        ("sim_commit.hlo.txt", "sim commit slots=8".to_string()),
        ("sim_cache_io.hlo.txt", format!("sim cache_io rows={SIM_ROWS}")),
    ]
}

fn executables_json() -> String {
    let mut entries = vec![
        format!(r#""prefill": {{"file":"sim_prefill.hlo.txt","kind":"prefill","prompt_len":{SIM_PREFILL_LEN}}}"#),
    ];
    for k in [1usize, 5, 8] {
        entries.push(format!(
            r#""decode_lin_{k}": {{"file":"sim_decode_lin_{k}.hlo.txt","kind":"decode_lin","k":{k}}}"#));
    }
    for t in [20usize, 64] {
        entries.push(format!(
            r#""decode_gen_{t}": {{"file":"sim_decode_gen_{t}.hlo.txt","kind":"decode_gen","t_pad":{t}}}"#));
    }
    for t in [1usize, 5, 8, 20, 64] {
        entries.push(format!(
            r#""commit_{t}": {{"file":"sim_commit.hlo.txt","kind":"commit","t_in":{t},"slots":8}}"#));
    }
    entries.push(format!(
        r#""decode_lin_1_b8": {{"file":"sim_decode_lin_1_b8.hlo.txt","kind":"decode_batch","of":"decode_lin_1","batch":{SIM_MAX_BATCH}}}"#));
    entries.push(format!(
        r#""decode_gen_20_b8": {{"file":"sim_decode_gen_20_b8.hlo.txt","kind":"decode_batch","of":"decode_gen_20","batch":{SIM_MAX_BATCH}}}"#));
    entries.push(r#""cache_io": {"file":"sim_cache_io.hlo.txt","kind":"cache_io"}"#.to_string());
    entries.join(",\n        ")
}

fn model_json(name: &str) -> String {
    let rows = SIM_ROWS;
    let exes = executables_json();
    format!(
        r#""{name}": {{
      "config": {{"name":"{name}","n_layers":2,"d_model":64,"n_heads":4,
                 "n_kv_heads":4,"head_dim":16,"max_seq":{rows},"params":100000}},
      "weights_file": "weights_sim.npz",
      "weight_names": ["embed","final_norm"],
      "weight_shapes": [[{VOCAB},64],[64]],
      "cache_shape": [2,2,{rows},64],
      "junk_row": {junk},
      "executables": {{
        {exes}
      }}
    }}"#,
        junk = rows - 1,
    )
}

/// Write the simulated artifact directory (idempotent: existing files are
/// overwritten with identical content).
pub fn write_sim_artifacts(dir: impl AsRef<Path>) -> Result<()> {
    write_sim_artifacts_with(dir, 0)
}

/// Like [`write_sim_artifacts`], with every decode launch sleeping
/// `delay_ms` — token streams are identical to the instant variant; only
/// wall-clock changes. Serving tests use this to make cancellation and
/// grouping windows deterministic.
pub fn write_sim_artifacts_with(dir: impl AsRef<Path>, delay_ms: u64) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    for (name, text) in exe_files(delay_ms) {
        std::fs::write(dir.join(name), text).with_context(|| format!("writing {name}"))?;
    }
    std::fs::write(dir.join("weights_sim.npz"), b"SIMWEIGHTS")
        .context("writing sim weights")?;
    let manifest = format!(
        r#"{{
  "profile": "sim",
  "prefill_len": {SIM_PREFILL_LEN},
  "commit_slots": 8,
  "vocab": {{"size": 259, "padded": {VOCAB}, "pad": 256, "bos": 257, "eos": 258}},
  "models": {{
    {tiny},
    {draft}
  }}
}}"#,
        tiny = model_json("tiny"),
        draft = model_json("draft"),
    );
    std::fs::write(dir.join("manifest.json"), manifest).context("writing sim manifest")?;
    Ok(())
}

/// Serializes the check-then-write in the `ensure_*` helpers: parallel
/// test threads must not interleave a `Manifest::load` with a concurrent
/// (re)write of manifest.json. Directories are pid-keyed, so in-process
/// exclusion is sufficient; manifest.json is also written last, after
/// every file it references.
/// [`rank::SETUP`]: held across artifact writing, which may touch any other
/// subsystem lock transitively — so it sits below every serving rank.
static ENSURE_LOCK: crate::util::sync::RankedMutex<()> =
    crate::util::sync::RankedMutex::new(crate::util::sync::rank::SETUP, "sim.ensure", ());

/// Write (once per process) and return the shared sim artifact directory.
/// Integration tests use this to exercise the full runtime/engine/serving
/// stack without PJRT or `make artifacts`.
pub fn ensure_sim_artifacts() -> Result<PathBuf> {
    let _g = ENSURE_LOCK.lock();
    let dir = std::env::temp_dir()
        .join(format!("la-sim-artifacts-v{SIM_FORMAT}-{}", std::process::id()));
    if !dir.join("manifest.json").exists() {
        write_sim_artifacts(&dir)?;
    }
    Ok(dir)
}

/// Slow-decode sibling of [`ensure_sim_artifacts`] (identical token
/// streams, ~`5ms` per decode launch) for timing-sensitive serving tests.
pub fn ensure_slow_sim_artifacts() -> Result<PathBuf> {
    let _g = ENSURE_LOCK.lock();
    let dir = std::env::temp_dir()
        .join(format!("la-sim-artifacts-v{SIM_FORMAT}-slow-{}", std::process::id()));
    if !dir.join("manifest.json").exists() {
        write_sim_artifacts_with(&dir, 5)?;
    }
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{cpu_client, Manifest, ModelRuntime};

    #[test]
    fn sim_artifacts_load_and_execute() {
        let dir = ensure_sim_artifacts().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        assert_eq!(manifest.profile, "sim");
        assert_eq!(manifest.prefill_len, SIM_PREFILL_LEN);
        let tiny = manifest.model("tiny").unwrap();
        assert_eq!(tiny.capacity(), SIM_ROWS - 1);
        assert_eq!(tiny.find_batched("decode_lin_1", 3),
                   Some(("decode_lin_1_b8", SIM_MAX_BATCH)));

        let client = cpu_client().unwrap();
        let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
        let prompt: Vec<u32> = vec![257, 10, 11, 12];
        let (_, cache) = rt.prefill(&prompt).unwrap();
        assert_eq!(cache.len, 3);
        let step = rt.decode("decode_lin_1", &cache, &[12]).unwrap();
        let next = step.logits.argmax(0, 259);
        // deterministic: same call, same answer
        let step2 = rt.decode("decode_lin_1", &cache, &[12]).unwrap();
        assert_eq!(next, step2.logits.argmax(0, 259));
        // commit advances the cache
        let cache = rt.commit(cache, &step.new_kv, 1, &[0], 1).unwrap();
        assert_eq!(cache.len, 4);
    }

    #[test]
    fn sim_batched_decode_matches_sequential() {
        let dir = ensure_sim_artifacts().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let client = cpu_client().unwrap();
        let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();

        let (_, ca) = rt.prefill(&[257, 1, 2, 3]).unwrap();
        let (_, cb) = rt.prefill(&[257, 9]).unwrap();
        let sa = rt.decode("decode_lin_1", &ca, &[3]).unwrap();
        let sb = rt.decode("decode_lin_1", &cb, &[9]).unwrap();

        let fused = rt
            .decode_batched("decode_lin_1", &[&ca, &cb], &[&[3], &[9]])
            .unwrap();
        assert_eq!(fused.len(), 2);
        assert_eq!(fused[0].logits.data, sa.logits.data, "slot 0 diverged");
        assert_eq!(fused[1].logits.data, sb.logits.data, "slot 1 diverged");

        // the per-slot new_kv commits identically to the sequential one
        let c_seq = rt.commit(ca, &sa.new_kv, 1, &[0], 1).unwrap();
        let (_, ca2) = rt.prefill(&[257, 1, 2, 3]).unwrap();
        let c_fused = rt.commit(ca2, &fused[0].new_kv, 1, &[0], 1).unwrap();
        let after_seq = rt.decode("decode_lin_1", &c_seq, &[0]).unwrap();
        let after_fused = rt.decode("decode_lin_1", &c_fused, &[0]).unwrap();
        assert_eq!(after_seq.logits.data, after_fused.logits.data);
    }

    #[test]
    fn cache_io_roundtrip_preserves_decode_state() {
        let dir = ensure_sim_artifacts().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let client = cpu_client().unwrap();
        let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
        assert!(rt.supports_cache_io());

        let (_, cache) = rt.prefill(&[257, 10, 11, 12]).unwrap();
        let host = rt.cache_to_host(&cache).unwrap();
        assert_eq!(host.len, 3);
        assert_eq!(host.elem, "i32");
        // prompt-proportional: committed rows + the current-token row, not
        // the full cache capacity
        assert_eq!(host.data.len(), (cache.len + 1) * 4);

        let restored = rt.cache_from_host(&host).unwrap();
        assert_eq!(restored.len, 3);
        let a = rt.decode("decode_lin_1", &cache, &[12]).unwrap();
        let b = rt.decode("decode_lin_1", &restored, &[12]).unwrap();
        assert_eq!(a.logits.data, b.logits.data, "restored cache diverged");

        // restore is a fresh buffer: committing to one leaves the other alone
        let restored = rt.commit(restored, &b.new_kv, 1, &[0], 1).unwrap();
        assert_eq!(restored.len, 4);
        let c = rt.decode("decode_lin_1", &cache, &[12]).unwrap();
        assert_eq!(a.logits.data, c.logits.data, "donor cache was mutated");
    }

    #[test]
    fn commit_overflow_error_is_typed() {
        let dir = ensure_sim_artifacts().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let client = cpu_client().unwrap();
        let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
        let (_, mut cache) = rt.prefill(&[257, 1]).unwrap();
        let step = rt.decode("decode_lin_1", &cache, &[1]).unwrap();
        cache.len = SIM_ROWS - 1; // == capacity: one more committed row overflows
        let err = rt.commit(cache, &step.new_kv, 1, &[0], 1).unwrap_err();
        let overflow = err.downcast_ref::<crate::runtime::model::CacheOverflow>();
        assert!(overflow.is_some(), "commit overflow must be the typed error: {err}");
        assert_eq!(overflow.unwrap().capacity, SIM_ROWS - 1);
    }

    #[test]
    fn missing_batched_exe_is_an_error() {
        let dir = ensure_sim_artifacts().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let client = cpu_client().unwrap();
        let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
        let (_, c) = rt.prefill(&[257, 1]).unwrap();
        let w = [1u32; 8];
        // decode_lin_8 has no batched variant
        assert!(rt.decode_batched("decode_lin_8", &[&c], &[&w[..]]).is_err());
        assert_eq!(rt.max_batch("decode_lin_8"), None);
        assert_eq!(rt.max_batch("decode_lin_1"), Some(SIM_MAX_BATCH));
    }
}
