//! PJRT runtime layer: load `artifacts/` (manifest + HLO text + npz weights),
//! compile once per executable, and run steps with device-resident state.

pub mod manifest;
pub mod model;
pub mod sim;

use anyhow::Result;

pub use manifest::{ExeKind, Manifest, ModelManifest};
pub use model::{Cache, CacheOverflow, HostKv, Logits, ModelRuntime, StepOut};

/// Create the PJRT CPU client (one per thread/device — the client is not
/// Send; lookahead-parallel workers each build their own).
pub fn cpu_client() -> Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}

/// Convenience: manifest + client + model runtime in one call.
pub fn load_model(artifacts_dir: &str, model: &str) -> Result<(Manifest, ModelRuntime)> {
    let manifest = Manifest::load(artifacts_dir)?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&client, &manifest, model)?;
    Ok((manifest, rt))
}
