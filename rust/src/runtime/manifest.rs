//! Typed view of `artifacts/manifest.json` — the binding contract emitted by
//! `python/compile/aot.py`. Executable parameter order is positional:
//!
//!   prefill:     weights.. , tokens i32[P], n_valid i32
//!   decode_la:   weights.. , cache, cache_len i32, tokens i32[T]
//!   decode_lin:  weights.. , cache, cache_len i32, tokens i32[K]
//!   decode_gen:  weights.. , cache, cache_len i32, tokens i32[T],
//!                relpos i32[T], mask u8[T,T]
//!   commit:      cache, new_kv, src_idx i32[slots], dest_start i32, count i32
//!   cache_io:    cache -> raw rows   |   raw rows -> cache
//!                (device<->host KV serialization for the `kv` subsystem:
//!                one executable, direction decided by the argument — see
//!                `ModelRuntime::cache_to_host` / `cache_from_host`)
//!
//! Batched decode executables (`kind: "decode_batch"`) fuse up to `batch`
//! sessions of a base decode executable (`of`) into one call:
//!
//!   decode_batch(of=decode_lin_*): weights.. , cache_0..cache_{B-1},
//!                cache_lens i32[B], tokens i32[B,T]
//!   decode_batch(of=decode_gen_*): weights.. , cache_0..cache_{B-1},
//!                cache_lens i32[B], tokens i32[B,T], relpos i32[T],
//!                mask u8[T,T]   (relpos/mask shared across the batch —
//!                batched groups always share one engine config)
//!
//! Outputs: logits f32[B*T, vocab] followed by one new_kv per slot.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub profile: String,
    pub prefill_len: usize,
    pub commit_slots: usize,
    pub vocab_size: usize,
    pub vocab_padded: usize,
    pub pad_id: u32,
    pub bos_id: u32,
    pub eos_id: u32,
    pub models: BTreeMap<String, ModelManifest>,
    pub dir: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub params: usize,
    pub weights_file: String,
    pub weight_names: Vec<String>,
    pub weight_shapes: Vec<Vec<usize>>,
    /// [L, 2, S, Hk*D]
    pub cache_shape: [usize; 4],
    pub junk_row: usize,
    pub executables: BTreeMap<String, ExeSpec>,
}

#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub file: String,
    pub kind: ExeKind,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExeKind {
    Prefill { prompt_len: usize },
    DecodeLa { w: usize, n: usize, g: usize, t_in: usize, attn: String },
    DecodeLin { k: usize },
    DecodeGen { t_pad: usize },
    /// Batched variant of the base decode executable `of`: up to `batch`
    /// fused (cache, token-window) slots per call.
    DecodeBatch { of: String, batch: usize },
    Commit { t_in: usize, slots: usize },
    /// Device<->host KV-cache serialization hook (snapshot/restore).
    CacheIo,
}

impl ExeKind {
    /// Step-input token count for decode kinds.
    pub fn t_in(&self) -> Option<usize> {
        match self {
            ExeKind::DecodeLa { t_in, .. } => Some(*t_in),
            ExeKind::DecodeLin { k } => Some(*k),
            ExeKind::DecodeGen { t_pad } => Some(*t_pad),
            ExeKind::Commit { t_in, .. } => Some(*t_in),
            // per-slot token count comes from the base executable
            ExeKind::DecodeBatch { .. } => None,
            ExeKind::Prefill { .. } => None,
            ExeKind::CacheIo => None,
        }
    }
}

fn req<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest: missing '{key}' in {ctx}"))
}

fn req_usize(j: &Json, key: &str, ctx: &str) -> Result<usize> {
    req(j, key, ctx)?.as_usize().ok_or_else(|| anyhow!("manifest: '{key}' not usize in {ctx}"))
}

fn req_str(j: &Json, key: &str, ctx: &str) -> Result<String> {
    Ok(req(j, key, ctx)?
        .as_str()
        .ok_or_else(|| anyhow!("manifest: '{key}' not str in {ctx}"))?
        .to_string())
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let vocab = req(&j, "vocab", "root")?;
        let mut models = BTreeMap::new();
        for (name, mj) in req(&j, "models", "root")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: models not an object"))?
        {
            models.insert(name.clone(), ModelManifest::from_json(name, mj)?);
        }

        Ok(Manifest {
            profile: req_str(&j, "profile", "root")?,
            prefill_len: req_usize(&j, "prefill_len", "root")?,
            commit_slots: req_usize(&j, "commit_slots", "root")?,
            vocab_size: req_usize(vocab, "size", "vocab")?,
            vocab_padded: req_usize(vocab, "padded", "vocab")?,
            pad_id: req_usize(vocab, "pad", "vocab")? as u32,
            bos_id: req_usize(vocab, "bos", "vocab")? as u32,
            eos_id: req_usize(vocab, "eos", "vocab")? as u32,
            models,
            dir,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }
}

impl ModelManifest {
    fn from_json(name: &str, j: &Json) -> Result<ModelManifest> {
        let cfg = req(j, "config", name)?;
        let cache: Vec<usize> = req(j, "cache_shape", name)?
            .usize_vec()
            .ok_or_else(|| anyhow!("bad cache_shape for {name}"))?;
        if cache.len() != 4 {
            bail!("cache_shape must be rank 4 for {name}");
        }
        let mut executables = BTreeMap::new();
        for (ename, ej) in req(j, "executables", name)?
            .as_obj()
            .ok_or_else(|| anyhow!("bad executables for {name}"))?
        {
            executables.insert(ename.clone(), ExeSpec::from_json(ename, ej)?);
        }
        Ok(ModelManifest {
            name: name.to_string(),
            n_layers: req_usize(cfg, "n_layers", name)?,
            d_model: req_usize(cfg, "d_model", name)?,
            n_heads: req_usize(cfg, "n_heads", name)?,
            n_kv_heads: req_usize(cfg, "n_kv_heads", name)?,
            head_dim: req_usize(cfg, "head_dim", name)?,
            max_seq: req_usize(cfg, "max_seq", name)?,
            params: req_usize(cfg, "params", name)?,
            weights_file: req_str(j, "weights_file", name)?,
            weight_names: req(j, "weight_names", name)?
                .str_vec()
                .ok_or_else(|| anyhow!("bad weight_names for {name}"))?,
            weight_shapes: req(j, "weight_shapes", name)?
                .as_arr()
                .ok_or_else(|| anyhow!("bad weight_shapes"))?
                .iter()
                .map(|x| x.usize_vec().ok_or_else(|| anyhow!("bad shape")))
                .collect::<Result<_>>()?,
            cache_shape: [cache[0], cache[1], cache[2], cache[3]],
            junk_row: req_usize(j, "junk_row", name)?,
            executables,
        })
    }

    /// Usable committed rows (everything below the junk row).
    pub fn capacity(&self) -> usize {
        self.junk_row
    }

    /// Find the decode_la executable for (w, n, g), preferring `attn` impl.
    pub fn find_decode_la(&self, w: usize, n: usize, g: usize, attn: &str)
                          -> Option<(&str, &ExeSpec)> {
        let mut fallback = None;
        for (name, spec) in &self.executables {
            if let ExeKind::DecodeLa { w: ww, n: nn, g: gg, attn: a, .. } = &spec.kind {
                if (*ww, *nn, *gg) == (w, n, g) {
                    if a == attn {
                        return Some((name.as_str(), spec));
                    }
                    fallback = Some((name.as_str(), spec));
                }
            }
        }
        fallback
    }

    /// Smallest generic decode executable with t_pad >= t.
    pub fn find_decode_gen(&self, t: usize) -> Option<(&str, usize)> {
        let mut best: Option<(&str, usize)> = None;
        for (name, spec) in &self.executables {
            if let ExeKind::DecodeGen { t_pad } = spec.kind {
                if t_pad >= t && best.is_none_or(|(_, b)| t_pad < b) {
                    best = Some((name.as_str(), t_pad));
                }
            }
        }
        best
    }

    /// Smallest batched executable fusing base executable `of` with
    /// `batch >= n` slots. None = this base has no batched variant big
    /// enough (the serving layer then falls back to per-session calls).
    pub fn find_batched(&self, of: &str, n: usize) -> Option<(&str, usize)> {
        let mut best: Option<(&str, usize)> = None;
        for (name, spec) in &self.executables {
            if let ExeKind::DecodeBatch { of: base, batch } = &spec.kind {
                if base == of && *batch >= n && best.is_none_or(|(_, b)| *batch < b) {
                    best = Some((name.as_str(), *batch));
                }
            }
        }
        best
    }

    /// Largest batch any batched variant of `of` supports (grouping cap).
    pub fn max_batch(&self, of: &str) -> Option<usize> {
        self.executables
            .values()
            .filter_map(|spec| match &spec.kind {
                ExeKind::DecodeBatch { of: base, batch } if base == of => Some(*batch),
                _ => None,
            })
            .max()
    }

    /// The cache_io (device<->host KV serialization) executable, if this
    /// model's artifact set was lowered with one. None = snapshot/restore
    /// and prefix reuse are unavailable for this model.
    pub fn cache_io_exe(&self) -> Option<&str> {
        self.executables
            .iter()
            .find(|(_, spec)| spec.kind == ExeKind::CacheIo)
            .map(|(name, _)| name.as_str())
    }

    pub fn commit_exe(&self, t_in: usize) -> Result<&str> {
        for (name, spec) in &self.executables {
            if let ExeKind::Commit { t_in: t, .. } = spec.kind {
                if t == t_in {
                    return Ok(name.as_str());
                }
            }
        }
        bail!("no commit executable for t_in={t_in} in model {}", self.name)
    }

    pub fn decode_lin_exe(&self, k: usize) -> Result<&str> {
        let name = format!("decode_lin_{k}");
        if self.executables.contains_key(&name) {
            Ok(self.executables.get_key_value(&name).unwrap().0)
        } else {
            bail!("no decode_lin_{k} for model {}", self.name)
        }
    }
}

impl ExeSpec {
    fn from_json(name: &str, j: &Json) -> Result<ExeSpec> {
        let file = req_str(j, "file", name)?;
        let kind = req_str(j, "kind", name)?;
        let kind = match kind.as_str() {
            "prefill" => ExeKind::Prefill { prompt_len: req_usize(j, "prompt_len", name)? },
            "decode_la" => ExeKind::DecodeLa {
                w: req_usize(j, "w", name)?,
                n: req_usize(j, "n", name)?,
                g: req_usize(j, "g", name)?,
                t_in: req_usize(j, "t_in", name)?,
                attn: req_str(j, "attn", name)?,
            },
            "decode_lin" => ExeKind::DecodeLin { k: req_usize(j, "k", name)? },
            "decode_gen" => ExeKind::DecodeGen { t_pad: req_usize(j, "t_pad", name)? },
            "decode_batch" => ExeKind::DecodeBatch {
                of: req_str(j, "of", name)?,
                batch: req_usize(j, "batch", name)?,
            },
            "commit" => ExeKind::Commit {
                t_in: req_usize(j, "t_in", name)?,
                slots: req_usize(j, "slots", name)?,
            },
            "cache_io" => ExeKind::CacheIo,
            other => bail!("unknown executable kind '{other}' for {name}"),
        };
        Ok(ExeSpec { file, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "profile": "min", "prefill_len": 256, "commit_slots": 8,
          "vocab": {"size": 259, "padded": 264, "pad": 256, "bos": 257, "eos": 258},
          "models": {
            "tiny": {
              "config": {"name":"tiny","n_layers":2,"d_model":128,"n_heads":4,
                         "n_kv_heads":4,"d_ff":352,"max_seq":768,"vocab":264,
                         "rope_theta":10000.0,"norm_eps":1e-5,
                         "head_dim":32,"params":500000},
              "weights_file": "weights_tiny.npz",
              "weight_names": ["embed","final_norm"],
              "weight_shapes": [[264,128],[128]],
              "cache_shape": [2,2,768,128],
              "junk_row": 767,
              "executables": {
                "prefill": {"file":"tiny_prefill.hlo.txt","kind":"prefill","prompt_len":256},
                "decode_lin_1": {"file":"a.hlo.txt","kind":"decode_lin","k":1,"t_in":1},
                "decode_la_w5n3g5": {"file":"b.hlo.txt","kind":"decode_la",
                  "w":5,"n":3,"g":5,"t_in":20,"n_lookahead":10,"tag":"w5n3g5","attn":"jnp"},
                "decode_gen_64": {"file":"c.hlo.txt","kind":"decode_gen","t_pad":64,"t_in":64},
                "decode_lin_1_b4": {"file":"e.hlo.txt","kind":"decode_batch",
                  "of":"decode_lin_1","batch":4},
                "decode_lin_1_b8": {"file":"f.hlo.txt","kind":"decode_batch",
                  "of":"decode_lin_1","batch":8},
                "commit_20": {"file":"d.hlo.txt","kind":"commit","t_in":20,"slots":8},
                "cache_io": {"file":"g.hlo.txt","kind":"cache_io"}
              }
            }
          }
        }"#
    }

    fn load_sample() -> Manifest {
        let dir = std::env::temp_dir().join(format!("la-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = load_sample();
        assert_eq!(m.prefill_len, 256);
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.cache_shape, [2, 2, 768, 128]);
        assert_eq!(tiny.capacity(), 767);
        assert_eq!(tiny.executables.len(), 8);
    }

    #[test]
    fn finds_cache_io() {
        let m = load_sample();
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.cache_io_exe(), Some("cache_io"));
        assert_eq!(tiny.executables["cache_io"].kind.t_in(), None);
    }

    #[test]
    fn finds_batched_executables() {
        let m = load_sample();
        let tiny = m.model("tiny").unwrap();
        // smallest batch >= n wins
        assert_eq!(tiny.find_batched("decode_lin_1", 1), Some(("decode_lin_1_b4", 4)));
        assert_eq!(tiny.find_batched("decode_lin_1", 4), Some(("decode_lin_1_b4", 4)));
        assert_eq!(tiny.find_batched("decode_lin_1", 5), Some(("decode_lin_1_b8", 8)));
        assert_eq!(tiny.find_batched("decode_lin_1", 9), None);
        assert_eq!(tiny.find_batched("decode_gen_64", 2), None);
        assert_eq!(tiny.max_batch("decode_lin_1"), Some(8));
        assert_eq!(tiny.max_batch("decode_gen_64"), None);
        // batched kinds report no per-slot token count of their own
        let spec = &tiny.executables["decode_lin_1_b4"];
        assert_eq!(spec.kind.t_in(), None);
    }

    #[test]
    fn finds_executables() {
        let m = load_sample();
        let tiny = m.model("tiny").unwrap();
        let (name, spec) = tiny.find_decode_la(5, 3, 5, "pallas").unwrap();
        assert_eq!(name, "decode_la_w5n3g5"); // falls back to jnp impl
        assert!(matches!(spec.kind, ExeKind::DecodeLa { t_in: 20, .. }));
        assert_eq!(tiny.find_decode_gen(30), Some(("decode_gen_64", 64)));
        assert!(tiny.find_decode_gen(100).is_none());
        assert_eq!(tiny.commit_exe(20).unwrap(), "commit_20");
        assert!(tiny.commit_exe(99).is_err());
    }

    #[test]
    fn missing_model_errors() {
        let m = load_sample();
        assert!(m.model("nope").is_err());
    }
}
