//! Per-model PJRT runtime: weights as device buffers, lazily compiled
//! executables, and typed step calls (prefill / decode / commit).
//!
//! All heavy tensors (weights, KV cache, per-step new-KV) stay device-resident
//! as `PjRtBuffer`s across calls — only token ids, scalars, and logits cross
//! the host boundary per step (see the patched `untuple_result` note in
//! `third_party/xla`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};
use xla::{FromRawBytes, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::kv::PrefixCache;
use crate::runtime::manifest::{ExeKind, Manifest, ModelManifest};
use crate::{debug, info};

/// Logits for the T step tokens: row-major [t, vocab_padded] f32.
#[derive(Debug, Clone)]
pub struct Logits {
    pub data: Vec<f32>,
    pub t: usize,
    pub vocab: usize,
}

impl Logits {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.vocab..(i + 1) * self.vocab]
    }

    pub fn argmax(&self, i: usize, vocab_live: usize) -> u32 {
        let row = &self.row(i)[..vocab_live];
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = j;
            }
        }
        best as u32
    }
}

/// The committed-token KV cache, device-resident.
pub struct Cache {
    pub buf: PjRtBuffer,
    /// valid committed rows (tokens *before* the current token)
    pub len: usize,
}

/// A host-resident KV cache image produced by [`ModelRuntime::cache_to_host`]
/// and consumed by [`ModelRuntime::cache_from_host`] — the unit the `kv`
/// subsystem snapshots to disk, parks during suspend, and forks for prefix
/// reuse. `data` is the backend's raw row-major payload; `elem` tags its
/// element type so a snapshot taken on one backend is never silently
/// reinterpreted on another.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostKv {
    /// committed rows (mirrors [`Cache::len`] at snapshot time).
    pub len: usize,
    /// element type tag of `data` ("i32" on the sim backend).
    pub elem: String,
    /// raw little-endian payload, all cache rows.
    pub data: Vec<u8>,
}

impl HostKv {
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Typed error for a commit that would write past the cache capacity.
/// Sessions downcast this to finish gracefully with
/// `FinishReason::CacheFull` instead of poisoning the session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheOverflow {
    pub len: usize,
    pub add: usize,
    pub capacity: usize,
}

impl std::fmt::Display for CacheOverflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cache overflow: {} + {} > {}", self.len, self.add, self.capacity)
    }
}

impl std::error::Error for CacheOverflow {}

/// Output of one decode step.
pub struct StepOut {
    pub logits: Logits,
    /// [L, 2, T, Hk*D] — stays on device; handed to `commit`.
    pub new_kv: PjRtBuffer,
}

pub struct ModelRuntime {
    pub client: PjRtClient,
    pub mm: ModelManifest,
    pub prefill_len: usize,
    pub commit_slots: usize,
    pub vocab_padded: usize,
    pub pad_id: u32,
    weights: Vec<PjRtBuffer>,
    exes: RefCell<BTreeMap<String, Rc<PjRtLoadedExecutable>>>,
    dir: std::path::PathBuf,
    /// wall-clock accounting: (compiles, executes)
    pub exec_count: RefCell<u64>,
    /// prefix-reuse trie consulted by [`ModelRuntime::prefill_reuse`]
    /// (attached by the serving layer; None = prefix reuse off).
    prefix: RefCell<Option<std::sync::Arc<PrefixCache>>>,
    /// namespace for prefix-trie operations ("" = default). The worker sets
    /// the request tenant here before opening a session, so tenants never
    /// observe (or time) each other's cached prefixes.
    prefix_ns: RefCell<String>,
}

impl ModelRuntime {
    pub fn load(client: &PjRtClient, manifest: &Manifest, model: &str) -> Result<Self> {
        let mm = manifest.model(model)?.clone();
        let npz = manifest.dir.join(&mm.weights_file);
        let names: Vec<&str> = mm.weight_names.iter().map(String::as_str).collect();
        let t0 = std::time::Instant::now();
        let weights = PjRtBuffer::read_npz_by_name(&npz, client, &names)
            .map_err(|e| anyhow!("loading {npz:?}: {e}"))?;
        if weights.len() != mm.weight_names.len() {
            bail!("weight count mismatch: {} vs {}", weights.len(), mm.weight_names.len());
        }
        info!("runtime", "loaded {} weights for '{model}' ({:.1}ms)",
              weights.len(), t0.elapsed().as_secs_f64() * 1e3);
        Ok(ModelRuntime {
            client: client.clone(),
            prefill_len: manifest.prefill_len,
            commit_slots: manifest.commit_slots,
            vocab_padded: manifest.vocab_padded,
            pad_id: manifest.pad_id,
            mm,
            weights,
            exes: RefCell::new(BTreeMap::new()),
            dir: manifest.dir.clone(),
            exec_count: RefCell::new(0),
            prefix: RefCell::new(None),
            prefix_ns: RefCell::new(String::new()),
        })
    }

    /// Lazily compile an executable by manifest name.
    pub fn exe(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .mm
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable '{name}' for model {}", self.mm.name))?;
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?;
        debug!("runtime", "compiled {name} in {:.1}ms", t0.elapsed().as_secs_f64() * 1e3);
        let rc = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.exe(n)?;
        }
        Ok(())
    }

    // -- host<->device helpers ------------------------------------------------

    fn tokens_buf(&self, tokens: &[u32], want_len: usize) -> Result<PjRtBuffer> {
        let mut v: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        v.resize(want_len, self.pad_id as i32);
        Ok(self.client.buffer_from_host_buffer(&v, &[want_len], None)?)
    }

    fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        // rank-0 via the host-buffer path: the copy is synchronous
        // (kImmutableOnlyDuringCall), avoiding the literal path's
        // transfer-ready Await (perf log in EXPERIMENTS.md §Perf)
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    fn i32_buf(&self, v: &[i32]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(v, &[v.len()], None)?)
    }

    fn logits_from(&self, buf: &PjRtBuffer, t: usize) -> Result<Logits> {
        let lit = buf.to_literal_sync()?;
        let data = lit.to_vec::<f32>()?;
        if data.len() != t * self.vocab_padded {
            bail!("logits size {} != {}x{}", data.len(), t, self.vocab_padded);
        }
        Ok(Logits { data, t, vocab: self.vocab_padded })
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer])
           -> Result<Vec<PjRtBuffer>> {
        *self.exec_count.borrow_mut() += 1;
        let mut out = exe.execute_b(args)?;
        if out.is_empty() || out[0].is_empty() {
            bail!("executable returned no outputs");
        }
        Ok(out.remove(0))
    }

    // -- step calls -----------------------------------------------------------

    /// Run prefill on a prompt (<= prefill_len tokens). Returns the per-token
    /// logits, the cache (rows 0..P-1 filled), and cache_len = len-1: the KV
    /// of every prompt token *except the current one* counts as committed.
    pub fn prefill(&self, tokens: &[u32]) -> Result<(Logits, Cache)> {
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        if tokens.len() > self.prefill_len {
            bail!("prompt len {} > prefill capacity {}", tokens.len(), self.prefill_len);
        }
        let exe = self.exe("prefill")?;
        let tb = self.tokens_buf(tokens, self.prefill_len)?;
        let nv = self.scalar_i32(tokens.len() as i32)?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&tb);
        args.push(&nv);
        let mut out = self.run(&exe, &args)?;
        if out.len() != 2 {
            bail!("prefill returned {} outputs, want 2", out.len());
        }
        let cache_buf = out.pop().unwrap();
        let logits_buf = out.pop().unwrap();
        let logits = self.logits_from(&logits_buf, self.prefill_len)?;
        Ok((logits, Cache { buf: cache_buf, len: tokens.len() - 1 }))
    }

    /// One decode step through a specialized (decode_la / decode_lin)
    /// executable. `tokens.len()` must equal the executable's t_in.
    pub fn decode(&self, exe_name: &str, cache: &Cache, tokens: &[u32]) -> Result<StepOut> {
        let spec_t = self
            .mm
            .executables
            .get(exe_name)
            .and_then(|s| s.kind.t_in())
            .ok_or_else(|| anyhow!("'{exe_name}' is not a decode executable"))?;
        if tokens.len() != spec_t {
            bail!("'{exe_name}' expects {spec_t} tokens, got {}", tokens.len());
        }
        let exe = self.exe(exe_name)?;
        let tb = self.tokens_buf(tokens, spec_t)?;
        let cl = self.scalar_i32(cache.len as i32)?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&cache.buf);
        args.push(&cl);
        args.push(&tb);
        let mut out = self.run(&exe, &args)?;
        if out.len() != 2 {
            bail!("decode returned {} outputs, want 2", out.len());
        }
        let new_kv = out.pop().unwrap();
        let logits = self.logits_from(&out.pop().unwrap(), spec_t)?;
        Ok(StepOut { logits, new_kv })
    }

    /// Max fused slots any batched variant of `base_exe` supports; None =
    /// no batched executable (callers fall back to per-session decode).
    pub fn max_batch(&self, base_exe: &str) -> Option<usize> {
        self.mm.max_batch(base_exe)
    }

    /// Shared front half of the batched decode calls: resolve the batched
    /// executable, then build the fused argument tail
    /// `cache_0..cache_{B-1}, cache_lens i32[B], tokens i32[B*t]` (unused
    /// slots padded with the first cache at length 0 and pad tokens, whose
    /// outputs are discarded).
    fn batched_args<'a>(&self, base_exe: &str, t: usize, caches: &[&'a Cache],
                        tokens: &[&[u32]])
                        -> Result<(Rc<PjRtLoadedExecutable>, usize,
                                   Vec<&'a PjRtBuffer>, PjRtBuffer, PjRtBuffer)> {
        let n = caches.len();
        if n == 0 || tokens.len() != n {
            bail!("batched decode: {} caches vs {} token windows", n, tokens.len());
        }
        let (bname, batch) = self
            .mm
            .find_batched(base_exe, n)
            .ok_or_else(|| anyhow!("no batched executable for '{base_exe}' x{n} \
                                    in model {}", self.mm.name))?;
        let exe = self.exe(bname)?;
        let mut cache_args: Vec<&PjRtBuffer> = Vec::with_capacity(batch);
        let mut lens: Vec<i32> = Vec::with_capacity(batch);
        let mut toks: Vec<i32> = Vec::with_capacity(batch * t);
        for (c, w) in caches.iter().zip(tokens) {
            if w.len() > t {
                bail!("batched decode: window {} > t {t}", w.len());
            }
            cache_args.push(&c.buf);
            lens.push(c.len as i32);
            toks.extend(w.iter().map(|&x| x as i32));
            toks.resize(toks.len() + (t - w.len()), self.pad_id as i32);
        }
        for _ in n..batch {
            cache_args.push(&caches[0].buf);
            lens.push(0);
            toks.resize(toks.len() + t, self.pad_id as i32);
        }
        Ok((exe, batch, cache_args, self.i32_buf(&lens)?, self.i32_buf(&toks)?))
    }

    /// Shared back half: split `[logits f32[B*t, vocab], new_kv_0..]` into
    /// one [`StepOut`] per live slot (padding slots dropped).
    fn batched_outs(&self, mut out: Vec<PjRtBuffer>, batch: usize, n: usize,
                    t: usize) -> Result<Vec<StepOut>> {
        if out.len() != 1 + batch {
            bail!("batched decode returned {} outputs, want {}", out.len(), 1 + batch);
        }
        let kvs: Vec<PjRtBuffer> = out.drain(1..).collect();
        let lit = out[0].to_literal_sync()?;
        let data = lit.to_vec::<f32>()?;
        if data.len() != batch * t * self.vocab_padded {
            bail!("batched logits size {} != {batch}x{t}x{}", data.len(),
                  self.vocab_padded);
        }
        let mut steps = Vec::with_capacity(n);
        for (b, new_kv) in kvs.into_iter().enumerate().take(n) {
            let logits = Logits {
                data: data[b * t * self.vocab_padded..(b + 1) * t * self.vocab_padded]
                    .to_vec(),
                t,
                vocab: self.vocab_padded,
            };
            steps.push(StepOut { logits, new_kv });
        }
        Ok(steps)
    }

    /// One fused decode step for a group of sessions sharing a linear or
    /// specialized decode executable: each slot gets its own cache and
    /// token window, one executable launch serves them all. Per-slot
    /// results are identical to calling [`ModelRuntime::decode`] per
    /// session (bit-exact on the sim backend; see DESIGN.md §3c).
    pub fn decode_batched(&self, base_exe: &str, caches: &[&Cache],
                          tokens: &[&[u32]]) -> Result<Vec<StepOut>> {
        let t = self
            .mm
            .executables
            .get(base_exe)
            .and_then(|s| s.kind.t_in())
            .ok_or_else(|| anyhow!("'{base_exe}' is not a decode executable"))?;
        if let Some(w) = tokens.iter().find(|w| w.len() != t) {
            bail!("'{base_exe}' expects {t} tokens per slot, got {}", w.len());
        }
        let (exe, batch, cache_args, lens, toks) =
            self.batched_args(base_exe, t, caches, tokens)?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.extend(cache_args);
        args.push(&lens);
        args.push(&toks);
        let out = self.run(&exe, &args)?;
        self.batched_outs(out, batch, caches.len(), t)
    }

    /// Fused generic masked decode: like [`ModelRuntime::decode_generic`]
    /// for every slot at once. The group shares one (relpos, mask) layout —
    /// batched groups are formed per engine config, so this is not a
    /// restriction in practice.
    pub fn decode_generic_batched(&self, base_exe: &str, caches: &[&Cache],
                                  tokens: &[&[u32]], relpos: &[i32], mask: &[u8])
                                  -> Result<Vec<StepOut>> {
        let t_pad = match self.mm.executables.get(base_exe).map(|s| &s.kind) {
            Some(ExeKind::DecodeGen { t_pad }) => *t_pad,
            _ => bail!("'{base_exe}' is not a decode_gen executable"),
        };
        if relpos.len() != t_pad || mask.len() != t_pad * t_pad {
            bail!("batched generic decode: layout shapes wrong for t_pad={t_pad}");
        }
        let (exe, batch, cache_args, lens, toks) =
            self.batched_args(base_exe, t_pad, caches, tokens)?;
        let rp = self.i32_buf(relpos)?;
        let mb = self
            .client
            .buffer_from_host_raw_bytes(xla::ElementType::U8, mask,
                                        &[t_pad, t_pad], None)?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.extend(cache_args);
        args.push(&lens);
        args.push(&toks);
        args.push(&rp);
        args.push(&mb);
        let out = self.run(&exe, &args)?;
        self.batched_outs(out, batch, caches.len(), t_pad)
    }

    /// Generic masked decode: caller provides the layout (tokens are padded
    /// to the executable's t_pad by this function; mask rows for pad slots
    /// must be pre-extended by the caller via `pad_mask`).
    pub fn decode_generic(&self, exe_name: &str, cache: &Cache, tokens: &[u32],
                          relpos: &[i32], mask: &[u8]) -> Result<StepOut> {
        let t_pad = match self.mm.executables.get(exe_name).map(|s| &s.kind) {
            Some(ExeKind::DecodeGen { t_pad }) => *t_pad,
            _ => bail!("'{exe_name}' is not a decode_gen executable"),
        };
        if tokens.len() > t_pad || relpos.len() != t_pad || mask.len() != t_pad * t_pad {
            bail!("generic decode arg shapes wrong for t_pad={t_pad}");
        }
        let exe = self.exe(exe_name)?;
        let tb = self.tokens_buf(tokens, t_pad)?;
        let cl = self.scalar_i32(cache.len as i32)?;
        let rp = self.i32_buf(relpos)?;
        let mb = self
            .client
            .buffer_from_host_raw_bytes(xla::ElementType::U8, mask, &[t_pad, t_pad], None)?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&cache.buf);
        args.push(&cl);
        args.push(&tb);
        args.push(&rp);
        args.push(&mb);
        let mut out = self.run(&exe, &args)?;
        let new_kv = out.pop().ok_or_else(|| anyhow!("missing new_kv"))?;
        let logits = self.logits_from(&out.pop().ok_or_else(|| anyhow!("missing logits"))?,
                                      t_pad)?;
        Ok(StepOut { logits, new_kv })
    }

    /// Scatter `count` accepted rows of `new_kv` (source indices `src_idx`)
    /// into the cache starting at row `cache.len`; advances `cache.len`.
    pub fn commit(&self, cache: Cache, new_kv: &PjRtBuffer, t_in: usize,
                  src_idx: &[i32], count: usize) -> Result<Cache> {
        if count > self.commit_slots || src_idx.len() > self.commit_slots {
            bail!("commit count {count} exceeds slots {}", self.commit_slots);
        }
        if cache.len + count > self.mm.capacity() {
            // typed so sessions can map it to a graceful CacheFull finish
            return Err(anyhow::Error::new(CacheOverflow {
                len: cache.len,
                add: count,
                capacity: self.mm.capacity(),
            }));
        }
        let exe_name = self.mm.commit_exe(t_in)?.to_string();
        let exe = self.exe(&exe_name)?;
        let mut idx = src_idx.to_vec();
        idx.resize(self.commit_slots, 0);
        let ib = self.i32_buf(&idx)?;
        let ds = self.scalar_i32(cache.len as i32)?;
        let cnt = self.scalar_i32(count as i32)?;
        let args: Vec<&PjRtBuffer> = vec![&cache.buf, new_kv, &ib, &ds, &cnt];
        let mut out = self.run(&exe, &args)?;
        let buf = out.pop().ok_or_else(|| anyhow!("commit returned nothing"))?;
        Ok(Cache { buf, len: cache.len + count })
    }

    // -- KV-cache serialization (the `kv` subsystem's runtime hooks) ----------

    /// Whether this model's artifact set carries a `cache_io` executable —
    /// the gate for snapshot/restore, prefix reuse, and session suspend.
    pub fn supports_cache_io(&self) -> bool {
        self.mm.cache_io_exe().is_some()
    }

    /// Attach (or detach) the prefix-reuse trie consulted by
    /// [`ModelRuntime::prefill_reuse`]. The serving layer shares one
    /// [`PrefixCache`] across all workers of a model; the trie stores only
    /// host-resident data, so it is `Send + Sync` even though the runtime
    /// itself is thread-pinned.
    pub fn set_prefix_cache(&self, pc: Option<std::sync::Arc<PrefixCache>>) {
        *self.prefix.borrow_mut() = pc;
    }

    /// Set the prefix-trie namespace for subsequent [`prefill_reuse`]
    /// calls (None = the default namespace). The serving layer passes the
    /// request tenant before opening each session.
    ///
    /// [`prefill_reuse`]: ModelRuntime::prefill_reuse
    pub fn set_prefix_namespace(&self, ns: Option<&str>) {
        *self.prefix_ns.borrow_mut() = ns.unwrap_or("").to_string();
    }

    /// Serialize a device cache to host memory via the `cache_io`
    /// executable. Only the meaningful rows are kept — the committed
    /// prefix plus the current-token row (`len + 1` rows): every row
    /// beyond `len` is unobservable (decode attends to rows `0..len`;
    /// commits overwrite from `len`), so truncating makes snapshots and
    /// trie entries prompt-proportional instead of full-capacity while a
    /// restore stays bit-identical for every observable row.
    pub fn cache_to_host(&self, cache: &Cache) -> Result<HostKv> {
        let name = self
            .mm
            .cache_io_exe()
            .ok_or_else(|| anyhow!("model {} has no cache_io executable", self.mm.name))?;
        let exe = self.exe(name)?;
        let mut out = self.run(&exe, &[&cache.buf])?;
        let buf = out.pop().ok_or_else(|| anyhow!("cache_io returned nothing"))?;
        let rows = buf.to_literal_sync()?.to_vec::<i32>()?;
        let keep = rows.len().min(cache.len + 1);
        let mut data = Vec::with_capacity(keep * 4);
        for r in &rows[..keep] {
            data.extend_from_slice(&r.to_le_bytes());
        }
        Ok(HostKv { len: cache.len, elem: "i32".into(), data })
    }

    /// Rebuild a device cache from a host image (the inverse of
    /// [`ModelRuntime::cache_to_host`]). The returned cache is a fresh
    /// device buffer — restoring twice yields two independent caches, which
    /// is what makes prefix forking copy-on-write at the device level.
    pub fn cache_from_host(&self, host: &HostKv) -> Result<Cache> {
        let name = self
            .mm
            .cache_io_exe()
            .ok_or_else(|| anyhow!("model {} has no cache_io executable", self.mm.name))?;
        if host.elem != "i32" {
            bail!("cache_from_host: unsupported element type '{}'", host.elem);
        }
        if host.data.len() % 4 != 0 {
            bail!("cache_from_host: payload length {} not a multiple of 4",
                  host.data.len());
        }
        let mut rows: Vec<i32> = host
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        // pad truncated snapshots back to full capacity with junk rows
        // (what those rows hold on a freshly prefilled cache)
        let total = self.mm.cache_shape[2];
        if rows.len() > total {
            bail!("cache_from_host: snapshot has {} rows, cache holds {total}",
                  rows.len());
        }
        if host.len >= total || host.len >= rows.len() + 1 {
            bail!("cache_from_host: committed len {} not covered by {} snapshot \
                   rows (capacity {total})", host.len, rows.len());
        }
        rows.resize(total, -1);
        let exe = self.exe(name)?;
        let db = self.i32_buf(&rows)?;
        let mut out = self.run(&exe, &[&db])?;
        let buf = out.pop().ok_or_else(|| anyhow!("cache_io returned nothing"))?;
        Ok(Cache { buf, len: host.len })
    }

    /// Prefill with prefix reuse: when a [`PrefixCache`] is attached and the
    /// model supports `cache_io`, a stored snapshot sharing a long-enough
    /// committed prefix with `tokens` is restored (fresh device buffer) and
    /// extended token-by-token instead of running the full prefill — the
    /// cache contents are bit-identical to a cold prefill for every row a
    /// later decode can observe. Engines that ignore prefill logits call
    /// this; callers needing the prompt logits keep
    /// [`ModelRuntime::prefill`].
    pub fn prefill_reuse(&self, tokens: &[u32]) -> Result<Cache> {
        let pc = self.prefix.borrow().clone();
        let Some(pc) = pc else {
            return Ok(self.prefill(tokens)?.1);
        };
        // below the trie's floor nothing can be stored or forked: skip the
        // lookup AND the post-prefill snapshot download entirely
        if !self.supports_cache_io() || tokens.is_empty()
            || tokens.len() > self.prefill_len || tokens.len() < pc.min_prefix()
        {
            return Ok(self.prefill(tokens)?.1);
        }
        // partial hits need the token-by-token extension path: a k=1 linear
        // decode (resolved by kind, not name) plus a 1-slot commit
        let lin1 = self
            .mm
            .executables
            .iter()
            .find(|(_, s)| matches!(s.kind, ExeKind::DecodeLin { k: 1 }))
            .map(|(n, _)| n.as_str());
        let can_extend = lin1.is_some() && self.mm.commit_exe(1).is_ok();
        let ns = self.prefix_ns.borrow().clone();
        if let Some((depth, kv)) = pc.lookup(&ns, tokens, can_extend) {
            debug_assert!(depth >= 1 && depth <= tokens.len());
            let mut cache = self.cache_from_host(&kv)?;
            // rows 0..depth of the donor hold exactly tokens[0..depth]
            // (shared prefix); commit the rest incrementally
            cache.len = depth - 1;
            if depth < tokens.len() {
                let lin1 = lin1.expect("partial hit requires the extension path");
                for i in (depth - 1)..(tokens.len() - 1) {
                    let so = self.decode(lin1, &cache, &[tokens[i]])?;
                    cache = self.commit(cache, &so.new_kv, 1, &[0], 1)?;
                }
                pc.insert(&ns, tokens, self.cache_to_host(&cache)?);
            }
            return Ok(cache);
        }
        let (_, cache) = self.prefill(tokens)?;
        pc.insert(&ns, tokens, self.cache_to_host(&cache)?);
        Ok(cache)
    }

    /// Extend a mask of live size t to the padded [t_pad x t_pad] layout
    /// (pad rows see only themselves so softmax stays finite).
    pub fn pad_mask(live: &[u8], t: usize, t_pad: usize) -> Vec<u8> {
        assert_eq!(live.len(), t * t);
        let mut m = vec![0u8; t_pad * t_pad];
        for q in 0..t {
            m[q * t_pad..q * t_pad + t].copy_from_slice(&live[q * t..(q + 1) * t]);
        }
        for q in t..t_pad {
            m[q * t_pad + q] = 1;
        }
        m
    }

    pub fn executions(&self) -> u64 {
        *self.exec_count.borrow()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_mask_extends() {
        let live = vec![1, 0, 1, 1]; // 2x2
        let m = ModelRuntime::pad_mask(&live, 2, 4);
        #[rustfmt::skip]
        let want = vec![
            1,0,0,0,
            1,1,0,0,
            0,0,1,0,
            0,0,0,1,
        ];
        assert_eq!(m, want);
    }

    #[test]
    fn logits_argmax() {
        let l = Logits { data: vec![0.0, 2.0, 1.0, 9.0, 1.0, 0.5, 0.2, 0.1], t: 2, vocab: 4 };
        assert_eq!(l.argmax(0, 3), 1); // index 3 excluded by vocab_live
        assert_eq!(l.argmax(1, 4), 0);
    }
}
