use anyhow::Result;

pub fn smoke_load(path: &str) -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let _exe = client.compile(&comp)?;
    Ok(())
}
