//! Request/response types + their JSON-lines wire format.
//!
//! Streaming protocol: a request with `"stream": true` receives zero or more
//! chunk lines `{"id":..,"seq":..,"delta":"..","done":false}` followed by
//! one final stats record (the [`Response`] JSON, which always carries
//! `"done":true`). Non-streaming requests get only the final record. A
//! control line `{"cancel": <id>}` stops a queued or running request; the
//! cancelled request still receives a well-formed final record with
//! `"finish":"cancelled"` and whatever text it had committed.

use anyhow::{anyhow, bail, Result};

use crate::engine::{GenParams, SamplingParams};
use crate::metrics::DecodeStats;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: f64,
    pub top_k: usize,
    pub top_p: f64,
    /// decoding method: "lookahead" (default), "autoregressive", "jacobi",
    /// "spec_decode", "prompt_lookup"
    pub method: String,
    /// optional (W,N,G) override for lookahead
    pub wng: Option<(usize, usize, usize)>,
    /// per-request override of the server's cross-request n-gram sharing
    /// toggle (None = use the server default).
    pub share_ngrams: Option<bool>,
    /// tenant namespace for shared caches: requests with a tenant only ever
    /// warm (and are warmed by) caches of the same tenant; None = the
    /// default shared namespace (single-tenant behavior).
    pub tenant: Option<String>,
    pub seed: u64,
    /// per-request override of the server's engine-selection controller:
    /// `"static"` pins the requested engine, `"adaptive"` opts into live
    /// re-tuning (greedy requests only — sampled sessions never switch).
    /// None = use the server default.
    pub controller: Option<String>,
    /// stream per-step token deltas as JSON-lines chunks before the final
    /// stats record.
    pub stream: bool,
    /// serving deadline: decode wall-clock budget in ms, measured from the
    /// moment a worker opens the session. On expiry the request finishes
    /// with `"finish":"deadline"` and a partial result.
    pub deadline_ms: Option<u64>,
    /// attach a compact per-request span timeline to the final record
    /// (requires server-side `--trace`; forces the session to be traced
    /// even when `--trace-sample` would skip it).
    pub trace: bool,
}

impl Default for Request {
    fn default() -> Self {
        Request {
            id: 0,
            prompt: String::new(),
            max_tokens: 64,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            method: "lookahead".into(),
            wng: None,
            share_ngrams: None,
            tenant: None,
            seed: 0,
            controller: None,
            stream: false,
            deadline_ms: None,
            trace: false,
        }
    }
}

impl Request {
    /// A request for `prompt` with the documented defaults (greedy lookahead,
    /// 64-token budget). Chain the field-named setters to override:
    /// `Request::new("hi").max_tokens(8).method("jacobi")`. The id stays 0 —
    /// the dispatcher (or TCP front) assigns the real one at submit time.
    pub fn new(prompt: impl Into<String>) -> Request {
        Request { prompt: prompt.into(), ..Default::default() }
    }

    pub fn max_tokens(mut self, n: usize) -> Self {
        self.max_tokens = n;
        self
    }

    pub fn temperature(mut self, t: f64) -> Self {
        self.temperature = t;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    pub fn top_p(mut self, p: f64) -> Self {
        self.top_p = p;
        self
    }

    pub fn method(mut self, m: impl Into<String>) -> Self {
        self.method = m.into();
        self
    }

    pub fn wng(mut self, wng: (usize, usize, usize)) -> Self {
        self.wng = Some(wng);
        self
    }

    pub fn share_ngrams(mut self, on: bool) -> Self {
        self.share_ngrams = Some(on);
        self
    }

    pub fn tenant(mut self, t: impl Into<String>) -> Self {
        self.tenant = Some(t.into());
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn controller(mut self, mode: impl Into<String>) -> Self {
        self.controller = Some(mode.into());
        self
    }

    pub fn stream(mut self, on: bool) -> Self {
        self.stream = on;
        self
    }

    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    pub fn gen_params(&self) -> GenParams {
        GenParams {
            max_new_tokens: self.max_tokens,
            sampling: SamplingParams {
                temperature: self.temperature,
                top_k: self.top_k,
                top_p: self.top_p,
            },
            stop_at_eos: true,
            seed: self.seed,
        }
    }

    /// Parse one JSON line: {"prompt": "...", "max_tokens": 64, ...}
    pub fn from_json_line(id: u64, line: &str) -> Result<Request> {
        let j = Json::parse(line).map_err(|e| anyhow!("bad request json: {e}"))?;
        Request::from_json(id, &j)
    }

    /// Parse an already-parsed request object (the TCP front parses once to
    /// tell control lines from requests).
    pub fn from_json(id: u64, j: &Json) -> Result<Request> {
        let prompt = j
            .get("prompt")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request missing 'prompt'"))?
            .to_string();
        let mut r = Request { id, prompt, ..Default::default() };
        if let Some(v) = j.get("max_tokens").and_then(Json::as_usize) {
            r.max_tokens = v.clamp(1, 4096);
        }
        if let Some(v) = j.get("temperature").and_then(Json::as_f64) {
            r.temperature = v.max(0.0);
        }
        if let Some(v) = j.get("top_k").and_then(Json::as_usize) {
            r.top_k = v;
        }
        if let Some(v) = j.get("top_p").and_then(Json::as_f64) {
            r.top_p = v.clamp(0.0, 1.0);
        }
        if let Some(v) = j.get("method").and_then(Json::as_str) {
            r.method = v.to_string();
        }
        if let Some(v) = j.get("seed").and_then(Json::as_i64) {
            // a negative seed used to wrap silently via `as u64`, making
            // "seed": -1 a different (undocumented) stream than documented
            if v < 0 {
                bail!("'seed' must be non-negative, got {v}");
            }
            r.seed = v as u64;
        }
        if let Some(v) = j.get("share_ngrams").and_then(Json::as_bool) {
            r.share_ngrams = Some(v);
        }
        if let Some(v) = j.get("tenant").and_then(Json::as_str) {
            if v.is_empty() {
                bail!("'tenant' must be a non-empty string");
            }
            r.tenant = Some(v.to_string());
        }
        if let Some(v) = j.get("controller").and_then(Json::as_str) {
            if v != "static" && v != "adaptive" {
                bail!("'controller' must be \"static\" or \"adaptive\", got '{v}'");
            }
            r.controller = Some(v.to_string());
        }
        if let Some(v) = j.get("stream").and_then(Json::as_bool) {
            r.stream = v;
        }
        if let Some(v) = j.get("deadline_ms").and_then(Json::as_usize) {
            r.deadline_ms = Some(v as u64);
        }
        if let Some(v) = j.get("trace").and_then(Json::as_bool) {
            r.trace = v;
        }
        if let Some(arr) = j.get("wng").and_then(Json::as_arr) {
            let v: Vec<usize> = arr.iter().filter_map(Json::as_usize).collect();
            if v.len() != 3 {
                bail!("'wng' must be three non-negative integers [W, N, G]");
            }
            // zero components would panic the layout (W >= 1, N >= 2) or
            // degenerate the verification branch — reject at the boundary
            if v[0] == 0 || v[2] == 0 {
                bail!("'wng' components must be positive, got {v:?}");
            }
            if v[1] < 2 {
                bail!("'wng' N must be >= 2 (n-gram length), got {}", v[1]);
            }
            r.wng = Some((v[0], v[1], v[2]));
        }
        Ok(r)
    }

    /// Wire form of this request (one JSON line, no trailing newline). The
    /// id is intentionally omitted — the TCP front assigns its own. Inverse
    /// of [`Request::from_json_line`] for every wire-visible field.
    pub fn to_json_line(&self) -> String {
        let mut fields = vec![
            ("prompt", Json::str(self.prompt.clone())),
            ("max_tokens", Json::num(self.max_tokens as f64)),
            ("temperature", Json::num(self.temperature)),
            ("top_k", Json::num(self.top_k as f64)),
            ("top_p", Json::num(self.top_p)),
            ("method", Json::str(self.method.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("stream", Json::Bool(self.stream)),
        ];
        if let Some((w, n, g)) = self.wng {
            fields.push((
                "wng",
                Json::arr(vec![
                    Json::num(w as f64),
                    Json::num(n as f64),
                    Json::num(g as f64),
                ]),
            ));
        }
        if let Some(v) = self.share_ngrams {
            fields.push(("share_ngrams", Json::Bool(v)));
        }
        if let Some(t) = &self.tenant {
            fields.push(("tenant", Json::str(t.clone())));
        }
        if let Some(c) = &self.controller {
            fields.push(("controller", Json::str(c.clone())));
        }
        if let Some(ms) = self.deadline_ms {
            fields.push(("deadline_ms", Json::num(ms as f64)));
        }
        // emitted only when set, so untraced requests stay byte-identical
        if self.trace {
            fields.push(("trace", Json::Bool(true)));
        }
        Json::obj(fields).dump()
    }
}

/// One incremental streaming chunk (committed-token text delta).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamChunk {
    pub id: u64,
    /// 1-based chunk sequence number within the request.
    pub seq: u64,
    pub delta: String,
}

impl StreamChunk {
    pub fn to_json_line(&self) -> String {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("seq", Json::num(self.seq as f64)),
            ("delta", Json::str(self.delta.clone())),
            ("done", Json::Bool(false)),
        ])
        .dump()
    }

    /// Inverse of [`StreamChunk::to_json_line`]: a non-final record
    /// (`done: false`). The donor's reply-tunnel relay uses this to tell
    /// chunks from the final [`Response`] line.
    pub fn from_json_line(line: &str) -> Result<StreamChunk> {
        let j = Json::parse(line).map_err(|e| anyhow!("bad chunk json: {e}"))?;
        if j.get("done").and_then(Json::as_bool) != Some(false) {
            bail!("not a stream chunk (missing 'done': false): {line}");
        }
        let id = j
            .get("id")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("chunk without id: {line}"))? as u64;
        let seq = j.get("seq").and_then(Json::as_i64).unwrap_or(0) as u64;
        let delta = j
            .get("delta")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("chunk without delta: {line}"))?
            .to_string();
        Ok(StreamChunk { id, seq, delta })
    }
}

/// A message from the serving pipeline to a submitter: either an
/// incremental chunk or the final stats record.
#[derive(Debug, Clone)]
pub enum Reply {
    Chunk(StreamChunk),
    Done(Response),
}

impl Reply {
    pub fn id(&self) -> u64 {
        match self {
            Reply::Chunk(c) => c.id,
            Reply::Done(r) => r.id,
        }
    }

    pub fn into_response(self) -> Option<Response> {
        match self {
            Reply::Done(r) => Some(r),
            Reply::Chunk(_) => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: usize,
    pub steps: usize,
    pub compression: f64,
    pub wall_ms: f64,
    pub queue_ms: f64,
    /// time to first token (ms), session open -> first committed step.
    pub ttft_ms: f64,
    /// why generation stopped: "eos" | "budget" | "cache_full" |
    /// "cancelled" | "deadline" (empty for error responses).
    pub finish: String,
    /// per-step accept-length histogram: index = tokens accepted in a step.
    pub accept_hist: Vec<usize>,
    /// request was served from an n-gram store that already held entries
    /// (cross-request shared cache warmed by earlier traffic).
    pub pool_warm: bool,
    /// request used the cross-request shared n-gram cache.
    pub pool_shared: bool,
    /// per-request n-gram speculation hit rate.
    pub pool_hit_rate: f64,
    pub error: Option<String>,
    /// compact span timeline (`[{name, cat, ts_us, dur_us}, ..]`), present
    /// only when the request set `"trace": true` on a tracing server —
    /// absent otherwise so default outputs stay byte-identical.
    pub timeline: Option<Json>,
}

impl Response {
    pub fn ok(id: u64, text: String, stats: &DecodeStats, queue_ms: f64) -> Response {
        Response {
            id,
            text,
            tokens: stats.generated_tokens,
            steps: stats.decode_steps,
            compression: stats.compression(),
            wall_ms: stats.wall.as_secs_f64() * 1e3,
            queue_ms,
            ttft_ms: stats.ttft.as_secs_f64() * 1e3,
            finish: String::new(),
            accept_hist: stats.accepted_by_len.clone(),
            pool_warm: stats.pool_warm_start,
            pool_shared: stats.pool_shared,
            pool_hit_rate: stats.pool_hit_rate(),
            error: None,
            timeline: None,
        }
    }

    pub fn err(id: u64, msg: String) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: 0,
            steps: 0,
            compression: 0.0,
            wall_ms: 0.0,
            queue_ms: 0.0,
            ttft_ms: 0.0,
            finish: String::new(),
            accept_hist: Vec::new(),
            pool_warm: false,
            pool_shared: false,
            pool_hit_rate: 0.0,
            error: Some(msg),
            timeline: None,
        }
    }

    /// Final record for a request cancelled while still queued (it never
    /// reached a worker — zero tokens, no error).
    pub fn cancelled(id: u64) -> Response {
        let mut r = Response::err(id, String::new());
        r.error = None;
        r.finish = "cancelled".into();
        r
    }

    pub fn with_finish(mut self, finish: &str) -> Response {
        self.finish = finish.to_string();
        self
    }

    pub fn to_json_line(&self) -> String {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("text", Json::str(self.text.clone())),
            ("tokens", Json::num(self.tokens as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("compression", Json::num((self.compression * 1000.0).round() / 1000.0)),
            ("wall_ms", Json::num((self.wall_ms * 100.0).round() / 100.0)),
            ("queue_ms", Json::num((self.queue_ms * 100.0).round() / 100.0)),
            ("ttft_ms", Json::num((self.ttft_ms * 100.0).round() / 100.0)),
            ("finish", Json::str(self.finish.clone())),
            ("accept_hist",
             Json::arr(self.accept_hist.iter().map(|&c| Json::num(c as f64)).collect())),
            ("pool_warm", Json::Bool(self.pool_warm)),
            ("pool_shared", Json::Bool(self.pool_shared)),
            ("pool_hit_rate", Json::num((self.pool_hit_rate * 1000.0).round() / 1000.0)),
            // terminates a streaming exchange; constant true on final records
            ("done", Json::Bool(true)),
        ];
        if let Some(e) = &self.error {
            fields.push(("error", Json::str(e.clone())));
        }
        if let Some(tl) = &self.timeline {
            fields.push(("timeline", tl.clone()));
        }
        Json::obj(fields).dump()
    }

    /// Parse a final record off the wire (a line with `"done": true`) —
    /// the client-side inverse of [`Response::to_json_line`]. The load
    /// harness uses this to turn raw protocol lines back into stats.
    pub fn from_json_line(line: &str) -> Result<Response> {
        let j = Json::parse(line).map_err(|e| anyhow!("bad response json: {e}"))?;
        if j.get("done").and_then(Json::as_bool) != Some(true) {
            bail!("not a final record (missing 'done': true): {line}");
        }
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        Ok(Response {
            id: j.get("id").and_then(Json::as_usize).unwrap_or(0) as u64,
            text: j.get("text").and_then(Json::as_str).unwrap_or("").to_string(),
            tokens: j.get("tokens").and_then(Json::as_usize).unwrap_or(0),
            steps: j.get("steps").and_then(Json::as_usize).unwrap_or(0),
            compression: num("compression"),
            wall_ms: num("wall_ms"),
            queue_ms: num("queue_ms"),
            ttft_ms: num("ttft_ms"),
            finish: j.get("finish").and_then(Json::as_str).unwrap_or("").to_string(),
            accept_hist: j
                .get("accept_hist")
                .and_then(Json::usize_vec)
                .unwrap_or_default(),
            pool_warm: j.get("pool_warm").and_then(Json::as_bool).unwrap_or(false),
            pool_shared: j.get("pool_shared").and_then(Json::as_bool).unwrap_or(false),
            pool_hit_rate: num("pool_hit_rate"),
            error: j.get("error").and_then(Json::as_str).map(str::to_string),
            timeline: j.get("timeline").cloned(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_request() {
        let r = Request::from_json_line(3, r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.method, "lookahead");
        assert_eq!(r.max_tokens, 64);
        assert!(!r.stream);
        assert_eq!(r.deadline_ms, None);
    }

    #[test]
    fn parses_full_request() {
        let r = Request::from_json_line(
            1,
            r#"{"prompt":"x","max_tokens":10,"temperature":0.7,"method":"autoregressive","wng":[5,3,5],"seed":9,"stream":true,"deadline_ms":250}"#,
        )
        .unwrap();
        assert_eq!(r.max_tokens, 10);
        assert!((r.temperature - 0.7).abs() < 1e-12);
        assert_eq!(r.method, "autoregressive");
        assert_eq!(r.wng, Some((5, 3, 5)));
        assert_eq!(r.seed, 9);
        assert!(r.stream);
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn rejects_negative_seed() {
        // used to wrap silently via `as u64`
        let e = Request::from_json_line(0, r#"{"prompt":"x","seed":-1}"#);
        assert!(e.is_err(), "negative seed must be rejected");
        assert!(e.unwrap_err().to_string().contains("seed"));
        // zero and positive still fine
        assert_eq!(Request::from_json_line(0, r#"{"prompt":"x","seed":0}"#)
                       .unwrap().seed, 0);
    }

    #[test]
    fn rejects_zero_wng_components() {
        for bad in [
            r#"{"prompt":"x","wng":[0,3,5]}"#,
            r#"{"prompt":"x","wng":[5,3,0]}"#,
            r#"{"prompt":"x","wng":[5,0,5]}"#,
            r#"{"prompt":"x","wng":[5,1,5]}"#, // N=1: not an n-gram
            r#"{"prompt":"x","wng":[5,3]}"#,   // wrong arity
        ] {
            assert!(Request::from_json_line(0, bad).is_err(), "accepted {bad}");
        }
        let ok = Request::from_json_line(0, r#"{"prompt":"x","wng":[1,2,1]}"#).unwrap();
        assert_eq!(ok.wng, Some((1, 2, 1)));
    }

    #[test]
    fn parses_share_ngrams_override() {
        let r = Request::from_json_line(1, r#"{"prompt":"x","share_ngrams":false}"#)
            .unwrap();
        assert_eq!(r.share_ngrams, Some(false));
        let r = Request::from_json_line(1, r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(r.share_ngrams, None);
    }

    #[test]
    fn parses_tenant_namespace() {
        let r = Request::from_json_line(1, r#"{"prompt":"x","tenant":"acme"}"#).unwrap();
        assert_eq!(r.tenant.as_deref(), Some("acme"));
        let r = Request::from_json_line(1, r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(r.tenant, None, "no tenant means the default shared namespace");
        assert!(Request::from_json_line(1, r#"{"prompt":"x","tenant":""}"#).is_err(),
                "empty tenant must be rejected");
    }

    #[test]
    fn parses_controller_override() {
        let r = Request::from_json_line(1, r#"{"prompt":"x","controller":"adaptive"}"#)
            .unwrap();
        assert_eq!(r.controller.as_deref(), Some("adaptive"));
        let r = Request::from_json_line(1, r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(r.controller, None, "no override means the server default");
        let e = Request::from_json_line(1, r#"{"prompt":"x","controller":"magic"}"#);
        assert!(e.is_err(), "unknown controller mode must be rejected");
        assert!(e.unwrap_err().to_string().contains("controller"));
    }

    #[test]
    fn response_carries_pool_stats() {
        let stats = DecodeStats {
            pool_hits: 3,
            pool_misses: 1,
            pool_warm_start: true,
            pool_shared: true,
            ..Default::default()
        };
        let r = Response::ok(1, "t".into(), &stats, 0.0);
        assert!(r.pool_warm && r.pool_shared);
        assert!((r.pool_hit_rate - 0.75).abs() < 1e-12);
        let j = Json::parse(&r.to_json_line()).unwrap();
        assert_eq!(j.get("pool_warm").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("pool_shared").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("pool_hit_rate").unwrap().as_f64(), Some(0.75));
    }

    #[test]
    fn request_new_matches_defaults() {
        let r = Request::new("hi");
        assert_eq!(r, Request { prompt: "hi".into(), ..Default::default() });
        let r = Request::new("x")
            .max_tokens(8)
            .temperature(0.5)
            .method("jacobi")
            .wng((5, 3, 5))
            .tenant("acme")
            .seed(7)
            .stream(true)
            .deadline_ms(250);
        assert_eq!(r.max_tokens, 8);
        assert!((r.temperature - 0.5).abs() < 1e-12);
        assert_eq!(r.method, "jacobi");
        assert_eq!(r.wng, Some((5, 3, 5)));
        assert_eq!(r.tenant.as_deref(), Some("acme"));
        assert_eq!(r.seed, 7);
        assert!(r.stream);
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn request_wire_roundtrip() {
        let r = Request::new("abc")
            .max_tokens(12)
            .wng((4, 3, 4))
            .share_ngrams(false)
            .tenant("t1")
            .controller("adaptive")
            .deadline_ms(99);
        let back = Request::from_json_line(0, &r.to_json_line()).unwrap();
        assert_eq!(back, Request { id: 0, ..r });
    }

    #[test]
    fn response_parse_roundtrip() {
        let mut stats = DecodeStats::default();
        stats.record_accept(3);
        stats.wall = std::time::Duration::from_millis(20);
        let r = Response::ok(5, "out".into(), &stats, 2.0).with_finish("eos");
        let back = Response::from_json_line(&r.to_json_line()).unwrap();
        assert_eq!(back.id, 5);
        assert_eq!(back.text, "out");
        assert_eq!(back.tokens, 3);
        assert_eq!(back.finish, "eos");
        assert!(back.error.is_none());
        // chunks are not final records
        let chunk = StreamChunk { id: 5, seq: 1, delta: "x".into() }.to_json_line();
        assert!(Response::from_json_line(&chunk).is_err());
    }

    #[test]
    fn rejects_missing_prompt() {
        assert!(Request::from_json_line(0, r#"{"max_tokens": 4}"#).is_err());
        assert!(Request::from_json_line(0, "not json").is_err());
    }

    #[test]
    fn response_roundtrips_as_json() {
        let mut stats = DecodeStats::default();
        stats.record_accept(2);
        stats.wall = std::time::Duration::from_millis(12);
        stats.ttft = std::time::Duration::from_millis(3);
        let line = Response::ok(7, "out".into(), &stats, 1.5)
            .with_finish("eos")
            .to_json_line();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("text").unwrap().as_str(), Some("out"));
        assert_eq!(j.get("tokens").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("finish").unwrap().as_str(), Some("eos"));
        assert_eq!(j.get("done").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("ttft_ms").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("accept_hist").unwrap().usize_vec().unwrap(), vec![0, 0, 1]);
        assert!(j.get("error").is_none());
    }

    #[test]
    fn chunk_wire_format() {
        let c = StreamChunk { id: 4, seq: 2, delta: "ab\n".into() };
        let j = Json::parse(&c.to_json_line()).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("seq").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("delta").unwrap().as_str(), Some("ab\n"));
        assert_eq!(j.get("done").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn trace_flag_and_timeline_are_emitted_only_when_set() {
        // default request: no "trace" key on the wire (byte-stability)
        let r = Request::new("x");
        assert!(!r.trace);
        assert!(!r.to_json_line().contains("trace"));
        let r = Request::new("x").trace(true);
        let back = Request::from_json_line(0, &r.to_json_line()).unwrap();
        assert!(back.trace);
        // default response: no "timeline" key
        let resp = Response::ok(1, "t".into(), &DecodeStats::default(), 0.0);
        assert!(resp.timeline.is_none());
        assert!(!resp.to_json_line().contains("timeline"));
        let mut resp = resp;
        resp.timeline = Some(Json::arr(vec![Json::obj(vec![
            ("name", Json::str("prefill")),
            ("cat", Json::str("prefill")),
            ("ts_us", Json::num(1.0)),
            ("dur_us", Json::num(2.0)),
        ])]));
        let back = Response::from_json_line(&resp.to_json_line()).unwrap();
        let tl = back.timeline.expect("timeline must survive the wire");
        assert_eq!(tl.as_arr().map(<[Json]>::len), Some(1));
    }

    #[test]
    fn cancelled_record_is_well_formed() {
        let r = Response::cancelled(9);
        assert!(r.error.is_none());
        assert_eq!(r.finish, "cancelled");
        assert_eq!(r.tokens, 0);
        let j = Json::parse(&r.to_json_line()).unwrap();
        assert_eq!(j.get("finish").unwrap().as_str(), Some("cancelled"));
    }
}
