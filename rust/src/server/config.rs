//! Serving configuration: [`ServerConfig`] + [`WorkerConfig`], their
//! documented defaults, and the chainable builders.
//!
//! This is the ONLY module that writes struct literals of these types —
//! every other construction site goes through [`ServerConfig::builder`],
//! [`WorkerConfig::builder`], or `Default`. Adding a config field is then a
//! one-module change (plus the CLI flag that feeds it) instead of a sweep
//! over main/benches/every integration test.

use crate::server::scheduler::Policy;

#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    pub workers: usize,
    pub policy: Policy,
    pub queue_depth: usize,
    /// server-level toggle for the cross-request shared n-gram cache. When
    /// true, one `NgramCacheRegistry` spans all workers; individual
    /// requests can still opt out via `share_ngrams: false`. When false,
    /// no registry exists and every request decodes against a cold pool.
    pub share_ngrams: bool,
    /// TTL decay for shared n-gram caches: entries untouched for this many
    /// ms are evicted on shard access (None = keep until LRU pressure).
    pub ngram_ttl_ms: Option<u64>,
    /// Continuous batching: fuse compatible live sessions into one batched
    /// decode call per scheduling round. Workers batch only when BOTH this
    /// and their `WorkerConfig::batch_decode` are true (both default on),
    /// so an explicit `false` at either level wins. The sequential
    /// per-session path commits byte-identical token streams.
    pub batch_decode: bool,
    /// Cross-worker session rebalancing: a server thread periodically
    /// compares per-worker live+parked depth and moves the coldest parked
    /// [`crate::kv::SessionSnapshot`] from the deepest worker to the
    /// shallowest one (snapshots are runtime-portable, so the adopter
    /// resumes byte-identically). Only meaningful with `workers > 1`; the
    /// donor must have parked sessions, so pair it with
    /// `WorkerConfig::kv_budget`.
    pub rebalance: bool,
    /// Rebalance scan interval in ms (ignored when `rebalance` is false).
    pub rebalance_interval_ms: u64,
    /// Remote peer listener addresses (`host:port`): with `rebalance`, the
    /// policy thread may ship parked sessions to these processes over the
    /// wire protocol (DESIGN.md §4c); prefill-only workers ship every
    /// committed session to the first alive decode peer. Empty = no
    /// networking.
    pub peers: Vec<String>,
    /// Address this process's own peer listener binds (`host:port`). None
    /// disables inbound transfers/heartbeats — required when `peers` is
    /// set on the OTHER side pointing here.
    pub peer_addr: Option<String>,
    /// Peer heartbeat/load-poll interval in ms (ignored without `peers`).
    pub heartbeat_ms: u64,
    /// Structured span tracing (DESIGN.md §8). When true the server owns a
    /// [`crate::trace::Tracer`] shared by every worker, the net transport,
    /// and the dispatcher; spans are exported via the `{"trace": true}`
    /// control line, `trace_out`, and per-request timelines. When false
    /// (default) no tracer exists and the decode path allocates nothing.
    pub trace: bool,
    /// Trace every Nth admitted request (1 = all). Sampled-out sessions
    /// carry `trace_id = 0` and cost one branch per would-be span.
    pub trace_sample: u64,
    /// Per-shard span ring capacity. The ring is bounded: overflow drops
    /// the OLDEST span and bumps the `trace_dropped` counter — tracing
    /// never blocks or grows without bound.
    pub trace_buf: usize,
    /// Write the Chrome trace-event JSON here on clean server shutdown
    /// (`--trace-out`). None = export only via the control line.
    pub trace_out: Option<String>,
    pub worker: WorkerConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            policy: Policy::Fifo,
            queue_depth: 256,
            share_ngrams: true,
            ngram_ttl_ms: None,
            batch_decode: true,
            rebalance: false,
            rebalance_interval_ms: 50,
            peers: Vec::new(),
            peer_addr: None,
            heartbeat_ms: 100,
            trace: false,
            trace_sample: 1,
            trace_buf: crate::trace::DEFAULT_TRACE_BUF,
            trace_out: None,
            worker: WorkerConfig::default(),
        }
    }
}

impl ServerConfig {
    /// Chainable builder over the documented defaults:
    /// `ServerConfig::builder().workers(2).rebalance(true).build()`.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder::default()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerConfig {
    pub artifacts_dir: String,
    pub model: String,
    /// default (W,N,G) when the request does not override it
    pub wng: (usize, usize, usize),
    pub draft_model: String,
    /// decode steps each live session gets per scheduling round.
    pub time_slice: usize,
    /// max concurrently interleaved sessions per worker.
    pub max_live: usize,
    /// fuse compatible live sessions into one batched decode call per round
    /// (falls back to per-session calls when the model has no batched
    /// executable for a group).
    pub batch_decode: bool,
    /// device KV budget: max device-resident session caches. When live
    /// sessions exceed it, the coldest suspendable session is parked
    /// (snapshot to host + device free) and revived when a slot opens —
    /// `max_live` then counts live + parked, a soft limit. 0 = unlimited
    /// (every admitted session stays device-resident, the pre-kv behavior).
    pub kv_budget: usize,
    /// prefix-reuse trie: requests sharing a long committed prompt prefix
    /// fork a stored KV snapshot instead of paying a full prefill
    /// (byte-exact; needs a `cache_io` executable in the artifacts).
    pub prefix_cache: bool,
    /// engine-selection controller: `"static"` keeps each request on its
    /// requested engine; `"adaptive"` lets the per-worker
    /// [`crate::control::AdaptiveController`] re-tune greedy sessions live
    /// (switches ride suspend/resume, committed output stays byte-exact).
    /// Requests can override either way via `Request::controller`.
    pub controller: String,
    /// Disaggregated serving, prefill half: this worker commits prompt KV
    /// (prefill + prefix-trie insert) but ships sessions to a remote decode
    /// peer instead of stepping them. Requires `ServerConfig::peers`; with
    /// no alive decode peer the worker decodes locally (degraded but
    /// correct).
    pub prefill_only: bool,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            artifacts_dir: "artifacts".into(),
            model: "tiny".into(),
            wng: (5, 3, 5),
            draft_model: "draft".into(),
            time_slice: 4,
            max_live: 4,
            batch_decode: true,
            kv_budget: 0,
            prefix_cache: true,
            controller: "static".into(),
            prefill_only: false,
        }
    }
}

impl WorkerConfig {
    /// Chainable builder over the documented defaults.
    pub fn builder() -> WorkerConfigBuilder {
        WorkerConfigBuilder::default()
    }
}

/// Chainable [`ServerConfig`] constructor. Worker-level knobs every caller
/// flips (`artifacts_dir`, `model`, `time_slice`, ...) are exposed directly
/// and mutate the embedded [`WorkerConfig`]; `worker(..)` replaces the whole
/// embedded config, so order matters — later calls win.
#[derive(Debug, Clone, Default)]
pub struct ServerConfigBuilder {
    cfg: ServerConfig,
}

impl ServerConfigBuilder {
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    pub fn policy(mut self, p: Policy) -> Self {
        self.cfg.policy = p;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.cfg.queue_depth = depth;
        self
    }

    pub fn share_ngrams(mut self, on: bool) -> Self {
        self.cfg.share_ngrams = on;
        self
    }

    pub fn ngram_ttl_ms(mut self, ttl: Option<u64>) -> Self {
        self.cfg.ngram_ttl_ms = ttl;
        self
    }

    /// Sets the toggle at BOTH levels (server and worker): the effective
    /// value is their AND, so one builder call expresses the caller's
    /// intent either way.
    pub fn batch_decode(mut self, on: bool) -> Self {
        self.cfg.batch_decode = on;
        self.cfg.worker.batch_decode = on;
        self
    }

    pub fn rebalance(mut self, on: bool) -> Self {
        self.cfg.rebalance = on;
        self
    }

    pub fn rebalance_interval_ms(mut self, ms: u64) -> Self {
        self.cfg.rebalance_interval_ms = ms;
        self
    }

    pub fn peers(mut self, peers: Vec<String>) -> Self {
        self.cfg.peers = peers;
        self
    }

    pub fn peer_addr(mut self, addr: Option<String>) -> Self {
        self.cfg.peer_addr = addr;
        self
    }

    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.cfg.heartbeat_ms = ms;
        self
    }

    pub fn trace(mut self, on: bool) -> Self {
        self.cfg.trace = on;
        self
    }

    pub fn trace_sample(mut self, every: u64) -> Self {
        self.cfg.trace_sample = every;
        self
    }

    pub fn trace_buf(mut self, cap: usize) -> Self {
        self.cfg.trace_buf = cap;
        self
    }

    pub fn trace_out(mut self, path: Option<String>) -> Self {
        self.cfg.trace_out = path;
        self
    }

    /// Replace the embedded [`WorkerConfig`] wholesale (also resets any
    /// worker-level knob set earlier on this builder).
    pub fn worker(mut self, w: WorkerConfig) -> Self {
        self.cfg.worker = w;
        self
    }

    // -- worker-level passthroughs -----------------------------------------

    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.worker.artifacts_dir = dir.into();
        self
    }

    pub fn model(mut self, model: impl Into<String>) -> Self {
        self.cfg.worker.model = model.into();
        self
    }

    pub fn wng(mut self, wng: (usize, usize, usize)) -> Self {
        self.cfg.worker.wng = wng;
        self
    }

    pub fn draft_model(mut self, model: impl Into<String>) -> Self {
        self.cfg.worker.draft_model = model.into();
        self
    }

    pub fn time_slice(mut self, steps: usize) -> Self {
        self.cfg.worker.time_slice = steps;
        self
    }

    pub fn max_live(mut self, n: usize) -> Self {
        self.cfg.worker.max_live = n;
        self
    }

    pub fn kv_budget(mut self, n: usize) -> Self {
        self.cfg.worker.kv_budget = n;
        self
    }

    pub fn prefix_cache(mut self, on: bool) -> Self {
        self.cfg.worker.prefix_cache = on;
        self
    }

    pub fn controller(mut self, mode: impl Into<String>) -> Self {
        self.cfg.worker.controller = mode.into();
        self
    }

    pub fn prefill_only(mut self, on: bool) -> Self {
        self.cfg.worker.prefill_only = on;
        self
    }

    pub fn build(self) -> ServerConfig {
        self.cfg
    }
}

/// Chainable [`WorkerConfig`] constructor (for callers that hand-build
/// workers without a server, e.g. the batched-equivalence harness).
#[derive(Debug, Clone, Default)]
pub struct WorkerConfigBuilder {
    cfg: WorkerConfig,
}

impl WorkerConfigBuilder {
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    pub fn model(mut self, model: impl Into<String>) -> Self {
        self.cfg.model = model.into();
        self
    }

    pub fn wng(mut self, wng: (usize, usize, usize)) -> Self {
        self.cfg.wng = wng;
        self
    }

    pub fn draft_model(mut self, model: impl Into<String>) -> Self {
        self.cfg.draft_model = model.into();
        self
    }

    pub fn time_slice(mut self, steps: usize) -> Self {
        self.cfg.time_slice = steps;
        self
    }

    pub fn max_live(mut self, n: usize) -> Self {
        self.cfg.max_live = n;
        self
    }

    pub fn batch_decode(mut self, on: bool) -> Self {
        self.cfg.batch_decode = on;
        self
    }

    pub fn kv_budget(mut self, n: usize) -> Self {
        self.cfg.kv_budget = n;
        self
    }

    pub fn prefix_cache(mut self, on: bool) -> Self {
        self.cfg.prefix_cache = on;
        self
    }

    pub fn controller(mut self, mode: impl Into<String>) -> Self {
        self.cfg.controller = mode.into();
        self
    }

    pub fn prefill_only(mut self, on: bool) -> Self {
        self.cfg.prefill_only = on;
        self
    }

    pub fn build(self) -> WorkerConfig {
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_equal_default() {
        assert_eq!(ServerConfig::builder().build(), ServerConfig::default());
        assert_eq!(WorkerConfig::builder().build(), WorkerConfig::default());
    }

    #[test]
    fn builder_sets_only_what_it_is_told() {
        let cfg = ServerConfig::builder().workers(2).rebalance(true).build();
        assert_eq!(cfg.workers, 2);
        assert!(cfg.rebalance);
        let want =
            ServerConfig { workers: 2, rebalance: true, ..ServerConfig::default() };
        assert_eq!(cfg, want, "untouched fields must keep their defaults");
    }

    #[test]
    fn batch_decode_sets_both_levels() {
        let cfg = ServerConfig::builder().batch_decode(false).build();
        assert!(!cfg.batch_decode);
        assert!(!cfg.worker.batch_decode, "worker level must follow");
    }

    #[test]
    fn worker_passthroughs_then_replacement() {
        let cfg = ServerConfig::builder()
            .time_slice(2)
            .worker(WorkerConfig::builder().max_live(8).build())
            .build();
        // worker(..) replaces wholesale: the earlier passthrough is gone
        assert_eq!(cfg.worker.time_slice, 4);
        assert_eq!(cfg.worker.max_live, 8);
    }
}
