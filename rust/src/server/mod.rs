//! Serving front (L3): request router, scheduler with back-pressure,
//! dynamic worker pool, TCP JSON-lines protocol, in-process API.

pub mod request;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use request::{Request, Response};
pub use scheduler::{Policy, Scheduler};
pub use server::{client_request, serve_tcp, ServerConfig, ServerHandle};
pub use worker::{Worker, WorkerConfig};
