//! Serving front (L3): request router, scheduler with back-pressure,
//! dynamic worker pool with time-sliced session interleaving, streaming +
//! cancellation, TCP JSON-lines protocol, in-process API.

pub mod config;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod worker;

pub use config::{ServerConfig, ServerConfigBuilder, WorkerConfig, WorkerConfigBuilder};
pub use request::{Reply, Request, Response, StreamChunk};
pub use scheduler::{CancelSet, Directive, MigratedSession, Policy, PopOutcome,
                    RebalanceHub, RemoteDonation, Scheduler, WorkerLoad};
pub use server::{client_request, client_request_stream, serve_tcp, RebalancePolicy,
                 ResponseStream, ServerHandle};
pub use worker::Worker;
