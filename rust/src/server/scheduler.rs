//! Request scheduler: a thread-safe queue with pluggable admission policies,
//! plus the cancellation rendezvous ([`CancelSet`]) and the cross-worker
//! rebalance rendezvous ([`RebalanceHub`]).
//!
//! The paper serves batch-1 requests; throughput comes from assigning queued
//! requests to engine workers, each of which time-slices steps across up to
//! `max_live` concurrent [`crate::engine::DecodeSession`]s. Policies: FIFO
//! (arrival order) and SJF (shortest-prompt-first, reduces head-of-line
//! blocking for mixed lengths). Workers block on [`Scheduler::pop`] only
//! when idle and poll [`Scheduler::try_pop`] between scheduling rounds while
//! they have live sessions (or [`Scheduler::pop_timeout`] when a rebalance
//! hub is attached, so idle workers still observe incoming migrations).

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

use crate::control::CtlCarry;
use crate::kv::SessionSnapshot;
use crate::net::Peers;
use crate::server::request::{Request, Response, StreamChunk};
use crate::tokenizer::Utf8StreamDecoder;
use crate::util::json::Json;
use crate::util::sync::{rank, RankedMutex};

/// Cancellation rendezvous between the server front and the workers: the
/// front marks ids, workers check the mark between steps — so a cancelled
/// in-flight request stops within one decode step.
#[derive(Debug)]
pub struct CancelSet {
    /// [`rank::CANCEL`]: marked while the server front holds the pending
    /// map (see `ServerHandle::cancel`), so it must rank above PENDING.
    ids: RankedMutex<HashSet<u64>>,
}

impl Default for CancelSet {
    fn default() -> Self {
        CancelSet { ids: RankedMutex::new(rank::CANCEL, "cancel.ids", HashSet::new()) }
    }
}

impl CancelSet {
    pub fn new() -> CancelSet {
        CancelSet::default()
    }

    /// Mark `id` for cancellation.
    pub fn request(&self, id: u64) {
        self.ids.lock().insert(id);
    }

    /// Is `id` marked? (Checked by workers between steps.)
    pub fn contains(&self, id: u64) -> bool {
        self.ids.lock().contains(&id)
    }

    /// Drop the mark (request retired or record delivered).
    pub fn clear(&self, id: u64) {
        self.ids.lock().remove(&id);
    }

    /// Outstanding marks. Diagnostics only: the dispatcher clears every id
    /// on retirement, so a churn run should end back at 0 — a growing set
    /// means a leak (a recycled id would be spuriously cancelled).
    pub fn len(&self) -> usize {
        self.ids.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    ShortestFirst,
}

impl Policy {
    pub fn parse(s: &str) -> Policy {
        match s {
            "sjf" | "shortest" => Policy::ShortestFirst,
            _ => Policy::Fifo,
        }
    }
}

struct Entry {
    req: Request,
    arrived: Instant,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Entry>,
    closed: bool,
}

pub struct Scheduler {
    /// [`rank::SCHED`]: popped entries are handed to workers with no other
    /// lock held; only HUB may be outstanding above it (rebalance donate).
    state: RankedMutex<State>,
    cv: Condvar,
    policy: Policy,
    /// back-pressure: reject when the queue is deeper than this.
    max_depth: usize,
}

pub struct Popped {
    pub req: Request,
    pub queued_ms: f64,
}

impl Scheduler {
    pub fn new(policy: Policy, max_depth: usize) -> Self {
        Scheduler {
            state: RankedMutex::new(rank::SCHED, "sched.state", State::default()),
            cv: Condvar::new(),
            policy,
            max_depth: max_depth.max(1),
        }
    }

    /// Enqueue; Err(req) when the queue is full (back-pressure signal).
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.state.lock();
        if st.queue.len() >= self.max_depth {
            return Err(req);
        }
        st.queue.push_back(Entry { req, arrived: Instant::now() });
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop(&self) -> Option<Popped> {
        let mut st = self.state.lock();
        loop {
            if let Some(idx) = self.select(&st.queue) {
                let e = st.queue.remove(idx).unwrap();
                return Some(Popped {
                    req: e.req,
                    queued_ms: e.arrived.elapsed().as_secs_f64() * 1e3,
                });
            }
            if st.closed {
                return None;
            }
            st = st.wait_on(&self.cv);
        }
    }

    /// Bounded-wait pop: like [`Scheduler::pop`] but gives up after
    /// `timeout`, distinguishing "nothing arrived yet" ([`PopOutcome::Empty`])
    /// from "closed and drained" ([`PopOutcome::Closed`]). Idle workers use
    /// this instead of the blocking pop when a [`RebalanceHub`] is attached,
    /// so they periodically return to their serve loop and adopt sessions
    /// migrated to them.
    pub fn pop_timeout(&self, timeout: Duration) -> PopOutcome {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock();
        loop {
            if let Some(idx) = self.select(&st.queue) {
                let e = st.queue.remove(idx).unwrap();
                return PopOutcome::Got(Popped {
                    req: e.req,
                    queued_ms: e.arrived.elapsed().as_secs_f64() * 1e3,
                });
            }
            if st.closed {
                return PopOutcome::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopOutcome::Empty;
            }
            let (g, _) = st.wait_timeout_on(&self.cv, deadline - now);
            st = g;
        }
    }

    /// Non-blocking pop; None when the queue is currently empty (or closed).
    /// Workers with live sessions use this between scheduling rounds so a
    /// long-running request never blocks admission of new ones.
    pub fn try_pop(&self) -> Option<Popped> {
        let mut st = self.state.lock();
        let idx = self.select(&st.queue)?;
        let e = st.queue.remove(idx).unwrap();
        Some(Popped {
            req: e.req,
            queued_ms: e.arrived.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Remove a still-queued request; false when `id` is not in the queue
    /// (it already reached a worker, finished, or never existed).
    pub fn cancel(&self, id: u64) -> bool {
        let mut st = self.state.lock();
        match st.queue.iter().position(|e| e.req.id == id) {
            Some(pos) => {
                st.queue.remove(pos);
                true
            }
            None => false,
        }
    }

    fn select(&self, q: &VecDeque<Entry>) -> Option<usize> {
        if q.is_empty() {
            return None;
        }
        match self.policy {
            Policy::Fifo => Some(0),
            Policy::ShortestFirst => {
                let mut best = 0;
                for i in 1..q.len() {
                    if q[i].req.prompt.len() < q[best].req.prompt.len() {
                        best = i;
                    }
                }
                Some(best)
            }
        }
    }

    pub fn close(&self) {
        self.state.lock().closed = true;
        self.cv.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.state.lock().queue.len()
    }
}

/// Result of a bounded scheduler wait ([`Scheduler::pop_timeout`]).
pub enum PopOutcome {
    Got(Popped),
    /// The timeout elapsed with the queue still empty (scheduler open).
    Empty,
    /// The scheduler is closed and drained: no request will ever arrive.
    Closed,
}

// ---------------------------------------------------------------------------
// cross-worker rebalance rendezvous
// ---------------------------------------------------------------------------

/// Per-worker load snapshot, published by the worker loop once per
/// scheduling round and read by the server's rebalance policy.
#[derive(Debug, Clone, Copy)]
pub struct WorkerLoad {
    /// device-resident sessions this round.
    pub live: usize,
    /// host-parked (suspended) sessions this round.
    pub parked: usize,
    /// false once the worker left its serve loop — never a donor or a
    /// target afterwards.
    pub alive: bool,
}

impl WorkerLoad {
    /// Total session depth (live + parked) — the quantity the rebalance
    /// policy equalizes.
    pub fn depth(&self) -> usize {
        self.live + self.parked
    }
}

/// A parked session in flight between workers: the donor's streaming state
/// (chunk sequence, held-back UTF-8 bytes, deadline) plus the portable
/// [`SessionSnapshot`]. Snapshots are host data, so handing one across the
/// hub is the whole migration — the adopter parks it in its own
/// [`crate::kv::KvManager`] and revives it like any local parked session.
pub struct MigratedSession {
    /// adopting worker id.
    pub to: usize,
    pub id: u64,
    pub stream: bool,
    pub queued_ms: f64,
    pub seq: u64,
    pub dec: Utf8StreamDecoder,
    pub deadline: Option<Instant>,
    pub snap: SessionSnapshot,
    /// controller bookkeeping travelling with the session (None = the
    /// session is not controller-tracked).
    pub ctl: Option<CtlCarry>,
    /// tracing identity minted at admission (0 = untraced). Travels with
    /// the session across workers AND processes so a migrated session's
    /// spans stitch into one timeline (DESIGN.md §8).
    pub trace_id: u64,
}

impl MigratedSession {
    /// Final-record parts for a migration that can no longer be served
    /// (its worker is gone): the held-back stream-decoder tail to flush
    /// first (streaming sessions only), then the Failed record. Every
    /// failure path uses this so a migrated stream never ends on a
    /// truncated UTF-8 sequence.
    pub fn into_failure(mut self, why: &str) -> (Option<StreamChunk>, Response) {
        let tail = if self.stream {
            let t = self.dec.finish();
            (!t.is_empty()).then(|| StreamChunk {
                id: self.id,
                seq: self.seq + 1,
                delta: t,
            })
        } else {
            None
        };
        (tail, Response::err(self.id, format!("{why} (session {})", self.id)))
    }

    /// Wire-transfer header for this migration: everything the adopter
    /// needs besides the `LAKV1` snapshot payload itself (which travels as
    /// checksummed chunks). The inverse is [`MigratedSession::from_wire`].
    pub fn wire_meta(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("stream", Json::Bool(self.stream)),
            ("queued_ms", Json::num(self.queued_ms)),
            ("seq", Json::num(self.seq as f64)),
        ];
        let pending = self.dec.pending();
        if !pending.is_empty() {
            let hx: String = pending.iter().map(|b| format!("{b:02x}")).collect();
            fields.push(("dec", Json::str(hx)));
        }
        if let Some(d) = self.deadline {
            // Instants don't cross processes: ship the remaining budget and
            // let the adopter re-anchor it on arrival.
            let remaining = d.saturating_duration_since(Instant::now());
            fields.push(("deadline_ms", Json::num(remaining.as_secs_f64() * 1e3)));
        }
        if let Some(ctl) = &self.ctl {
            let ids = ctl.prompt_ids.iter().map(|&t| Json::num(t as f64)).collect();
            let mut c = vec![
                ("prompt_ids", Json::arr(ids)),
                ("adaptive", Json::Bool(ctl.adaptive)),
            ];
            if let Some(t) = &ctl.tenant {
                c.push(("tenant", Json::str(t.clone())));
            }
            fields.push(("ctl", Json::obj(c)));
        }
        if self.trace_id != 0 {
            // hex string, not a JSON number: trace ids pack the donor pid
            // into the high 32 bits and would lose precision above 2^53 in
            // an f64-backed number field.
            fields.push(("trace_id", Json::str(crate::trace::hex_id(self.trace_id))));
        }
        Json::obj(fields)
    }

    /// Rebuild a migration from a wire-transfer header plus the decoded
    /// snapshot. `to` is the adopting process's chosen local worker and `id`
    /// its fresh request id — the donor keeps the client-facing id (carried
    /// in the meta) and rewrites reply ids on the way back.
    pub fn from_wire(
        meta: &Json,
        snap: SessionSnapshot,
        to: usize,
        id: u64,
    ) -> MigratedSession {
        let dec = match meta.get("dec").and_then(Json::as_str) {
            Some(hx) => Utf8StreamDecoder::from_pending(
                (0..hx.len() / 2)
                    .filter_map(|i| u8::from_str_radix(&hx[2 * i..2 * i + 2], 16).ok())
                    .collect(),
            ),
            None => Utf8StreamDecoder::new(),
        };
        let deadline = meta
            .get("deadline_ms")
            .and_then(Json::as_f64)
            .map(|ms| Instant::now() + Duration::from_secs_f64((ms / 1e3).max(0.0)));
        let ctl = meta.get("ctl").map(|c| CtlCarry {
            prompt_ids: c
                .get("prompt_ids")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Json::as_usize)
                        .map(|v| v as u32)
                        .collect()
                })
                .unwrap_or_default(),
            tenant: c.get("tenant").and_then(Json::as_str).map(str::to_string),
            adaptive: c.get("adaptive").and_then(Json::as_bool).unwrap_or(false),
        });
        MigratedSession {
            to,
            id,
            stream: meta.get("stream").and_then(Json::as_bool).unwrap_or(true),
            queued_ms: meta.get("queued_ms").and_then(Json::as_f64).unwrap_or(0.0),
            seq: meta.get("seq").and_then(Json::as_i64).unwrap_or(0) as u64,
            dec,
            deadline,
            snap,
            ctl,
            trace_id: meta
                .get("trace_id")
                .and_then(Json::as_str)
                .and_then(crate::trace::parse_hex_id)
                .unwrap_or(0),
        }
    }
}

/// A donation target for one worker: another worker in this process, or a
/// peer process reachable over the wire (an index into the server's
/// heartbeat-maintained peer table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Directive {
    Local(usize),
    Remote(usize),
}

/// A donation addressed to a remote peer, consumed by the server's network
/// transport thread (which streams the snapshot via `net::send_session`).
pub struct RemoteDonation {
    /// index into the server's peer table.
    pub peer: usize,
    /// the outbound migration. `m.to` is the DONOR's own worker id, so a
    /// bounce routes home through the ordinary [`RebalanceHub::transfer`]
    /// path and the donor re-parks it like any local bounce.
    pub m: MigratedSession,
}

/// The hub's attachment to the network transport (present only when the
/// server was started with `--peers`).
struct RemoteLink {
    tx: Sender<RemoteDonation>,
    peers: Arc<Peers>,
}

struct HubState {
    loads: Vec<WorkerLoad>,
    /// pending donation directive per worker: `directives[w] = Some(d)`
    /// asks worker `w` to move its coldest parked session to the local
    /// worker or remote peer named by `d`.
    directives: Vec<Option<Directive>>,
    /// in-flight migrations, queued per adopting worker.
    queues: Vec<VecDeque<MigratedSession>>,
}

/// Rendezvous for cross-worker session rebalancing. Three parties meet
/// here: workers publish their load and poll for directives/migrations
/// every scheduling round, and the server's rebalance thread turns load
/// imbalance into donation directives. All state sits behind one lock so
/// worker exit ([`RebalanceHub::mark_exited`]) atomically rejects future
/// transfers while draining the already-queued ones — a migration is never
/// silently stranded on a dead worker.
pub struct RebalanceHub {
    /// [`rank::HUB`]: outermost lock in the stack — rebalance decisions
    /// fan out into scheduler/kv work, never the other way around.
    st: RankedMutex<HubState>,
    moves: AtomicU64,
    /// network transport attachment (None = single-process serving). Same
    /// HUB rank as `st`: the two are never held together (equal ranks are
    /// mutually leaf-only under the tracker's strict ordering).
    remote: RankedMutex<Option<RemoteLink>>,
}

impl RebalanceHub {
    pub fn new(workers: usize) -> RebalanceHub {
        RebalanceHub {
            st: RankedMutex::new(
                rank::HUB,
                "hub.st",
                HubState {
                    loads: vec![WorkerLoad { live: 0, parked: 0, alive: true }; workers],
                    directives: vec![None; workers],
                    queues: (0..workers).map(|_| VecDeque::new()).collect(),
                },
            ),
            moves: AtomicU64::new(0),
            remote: RankedMutex::new(rank::HUB, "hub.remote", None),
        }
    }

    pub fn workers(&self) -> usize {
        self.st.lock().loads.len()
    }

    /// Publish worker `w`'s depth for this round (the queue-depth report
    /// the rebalance policy reads).
    pub fn report_load(&self, w: usize, live: usize, parked: usize) {
        let mut st = self.st.lock();
        if let Some(l) = st.loads.get_mut(w) {
            l.live = live;
            l.parked = parked;
        }
    }

    /// Point-in-time copy of every worker's load.
    pub fn loads(&self) -> Vec<WorkerLoad> {
        self.st.lock().loads.clone()
    }

    /// Ask worker `from` to move its coldest parked session to worker `to`.
    /// Returns false (no directive recorded) when either end is unknown or
    /// exited, `from == to`, or a directive for `from` is already pending.
    pub fn direct(&self, from: usize, to: usize) -> bool {
        let mut st = self.st.lock();
        let n = st.loads.len();
        if from >= n || to >= n || from == to {
            return false;
        }
        if !st.loads[from].alive || !st.loads[to].alive || st.directives[from].is_some()
        {
            return false;
        }
        st.directives[from] = Some(Directive::Local(to));
        true
    }

    /// Ask worker `from` to ship its coldest parked session to remote peer
    /// `peer`. Same single-slot rule as [`RebalanceHub::direct`]; the
    /// target's aliveness lives in the heartbeat's peer table (peers are
    /// not workers, so `loads` does not cover them) and is checked by the
    /// policy thread when it picks the peer.
    pub fn direct_remote(&self, from: usize, peer: usize) -> bool {
        let mut st = self.st.lock();
        if from >= st.loads.len()
            || !st.loads[from].alive
            || st.directives[from].is_some()
        {
            return false;
        }
        st.directives[from] = Some(Directive::Remote(peer));
        true
    }

    /// Consume the pending donation directive for worker `w`, if any.
    /// Directives whose LOCAL target exited between `direct` and now are
    /// dropped: the donation could only bounce, but the donor would still
    /// burn a round reviving and re-parking the session (and the directive
    /// would read as progress in the metrics).
    pub fn take_directive(&self, w: usize) -> Option<Directive> {
        let mut st = self.st.lock();
        let d = st.directives.get_mut(w)?.take()?;
        if let Directive::Local(t) = d {
            if !st.loads.get(t).is_some_and(|l| l.alive) {
                return None;
            }
        }
        Some(d)
    }

    /// Hand a parked session to its adopting worker. Fails (returning the
    /// migration so the donor re-parks it locally) when the target already
    /// exited — the check and the enqueue are atomic with
    /// [`RebalanceHub::mark_exited`], so acceptance means the adopter will
    /// observe it before exiting.
    pub fn transfer(&self, m: MigratedSession) -> Result<(), MigratedSession> {
        let mut st = self.st.lock();
        if m.to >= st.loads.len() || !st.loads[m.to].alive {
            return Err(m);
        }
        let to = m.to;
        st.queues[to].push_back(m);
        self.moves.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Migrations addressed to worker `w` (drained; adoption order = send
    /// order).
    pub fn take_transfers(&self, w: usize) -> Vec<MigratedSession> {
        let mut st = self.st.lock();
        match st.queues.get_mut(w) {
            Some(q) => q.drain(..).collect(),
            None => Vec::new(),
        }
    }

    /// Worker `w` is leaving its serve loop: refuse future transfers and
    /// return any still queued for it (the exiting worker either serves
    /// them or fails them — never drops them silently).
    pub fn mark_exited(&self, w: usize) -> Vec<MigratedSession> {
        let mut st = self.st.lock();
        if let Some(l) = st.loads.get_mut(w) {
            l.alive = false;
            l.live = 0;
            l.parked = 0;
        }
        if let Some(d) = st.directives.get_mut(w) {
            *d = None;
        }
        match st.queues.get_mut(w) {
            Some(q) => q.drain(..).collect(),
            None => Vec::new(),
        }
    }

    /// Drain every queued migration (the server's shutdown sweep: after all
    /// workers joined, anything left here gets a final error record so no
    /// client hangs).
    pub fn drain(&self) -> Vec<MigratedSession> {
        let mut st = self.st.lock();
        let mut out = Vec::new();
        for q in st.queues.iter_mut() {
            out.extend(q.drain(..));
        }
        out
    }

    /// Total accepted transfers so far.
    pub fn moves(&self) -> u64 {
        self.moves.load(Ordering::Relaxed)
    }

    /// Attach the network transport: remote donations flow through `tx` to
    /// the server's transport thread, and `peers` is the
    /// heartbeat-maintained table used to pick decode targets.
    pub fn set_remote(&self, tx: Sender<RemoteDonation>, peers: Arc<Peers>) {
        *self.remote.lock() = Some(RemoteLink { tx, peers });
    }

    /// Drop the transport link (shutdown): the transport thread's receiver
    /// disconnects once in-flight donations drain, and subsequent
    /// [`RebalanceHub::donate_remote`] calls bounce immediately.
    pub fn clear_remote(&self) {
        *self.remote.lock() = None;
    }

    /// Ship a migration to remote peer `peer`; returns the migration when
    /// no transport is attached (or it already shut down) so the donor
    /// re-parks it locally.
    pub fn donate_remote(
        &self,
        peer: usize,
        m: MigratedSession,
    ) -> Result<(), MigratedSession> {
        let link = self.remote.lock();
        match link.as_ref() {
            Some(l) => l.tx.send(RemoteDonation { peer, m }).map_err(|e| e.0.m),
            None => Err(m),
        }
    }

    /// First alive non-prefill peer, if a transport is attached — where a
    /// prefill-only worker ships its freshly-committed sessions. None means
    /// "decode locally" (degraded but correct).
    pub fn remote_decode_peer(&self) -> Option<usize> {
        let peers = self.remote.lock().as_ref()?.peers.clone();
        peers.snapshot().iter().position(|p| p.alive && !p.prefill_only)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64, prompt: &str) -> Request {
        let mut r = Request::new(prompt);
        r.id = id;
        r
    }

    #[test]
    fn fifo_order() {
        let s = Scheduler::new(Policy::Fifo, 16);
        s.push(req(1, "aaa")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert_eq!(s.pop().unwrap().req.id, 1);
        assert_eq!(s.pop().unwrap().req.id, 2);
    }

    #[test]
    fn sjf_prefers_short_prompts() {
        let s = Scheduler::new(Policy::ShortestFirst, 16);
        s.push(req(1, "aaaaaaaa")).unwrap();
        s.push(req(2, "b")).unwrap();
        s.push(req(3, "cc")).unwrap();
        assert_eq!(s.pop().unwrap().req.id, 2);
        assert_eq!(s.pop().unwrap().req.id, 3);
        assert_eq!(s.pop().unwrap().req.id, 1);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let s = Scheduler::new(Policy::Fifo, 2);
        s.push(req(1, "a")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert!(s.push(req(3, "c")).is_err());
        assert_eq!(s.depth(), 2);
    }

    #[test]
    fn close_unblocks_pop() {
        let s = Arc::new(Scheduler::new(Policy::Fifo, 4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.pop().is_none());
        crate::util::sync::nap(std::time::Duration::from_millis(20));
        s.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn drains_after_close() {
        let s = Scheduler::new(Policy::Fifo, 4);
        s.push(req(1, "a")).unwrap();
        s.close();
        assert!(s.pop().is_some());
        assert!(s.pop().is_none());
    }

    #[test]
    fn try_pop_never_blocks() {
        let s = Scheduler::new(Policy::Fifo, 4);
        assert!(s.try_pop().is_none());
        s.push(req(1, "a")).unwrap();
        assert_eq!(s.try_pop().unwrap().req.id, 1);
        assert!(s.try_pop().is_none());
    }

    #[test]
    fn cancel_removes_queued_request() {
        let s = Scheduler::new(Policy::Fifo, 4);
        s.push(req(1, "a")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert!(s.cancel(1));
        assert!(!s.cancel(1), "double cancel must report not-found");
        assert!(!s.cancel(99));
        assert_eq!(s.depth(), 1);
        assert_eq!(s.try_pop().unwrap().req.id, 2);
    }

    #[test]
    fn cancel_set_roundtrip() {
        let c = CancelSet::new();
        assert!(!c.contains(5));
        c.request(5);
        assert!(c.contains(5));
        c.clear(5);
        assert!(!c.contains(5));
    }

    #[test]
    fn pop_timeout_distinguishes_empty_from_closed() {
        let s = Scheduler::new(Policy::Fifo, 4);
        // empty + open: times out
        let t0 = std::time::Instant::now();
        assert!(matches!(s.pop_timeout(Duration::from_millis(10)), PopOutcome::Empty));
        assert!(t0.elapsed() >= Duration::from_millis(10));
        // queued request: returned immediately
        s.push(req(1, "a")).unwrap();
        match s.pop_timeout(Duration::from_millis(10)) {
            PopOutcome::Got(p) => assert_eq!(p.req.id, 1),
            _ => panic!("queued request must pop"),
        }
        // closed + drained: Closed, without waiting out the timeout
        s.close();
        assert!(matches!(s.pop_timeout(Duration::from_secs(30)), PopOutcome::Closed));
    }

    fn mig(to: usize, id: u64) -> MigratedSession {
        MigratedSession {
            to,
            id,
            stream: false,
            queued_ms: 0.0,
            seq: 0,
            dec: Utf8StreamDecoder::new(),
            deadline: None,
            snap: SessionSnapshot {
                model: "tiny".into(),
                engine: crate::kv::EngineState::Autoregressive {
                    cur: id as u32,
                    rng: [1, 2, 3, 4],
                },
                kv: crate::runtime::HostKv {
                    len: 1,
                    elem: "i32".into(),
                    data: vec![0; 8],
                },
                draft_kv: None,
                params: crate::engine::GenParams::default(),
                out: vec![],
                stats: crate::metrics::DecodeStats::default(),
                wall_offset: Duration::ZERO,
                pool: crate::ngram::PoolHandle::none(),
            },
            ctl: None,
            trace_id: 0,
        }
    }

    #[test]
    fn hub_load_directive_transfer_lifecycle() {
        let hub = RebalanceHub::new(2);
        assert_eq!(hub.workers(), 2);
        hub.report_load(0, 3, 2);
        hub.report_load(1, 1, 0);
        let loads = hub.loads();
        assert_eq!((loads[0].depth(), loads[1].depth()), (5, 1));
        assert!(loads.iter().all(|l| l.alive));

        // directive: recorded once, consumed once
        assert!(hub.direct(0, 1));
        assert!(!hub.direct(0, 1), "second directive must wait for the first");
        assert!(!hub.direct(0, 0), "self-donation is meaningless");
        assert!(!hub.direct(5, 1), "unknown donor");
        assert_eq!(hub.take_directive(0), Some(Directive::Local(1)));
        assert_eq!(hub.take_directive(0), None);

        // transfer: queued for the adopter, counted
        assert!(hub.transfer(mig(1, 7)).is_ok());
        assert_eq!(hub.moves(), 1);
        let got = hub.take_transfers(1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 7);
        assert!(hub.take_transfers(1).is_empty());
    }

    #[test]
    fn hub_exited_worker_rejects_transfers_and_drains_pending() {
        let hub = RebalanceHub::new(2);
        assert!(hub.transfer(mig(1, 7)).is_ok());
        // exit returns what was already queued...
        let pending = hub.mark_exited(1);
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].id, 7);
        // ...and later transfers bounce back to the donor
        let rejected = hub.transfer(mig(1, 8)).unwrap_err();
        assert_eq!(rejected.id, 8);
        assert!(!hub.direct(0, 1), "exited workers are not targets");
        assert!(!hub.loads()[1].alive);
        assert_eq!(hub.loads()[1].depth(), 0, "exit zeroes the load report");
    }

    #[test]
    fn hub_drain_sweeps_every_queue() {
        let hub = RebalanceHub::new(3);
        assert!(hub.transfer(mig(1, 1)).is_ok());
        assert!(hub.transfer(mig(2, 2)).is_ok());
        assert!(hub.transfer(mig(2, 3)).is_ok());
        let mut ids: Vec<u64> = hub.drain().into_iter().map(|m| m.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3]);
        assert!(hub.drain().is_empty());
    }

    #[test]
    fn directive_to_exited_target_is_dropped_at_take_time() {
        let hub = RebalanceHub::new(2);
        assert!(hub.direct(0, 1));
        // the target exits between the policy's direct() and the donor's
        // take_directive(): the stale directive must evaporate instead of
        // sending a donation that can only bounce
        hub.mark_exited(1);
        assert_eq!(hub.take_directive(0), None);
        // the slot is freed; remote directives are exempt from the local
        // aliveness check (peer liveness lives in the heartbeat table)
        assert!(hub.direct_remote(0, 3));
        assert_eq!(hub.take_directive(0), Some(Directive::Remote(3)));
    }

    #[test]
    fn remote_donation_without_transport_bounces() {
        let hub = RebalanceHub::new(1);
        assert!(hub.remote_decode_peer().is_none());
        let back = hub.donate_remote(0, mig(0, 9)).unwrap_err();
        assert_eq!(back.id, 9);
        // attach a transport: donations flow to the receiver, and the
        // decode-peer pick skips dead and prefill-only peers
        let (tx, rx) = std::sync::mpsc::channel();
        let peers = Arc::new(Peers::new(&[
            "127.0.0.1:1".into(),
            "127.0.0.1:2".into(),
        ]));
        peers.update(0, true, true, 0, 0); // alive but prefill-only
        peers.update(1, true, false, 0, 0);
        hub.set_remote(tx, peers);
        assert_eq!(hub.remote_decode_peer(), Some(1));
        assert!(hub.donate_remote(1, mig(0, 10)).is_ok());
        let got = rx.recv().unwrap();
        assert_eq!((got.peer, got.m.id), (1, 10));
        // cleared link: the receiver disconnects, donations bounce again
        hub.clear_remote();
        assert!(rx.recv().is_err(), "transport receiver must disconnect");
        assert!(hub.donate_remote(1, mig(0, 11)).is_err());
        assert!(hub.remote_decode_peer().is_none());
    }

    #[test]
    fn wire_meta_round_trips_streaming_state() {
        let mut m = mig(1, 42);
        m.stream = true;
        m.seq = 3;
        m.queued_ms = 1.5;
        m.dec = Utf8StreamDecoder::from_pending(vec![0xe2, 0x82]);
        m.deadline = Some(Instant::now() + Duration::from_secs(30));
        m.ctl = Some(CtlCarry {
            prompt_ids: vec![5, 6, 7],
            tenant: Some("acme".into()),
            adaptive: true,
        });
        // a trace id above 2^53 must survive the f64-backed JSON layer
        m.trace_id = (0xdead_beef_u64 << 32) | 7;
        let meta = m.wire_meta();
        // the donor-side client id travels in the meta (reply rewriting)
        assert_eq!(meta.get("id").and_then(Json::as_usize), Some(42));
        // headers survive the JSON writer/parser round trip
        let meta = Json::parse(&meta.dump()).unwrap();
        let back = MigratedSession::from_wire(&meta, mig(0, 0).snap, 2, 99);
        assert_eq!(back.to, 2, "adopter picks its own local worker");
        assert_eq!(back.id, 99, "adopter assigns a fresh local id");
        assert!(back.stream);
        assert_eq!(back.seq, 3);
        assert!((back.queued_ms - 1.5).abs() < 1e-9);
        assert_eq!(back.dec.pending(), &[0xe2, 0x82]);
        let remaining = back
            .deadline
            .expect("deadline must survive the wire")
            .saturating_duration_since(Instant::now());
        assert!(remaining <= Duration::from_secs(30));
        assert!(remaining > Duration::from_secs(25), "budget must re-anchor");
        let ctl = back.ctl.expect("controller carry must survive");
        assert_eq!(ctl.prompt_ids, vec![5, 6, 7]);
        assert_eq!(ctl.tenant.as_deref(), Some("acme"));
        assert!(ctl.adaptive);
        assert_eq!(back.trace_id, (0xdead_beef_u64 << 32) | 7);
        // a minimal meta (non-streaming, no ctl, untraced) also rebuilds
        let lean = mig(0, 8).wire_meta();
        assert!(lean.get("trace_id").is_none(), "untraced ships no id");
        let back = MigratedSession::from_wire(&lean, mig(0, 0).snap, 0, 1);
        assert!(!back.stream);
        assert!(back.dec.pending().is_empty());
        assert!(back.deadline.is_none() && back.ctl.is_none());
        assert_eq!(back.trace_id, 0);
    }
}
