//! Request scheduler: a thread-safe queue with pluggable admission policies.
//!
//! The paper serves batch-1 requests; throughput comes from assigning queued
//! requests to idle engine workers. Policies: FIFO (arrival order) and SJF
//! (shortest-prompt-first, reduces head-of-line blocking for mixed lengths).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::server::request::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    ShortestFirst,
}

impl Policy {
    pub fn parse(s: &str) -> Policy {
        match s {
            "sjf" | "shortest" => Policy::ShortestFirst,
            _ => Policy::Fifo,
        }
    }
}

struct Entry {
    req: Request,
    arrived: Instant,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Entry>,
    closed: bool,
}

pub struct Scheduler {
    state: Mutex<State>,
    cv: Condvar,
    policy: Policy,
    /// back-pressure: reject when the queue is deeper than this.
    max_depth: usize,
}

pub struct Popped {
    pub req: Request,
    pub queued_ms: f64,
}

impl Scheduler {
    pub fn new(policy: Policy, max_depth: usize) -> Self {
        Scheduler {
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
            policy,
            max_depth: max_depth.max(1),
        }
    }

    /// Enqueue; Err(req) when the queue is full (back-pressure signal).
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.queue.len() >= self.max_depth {
            return Err(req);
        }
        st.queue.push_back(Entry { req, arrived: Instant::now() });
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop(&self) -> Option<Popped> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(idx) = self.select(&st.queue) {
                let e = st.queue.remove(idx).unwrap();
                return Some(Popped {
                    req: e.req,
                    queued_ms: e.arrived.elapsed().as_secs_f64() * 1e3,
                });
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn select(&self, q: &VecDeque<Entry>) -> Option<usize> {
        if q.is_empty() {
            return None;
        }
        match self.policy {
            Policy::Fifo => Some(0),
            Policy::ShortestFirst => {
                let mut best = 0;
                for i in 1..q.len() {
                    if q[i].req.prompt.len() < q[best].req.prompt.len() {
                        best = i;
                    }
                }
                Some(best)
            }
        }
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64, prompt: &str) -> Request {
        Request { id, prompt: prompt.into(), ..Default::default() }
    }

    #[test]
    fn fifo_order() {
        let s = Scheduler::new(Policy::Fifo, 16);
        s.push(req(1, "aaa")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert_eq!(s.pop().unwrap().req.id, 1);
        assert_eq!(s.pop().unwrap().req.id, 2);
    }

    #[test]
    fn sjf_prefers_short_prompts() {
        let s = Scheduler::new(Policy::ShortestFirst, 16);
        s.push(req(1, "aaaaaaaa")).unwrap();
        s.push(req(2, "b")).unwrap();
        s.push(req(3, "cc")).unwrap();
        assert_eq!(s.pop().unwrap().req.id, 2);
        assert_eq!(s.pop().unwrap().req.id, 3);
        assert_eq!(s.pop().unwrap().req.id, 1);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let s = Scheduler::new(Policy::Fifo, 2);
        s.push(req(1, "a")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert!(s.push(req(3, "c")).is_err());
        assert_eq!(s.depth(), 2);
    }

    #[test]
    fn close_unblocks_pop() {
        let s = Arc::new(Scheduler::new(Policy::Fifo, 4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn drains_after_close() {
        let s = Scheduler::new(Policy::Fifo, 4);
        s.push(req(1, "a")).unwrap();
        s.close();
        assert!(s.pop().is_some());
        assert!(s.pop().is_none());
    }
}
