//! Request scheduler: a thread-safe queue with pluggable admission policies,
//! plus the cancellation rendezvous ([`CancelSet`]).
//!
//! The paper serves batch-1 requests; throughput comes from assigning queued
//! requests to engine workers, each of which time-slices steps across up to
//! `max_live` concurrent [`crate::engine::DecodeSession`]s. Policies: FIFO
//! (arrival order) and SJF (shortest-prompt-first, reduces head-of-line
//! blocking for mixed lengths). Workers block on [`Scheduler::pop`] only
//! when idle and poll [`Scheduler::try_pop`] between scheduling rounds while
//! they have live sessions.

use std::collections::{HashSet, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::server::request::Request;

/// Cancellation rendezvous between the server front and the workers: the
/// front marks ids, workers check the mark between steps — so a cancelled
/// in-flight request stops within one decode step.
#[derive(Debug, Default)]
pub struct CancelSet {
    ids: Mutex<HashSet<u64>>,
}

impl CancelSet {
    pub fn new() -> CancelSet {
        CancelSet::default()
    }

    /// Mark `id` for cancellation.
    pub fn request(&self, id: u64) {
        self.ids.lock().unwrap().insert(id);
    }

    /// Is `id` marked? (Checked by workers between steps.)
    pub fn contains(&self, id: u64) -> bool {
        self.ids.lock().unwrap().contains(&id)
    }

    /// Drop the mark (request retired or record delivered).
    pub fn clear(&self, id: u64) {
        self.ids.lock().unwrap().remove(&id);
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    ShortestFirst,
}

impl Policy {
    pub fn parse(s: &str) -> Policy {
        match s {
            "sjf" | "shortest" => Policy::ShortestFirst,
            _ => Policy::Fifo,
        }
    }
}

struct Entry {
    req: Request,
    arrived: Instant,
}

#[derive(Default)]
struct State {
    queue: VecDeque<Entry>,
    closed: bool,
}

pub struct Scheduler {
    state: Mutex<State>,
    cv: Condvar,
    policy: Policy,
    /// back-pressure: reject when the queue is deeper than this.
    max_depth: usize,
}

pub struct Popped {
    pub req: Request,
    pub queued_ms: f64,
}

impl Scheduler {
    pub fn new(policy: Policy, max_depth: usize) -> Self {
        Scheduler {
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
            policy,
            max_depth: max_depth.max(1),
        }
    }

    /// Enqueue; Err(req) when the queue is full (back-pressure signal).
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.queue.len() >= self.max_depth {
            return Err(req);
        }
        st.queue.push_back(Entry { req, arrived: Instant::now() });
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop(&self) -> Option<Popped> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(idx) = self.select(&st.queue) {
                let e = st.queue.remove(idx).unwrap();
                return Some(Popped {
                    req: e.req,
                    queued_ms: e.arrived.elapsed().as_secs_f64() * 1e3,
                });
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Non-blocking pop; None when the queue is currently empty (or closed).
    /// Workers with live sessions use this between scheduling rounds so a
    /// long-running request never blocks admission of new ones.
    pub fn try_pop(&self) -> Option<Popped> {
        let mut st = self.state.lock().unwrap();
        let idx = self.select(&st.queue)?;
        let e = st.queue.remove(idx).unwrap();
        Some(Popped {
            req: e.req,
            queued_ms: e.arrived.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Remove a still-queued request; false when `id` is not in the queue
    /// (it already reached a worker, finished, or never existed).
    pub fn cancel(&self, id: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.queue.iter().position(|e| e.req.id == id) {
            Some(pos) => {
                st.queue.remove(pos);
                true
            }
            None => false,
        }
    }

    fn select(&self, q: &VecDeque<Entry>) -> Option<usize> {
        if q.is_empty() {
            return None;
        }
        match self.policy {
            Policy::Fifo => Some(0),
            Policy::ShortestFirst => {
                let mut best = 0;
                for i in 1..q.len() {
                    if q[i].req.prompt.len() < q[best].req.prompt.len() {
                        best = i;
                    }
                }
                Some(best)
            }
        }
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64, prompt: &str) -> Request {
        Request { id, prompt: prompt.into(), ..Default::default() }
    }

    #[test]
    fn fifo_order() {
        let s = Scheduler::new(Policy::Fifo, 16);
        s.push(req(1, "aaa")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert_eq!(s.pop().unwrap().req.id, 1);
        assert_eq!(s.pop().unwrap().req.id, 2);
    }

    #[test]
    fn sjf_prefers_short_prompts() {
        let s = Scheduler::new(Policy::ShortestFirst, 16);
        s.push(req(1, "aaaaaaaa")).unwrap();
        s.push(req(2, "b")).unwrap();
        s.push(req(3, "cc")).unwrap();
        assert_eq!(s.pop().unwrap().req.id, 2);
        assert_eq!(s.pop().unwrap().req.id, 3);
        assert_eq!(s.pop().unwrap().req.id, 1);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let s = Scheduler::new(Policy::Fifo, 2);
        s.push(req(1, "a")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert!(s.push(req(3, "c")).is_err());
        assert_eq!(s.depth(), 2);
    }

    #[test]
    fn close_unblocks_pop() {
        let s = Arc::new(Scheduler::new(Policy::Fifo, 4));
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn drains_after_close() {
        let s = Scheduler::new(Policy::Fifo, 4);
        s.push(req(1, "a")).unwrap();
        s.close();
        assert!(s.pop().is_some());
        assert!(s.pop().is_none());
    }

    #[test]
    fn try_pop_never_blocks() {
        let s = Scheduler::new(Policy::Fifo, 4);
        assert!(s.try_pop().is_none());
        s.push(req(1, "a")).unwrap();
        assert_eq!(s.try_pop().unwrap().req.id, 1);
        assert!(s.try_pop().is_none());
    }

    #[test]
    fn cancel_removes_queued_request() {
        let s = Scheduler::new(Policy::Fifo, 4);
        s.push(req(1, "a")).unwrap();
        s.push(req(2, "b")).unwrap();
        assert!(s.cancel(1));
        assert!(!s.cancel(1), "double cancel must report not-found");
        assert!(!s.cancel(99));
        assert_eq!(s.depth(), 1);
        assert_eq!(s.try_pop().unwrap().req.id, 2);
    }

    #[test]
    fn cancel_set_roundtrip() {
        let c = CancelSet::new();
        assert!(!c.contains(5));
        c.request(5);
        assert!(c.contains(5));
        c.clear(5);
        assert!(!c.contains(5));
    }
}
