//! The serving front (vLLM-router-like, thread-based — no tokio offline):
//!
//!   TCP conn ──lines──> parse ──> Scheduler (FIFO/SJF, back-pressure)
//!                                   │ pop / try_pop
//!                              Worker pool (one PJRT runtime each,
//!                              time-sliced multi-session interleave)
//!                                   │ Reply::Chunk* + Reply::Done
//!                              dispatcher ──> per-request channel
//!
//! Also exposes an in-process `ServerHandle::submit` (returning a
//! [`ResponseStream`]) used by the examples, tests, and the e2e bench
//! driver, plus `ServerHandle::cancel` for queued or in-flight requests.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::info;
use crate::kv::snapshot::fnv64;
use crate::kv::{PrefixCache, SessionSnapshot};
use crate::metrics::Registry;
use crate::net::{self, Peers, SendOutcome, TransferOpts};
use crate::ngram::NgramCacheRegistry;
use crate::server::config::ServerConfig;
use crate::server::request::{Reply, Request, Response, StreamChunk};
use crate::server::scheduler::{CancelSet, MigratedSession, RebalanceHub,
                               RemoteDonation, Scheduler, WorkerLoad};
use crate::server::worker::Worker;
use crate::trace::Tracer;
use crate::util::json::Json;
use crate::util::sync::{rank, RankedMutex};

/// Decision logic of the cross-worker rebalancer: equalize per-worker
/// session depth (live + parked) by moving one parked snapshot per scan
/// from the deepest worker with parked sessions to the shallowest live
/// worker, whenever the gap is at least `min_gap`.
#[derive(Debug, Clone)]
pub struct RebalancePolicy {
    /// minimum (donor depth - target depth) before a move pays for itself:
    /// moving one session shrinks the gap by 2, so anything below 2 would
    /// oscillate.
    pub min_gap: usize,
}

impl Default for RebalancePolicy {
    fn default() -> Self {
        RebalancePolicy { min_gap: 2 }
    }
}

impl RebalancePolicy {
    /// Pick (donor, target) for one migration, or None when the cluster is
    /// balanced (or no donor has a parked session to give away). Pure —
    /// unit-tested directly; the rebalance thread feeds it the hub's load
    /// report.
    pub fn pick(&self, loads: &[WorkerLoad]) -> Option<(usize, usize)> {
        let mut donor: Option<usize> = None;
        let mut target: Option<usize> = None;
        for (i, l) in loads.iter().enumerate() {
            if !l.alive {
                continue;
            }
            if l.parked > 0
                && donor.is_none_or(|d: usize| l.depth() > loads[d].depth())
            {
                donor = Some(i);
            }
            if target.is_none_or(|t: usize| l.depth() < loads[t].depth()) {
                target = Some(i);
            }
        }
        let (d, t) = (donor?, target?);
        if d == t || loads[d].depth() < loads[t].depth() + self.min_gap.max(1) {
            return None;
        }
        Some((d, t))
    }
}

/// Per-request reply stream returned by [`ServerHandle::submit`]: zero or
/// more `Reply::Chunk`s (streaming requests only) followed by exactly one
/// `Reply::Done` with the final stats record. The `id` is the server-side
/// request id — pass it to [`ServerHandle::cancel`].
pub struct ResponseStream {
    pub id: u64,
    rx: Receiver<Reply>,
}

impl ResponseStream {
    /// Next event (blocking).
    pub fn recv(&self) -> Result<Reply> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("server shutting down"))
    }

    /// Non-blocking poll; None when nothing is pending.
    pub fn try_recv(&self) -> Option<Reply> {
        self.rx.try_recv().ok()
    }

    /// Drain chunks and return the final record (the final `text` is always
    /// the full completion, so non-streaming callers lose nothing).
    pub fn wait(self) -> Result<Response> {
        loop {
            match self.recv()? {
                Reply::Done(r) => return Ok(r),
                Reply::Chunk(_) => {}
            }
        }
    }
}

/// In-process handle: submit requests, receive reply streams, cancel, shut
/// down.
pub struct ServerHandle {
    sched: Arc<Scheduler>,
    /// [`rank::PENDING`]: held while marking the cancel set (see
    /// `ServerHandle::cancel`) so a submit/cancel race can't strand a mark.
    pending: Arc<RankedMutex<HashMap<u64, Sender<Reply>>>>,
    /// shared with the peer gateway: locally-submitted and wire-adopted
    /// requests draw fresh ids from the same counter.
    next_id: Arc<AtomicU64>,
    pub metrics: Arc<RankedMutex<Registry>>,
    /// cross-request n-gram caches (None when sharing is disabled).
    pub ngram_caches: Option<Arc<NgramCacheRegistry>>,
    /// prefix-reuse trie shared by all workers (None when disabled via
    /// `WorkerConfig::prefix_cache = false`).
    pub prefix_cache: Option<Arc<PrefixCache>>,
    /// cross-worker rebalance rendezvous (None when `ServerConfig::
    /// rebalance` is off or the server runs a single worker without
    /// networking).
    pub rebalance: Option<Arc<RebalanceHub>>,
    /// heartbeat-maintained remote peer table (None without
    /// `ServerConfig::peers`).
    pub peers: Option<Arc<Peers>>,
    /// span recorder shared by workers, the net layer, and the TCP front
    /// (None unless `ServerConfig::trace` is on).
    pub tracer: Option<Arc<Tracer>>,
    cancels: Arc<CancelSet>,
    /// donor ids of sessions adopted away over the wire, mapped to the
    /// owning peer: `cancel(id)` forwards the stop signal there so it still
    /// lands within one decode step. Entries are removed when the relay
    /// delivers the final record.
    remote_cancels: Arc<RankedMutex<HashMap<u64, (String, u64)>>>,
    worker_joins: Vec<std::thread::JoinHandle<()>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    rebalancer: Option<std::thread::JoinHandle<()>>,
    rebalance_stop: Arc<AtomicBool>,
    net_stop: Arc<AtomicBool>,
    net_joins: Vec<std::thread::JoinHandle<()>>,
    /// reply-relay threads, one per adopted-away session (spawned by the
    /// transport thread, joined at shutdown).
    relay_joins: Arc<RankedMutex<Vec<std::thread::JoinHandle<()>>>>,
    /// fault injection: planned cut offsets consumed by outbound snapshot
    /// transfers ([`ServerHandle::inject_net_cuts`]).
    net_cuts: Arc<RankedMutex<Vec<usize>>>,
}

impl ServerHandle {
    pub fn start(cfg: ServerConfig) -> Result<ServerHandle> {
        let sched = Arc::new(Scheduler::new(cfg.policy, cfg.queue_depth));
        let pending: Arc<RankedMutex<HashMap<u64, Sender<Reply>>>> =
            Arc::new(RankedMutex::new(rank::PENDING, "srv.pending", HashMap::new()));
        let metrics =
            Arc::new(RankedMutex::new(rank::LEAF, "metrics.registry", Registry::new()));
        let cancels = Arc::new(CancelSet::new());
        let ngram_caches = cfg.share_ngrams.then(|| {
            let ttl = cfg.ngram_ttl_ms.map(std::time::Duration::from_millis);
            Arc::new(NgramCacheRegistry::new().with_max_age(ttl))
        });
        // one prefix-reuse trie spans all workers: it stores host data
        // only, so sharing it is what lets a prompt prefilled on worker 0
        // skip prefill on worker 1
        let prefix_cache =
            cfg.worker.prefix_cache.then(|| Arc::new(PrefixCache::with_defaults()));
        // migrations need a donor and a distinct adopter: a single-worker
        // server has neither, so the hub (and its idle-poll cost) is skipped
        // — unless networking is on, where the adopter (or donor) lives in
        // another process and the hub is the local rendezvous for both
        // inbound adoptions and outbound donations
        let net_on = cfg.peer_addr.is_some() || !cfg.peers.is_empty();
        let rebalance = ((cfg.rebalance && cfg.workers > 1) || net_on)
            .then(|| Arc::new(RebalanceHub::new(cfg.workers.max(1))));
        let next_id = Arc::new(AtomicU64::new(1));
        // one span recorder spans workers, the net layer, and the TCP
        // front; when tracing is off every instrumentation site sees None
        // and the hot path stays untouched
        let tracer = cfg.trace.then(|| {
            Arc::new(Tracer::new(cfg.workers.max(1), cfg.trace_sample.max(1),
                                 cfg.trace_buf))
        });
        let remote_cancels: Arc<RankedMutex<HashMap<u64, (String, u64)>>> = Arc::new(
            RankedMutex::new(rank::PENDING, "srv.remote_cancels", HashMap::new()),
        );

        // peer listener binds BEFORE workers spawn so a bad --peer-addr
        // fails fast instead of leaking worker threads
        let net_stop = Arc::new(AtomicBool::new(false));
        let net_cuts: Arc<RankedMutex<Vec<usize>>> =
            Arc::new(RankedMutex::new(rank::LEAF, "net.cuts", Vec::new()));
        let relay_joins: Arc<RankedMutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(RankedMutex::new(rank::PENDING, "srv.relay_joins", Vec::new()));
        let mut net_joins = Vec::new();
        if let (Some(addr), Some(hub)) = (&cfg.peer_addr, &rebalance) {
            let gateway: Arc<dyn net::Adopt> = Arc::new(NetGateway {
                hub: hub.clone(),
                pending: pending.clone(),
                next_id: next_id.clone(),
                ngram_caches: ngram_caches.clone(),
                metrics: metrics.clone(),
                prefill_only: cfg.worker.prefill_only,
                cancels: cancels.clone(),
                tracer: tracer.clone(),
            });
            let listener =
                net::spawn_listener(addr, gateway, metrics.clone(), net_stop.clone())
                    .with_context(|| format!("binding peer listener {addr}"))?;
            net_joins.push(listener);
            info!("server", "peer listener on {addr}");
        }

        let (tx, rx): (Sender<Reply>, Receiver<Reply>) = channel();

        let mut worker_joins = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let sched_c = sched.clone();
            let tx_c = tx.clone();
            let mut wcfg = cfg.worker.clone();
            wcfg.batch_decode = cfg.batch_decode && cfg.worker.batch_decode;
            let caches_c = ngram_caches.clone();
            let cancels_c = cancels.clone();
            let metrics_c = metrics.clone();
            let prefix_c = prefix_cache.clone();
            let hub_c = rebalance.clone();
            let tracer_c = tracer.clone();
            worker_joins.push(std::thread::spawn(move || {
                match Worker::start(wid, wcfg, caches_c, cancels_c, Some(metrics_c),
                                    prefix_c, hub_c.clone(), tracer_c) {
                    Ok(w) => w.run(sched_c, tx_c),
                    Err(e) => {
                        // a worker that never ran must not stay a rebalance
                        // target, and anything already migrated to it must
                        // still end in a final record — not a silent hang
                        if let Some(hub) = &hub_c {
                            for m in hub.mark_exited(wid) {
                                let (tail, resp) = m.into_failure(
                                    "adopting worker failed to start");
                                if let Some(c) = tail {
                                    let _ = tx_c.send(Reply::Chunk(c));
                                }
                                let _ = tx_c.send(Reply::Done(resp));
                            }
                        }
                        eprintln!("[ERROR] worker {wid} failed to start: {e}");
                    }
                }
            }));
        }

        // outbound networking: heartbeat keeps the peer table's liveness
        // and load fresh; the transport thread streams donated snapshots
        // to peers and relays the adopter's replies back to the waiting
        // client (DESIGN.md §4c)
        let peers = (!cfg.peers.is_empty()).then(|| Arc::new(Peers::new(&cfg.peers)));
        if let (Some(peers_t), Some(hub)) = (&peers, &rebalance) {
            net_joins.push(net::spawn_heartbeat(
                peers_t.clone(),
                metrics.clone(),
                Duration::from_millis(cfg.heartbeat_ms.max(1)),
                net_stop.clone(),
            ));
            let (dtx, drx) = channel::<RemoteDonation>();
            hub.set_remote(dtx, peers_t.clone());
            net_joins.push(spawn_transport(NetTransport {
                rx: drx,
                hub: hub.clone(),
                peers: peers_t.clone(),
                metrics: metrics.clone(),
                relay_joins: relay_joins.clone(),
                cuts: net_cuts.clone(),
                stop: net_stop.clone(),
                replies: tx.clone(),
                tracer: tracer.clone(),
                remote_cancels: remote_cancels.clone(),
            }));
        }
        drop(tx);

        // rebalancer: periodically turn the hub's load report into one
        // donation directive (deepest parked donor -> shallowest target).
        // Remote peers join the scan as pseudo-workers appended after the
        // local ones, so the same policy picks local or remote targets.
        let rebalance_stop = Arc::new(AtomicBool::new(false));
        let want_rebalancer =
            cfg.rebalance && (cfg.workers > 1 || !cfg.peers.is_empty());
        let rebalancer = rebalance.as_ref().filter(|_| want_rebalancer).map(|hub| {
            let hub = hub.clone();
            let stop = rebalance_stop.clone();
            let metrics_c = metrics.clone();
            let peers_c = peers.clone();
            let policy = RebalancePolicy::default();
            let interval = Duration::from_millis(cfg.rebalance_interval_ms.max(1));
            std::thread::spawn(move || {
                let tick = interval.min(Duration::from_millis(25));
                let mut slept = Duration::ZERO;
                while !stop.load(Ordering::Relaxed) {
                    // sleep in short naps so shutdown joins promptly even
                    // with a long scan interval
                    crate::util::sync::nap(tick);
                    slept += tick;
                    if slept < interval {
                        continue;
                    }
                    slept = Duration::ZERO;
                    let mut loads = hub.loads();
                    let n_local = loads.len();
                    if let Some(peers) = &peers_c {
                        for p in peers.snapshot() {
                            // prefill-only peers never adopt decode work
                            loads.push(WorkerLoad {
                                live: p.live,
                                parked: p.parked,
                                alive: p.alive && !p.prefill_only,
                            });
                        }
                    }
                    if let Some((from, to)) = policy.pick(&loads) {
                        if from >= n_local {
                            // remote donors manage their own parked pool;
                            // this process cannot direct them
                            continue;
                        }
                        let ok = if to < n_local {
                            hub.direct(from, to)
                        } else {
                            hub.direct_remote(from, to - n_local)
                        };
                        if ok {
                            metrics_c.lock().inc("rebalance_directives", 1);
                        }
                    }
                }
            })
        });

        // dispatcher: route worker replies to the submitting channel.
        // Chunks are forwarded without consuming the pending entry; the
        // Done record removes it and feeds the serving metrics.
        let pending_c = pending.clone();
        let metrics_c = metrics.clone();
        let cancels_c = cancels.clone();
        let dispatcher = std::thread::spawn(move || {
            while let Ok(reply) = rx.recv() {
                match reply {
                    Reply::Chunk(c) => {
                        let ch = pending_c.lock().get(&c.id).cloned();
                        if let Some(ch) = ch {
                            let _ = ch.send(Reply::Chunk(c));
                        }
                    }
                    Reply::Done(resp) => {
                        {
                            let mut m = metrics_c.lock();
                            if resp.error.is_none() {
                                m.inc("responses_ok", 1);
                                m.inc("tokens_out", resp.tokens as u64);
                                m.observe("latency_ms", resp.wall_ms);
                                m.observe("queue_ms", resp.queue_ms);
                                m.observe("ttft_ms", resp.ttft_ms);
                                m.observe("compression", resp.compression);
                                // per-step accept-length histogram across
                                // all requests (the paper's S distribution)
                                for (len, &cnt) in resp.accept_hist.iter().enumerate() {
                                    for _ in 0..cnt {
                                        m.observe("accept_len", len as f64);
                                    }
                                }
                                if !resp.finish.is_empty() {
                                    m.inc(&format!("finish_{}", resp.finish), 1);
                                }
                                if resp.pool_shared {
                                    m.inc(
                                        if resp.pool_warm {
                                            "ngram_warm_requests"
                                        } else {
                                            "ngram_cold_requests"
                                        },
                                        1,
                                    );
                                    m.observe("pool_hit_rate", resp.pool_hit_rate);
                                }
                            } else {
                                m.inc("responses_err", 1);
                            }
                        }
                        let ch = pending_c.lock().remove(&resp.id);
                        // clear AFTER removing the pending entry: cancel()
                        // only marks ids it observed in `pending` (under the
                        // same lock), so this ordering guarantees any mark
                        // racing with completion is swept — no stale ids
                        // accumulate in the CancelSet.
                        cancels_c.clear(resp.id);
                        if let Some(ch) = ch {
                            let _ = ch.send(Reply::Done(resp));
                        }
                    }
                }
            }
        });

        Ok(ServerHandle {
            sched,
            pending,
            next_id,
            metrics,
            ngram_caches,
            prefix_cache,
            rebalance,
            peers,
            tracer,
            cancels,
            remote_cancels,
            worker_joins,
            dispatcher: Some(dispatcher),
            rebalancer,
            rebalance_stop,
            net_stop,
            net_joins,
            relay_joins,
            net_cuts,
        })
    }

    /// Fault injection for the wire tests: each planned offset cuts one
    /// outbound snapshot-transfer connection after that many payload bytes
    /// (one cut consumed per attempt — see [`TransferOpts`]). A no-op
    /// without `ServerConfig::peers`.
    pub fn inject_net_cuts(&self, cuts: Vec<usize>) {
        self.net_cuts.lock().extend(cuts);
    }

    /// Sync derived gauges into the registry so every report flavor (text
    /// or JSON) carries them: prefix-cache stats, per-worker live/parked
    /// totals, and the scheduler queue depth.
    fn sync_gauges(&self) {
        // read every source gauge BEFORE taking the registry lock: the
        // sources acquire lower-ranked locks (sched.state, cancel.ids,
        // kv.prefix), and the lock hierarchy forbids taking those while
        // the leaf-ranked registry is held (DESIGN.md §9)
        let prefix = self.prefix_cache.as_ref().map(|pc| pc.stats());
        let depth = self.sched.depth() as u64;
        let marks = self.cancels.len() as u64;
        let trace = self.tracer.as_ref().map(|t| t.stats());
        let mut m = self.metrics.lock();
        if let Some(st) = prefix {
            m.set("prefix_hits", st.hits);
            m.set("prefix_miss", st.misses);
            m.set("prefix_entries", st.entries as u64);
            m.set("prefix_bytes", st.bytes as u64);
            m.set("prefix_bytes_reused", st.bytes_reused);
        }
        // workers write per-worker parked/live gauges so they never
        // clobber each other; the endpoint reports server-wide totals
        let total: u64 = m
            .counters
            .iter()
            .filter(|(k, _)| k.starts_with("suspended_sessions_w"))
            .map(|(_, v)| *v)
            .sum();
        m.set("suspended_sessions", total);
        let live: u64 = m
            .counters
            .iter()
            .filter(|(k, _)| k.starts_with("live_sessions_w"))
            .map(|(_, v)| *v)
            .sum();
        m.set("live_sessions", live);
        // queue-depth report: requests admitted by no worker yet
        m.set("queue_depth", depth);
        // cancel marks still outstanding — returns to 0 at quiescence
        // (every retirement path sweeps its mark)
        m.set("cancel_marks", marks);
        if let Some((recorded, dropped)) = trace {
            m.set("trace_spans", recorded);
            m.set("trace_dropped", dropped);
        }
    }

    /// Server metrics report including per-cache n-gram counters and the
    /// KV subsystem (prefix-reuse gauges are synced into the registry here,
    /// so the dispatcher metrics endpoint always carries them). Histogram
    /// lines carry p50/p90/p99 — `batch_size` and `ttft_ms` included, so
    /// operators read latency/occupancy percentiles without raw samples.
    pub fn report(&self) -> String {
        self.sync_gauges();
        let mut s = self.metrics.lock().report();
        if let Some(reg) = &self.ngram_caches {
            s.push_str(&reg.report());
        }
        if let Some(pc) = &self.prefix_cache {
            s.push_str(&pc.report());
        }
        s
    }

    /// Machine-readable flavor of [`ServerHandle::report`]: counters plus
    /// per-histogram [`crate::metrics::HistSummary`] objects (count, mean,
    /// p50/p90/p99, max) under `"histograms"`. This is what the serving
    /// benchmark harness (`bench::load`) scrapes — also served over TCP via
    /// the `{"report": true}` control line.
    pub fn report_json(&self) -> Json {
        self.sync_gauges();
        self.metrics.lock().report_json()
    }

    /// Typed percentile summary of one serving histogram (e.g. `ttft_ms`,
    /// `batch_size`, `latency_ms`); None when it has no samples yet.
    pub fn hist_summary(&self, name: &str) -> Option<crate::metrics::HistSummary> {
        self.metrics.lock().summary(name)
    }

    /// Chrome trace-event JSON of everything the tracer holds (load the
    /// dump into Perfetto / `chrome://tracing`); `Json::Null` when tracing
    /// is off — also served over TCP via the `{"trace": true}` control
    /// line.
    pub fn trace_json(&self) -> Json {
        match &self.tracer {
            Some(t) => t.chrome_json(),
            None => Json::Null,
        }
    }

    /// Prometheus text exposition of the serving registry (gauges synced
    /// first) — also served over TCP via the `{"metrics": "prometheus"}`
    /// control line.
    pub fn prometheus(&self) -> String {
        self.sync_gauges();
        self.metrics.lock().prometheus()
    }

    /// Submit a request; returns the per-request reply stream (chunks for
    /// `stream: true` requests, then the final record).
    pub fn submit(&self, mut req: Request) -> Result<ResponseStream> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        let (tx, rx) = channel();
        self.pending.lock().insert(id, tx);
        self.metrics.lock().inc("requests", 1);
        if let Err(rejected) = self.sched.push(req) {
            self.pending.lock().remove(&id);
            self.metrics.lock().inc("rejected", 1);
            bail!("queue full, request {} rejected", rejected.id);
        }
        Ok(ResponseStream { id, rx })
    }

    /// Cancel a request by id. A still-queued request is removed and its
    /// final record synthesized immediately; an in-flight request is marked
    /// and its worker stops it within one decode step (the final record
    /// then carries the partial text and `"finish":"cancelled"`). Returns
    /// false when the id is unknown or already finished.
    pub fn cancel(&self, id: u64) -> bool {
        if self.sched.cancel(id) {
            self.metrics.lock().inc("finish_cancelled", 1);
            if let Some(ch) = self.pending.lock().remove(&id) {
                let _ = ch.send(Reply::Done(Response::cancelled(id)));
            }
            return true;
        }
        // The session may have been adopted by a remote peer: forward the
        // stop signal there (the adopter marks its own CancelSet, so the
        // cancel still lands within one decode step); the relayed final
        // record then sweeps the local bookkeeping like any other reply.
        let remote = self.remote_cancels.lock().get(&id).cloned();
        if let Some((addr, xfer)) = remote {
            let _ = net::cancel_session(&addr, xfer);
        }
        // Mark while holding the pending lock: the dispatcher removes the
        // pending entry (same lock) before clearing marks, so a mark set
        // here for a still-pending request is either observed by the worker
        // or swept by the dispatcher's clear — never left behind.
        let pending = self.pending.lock();
        if pending.contains_key(&id) {
            self.cancels.request(id);
            return true;
        }
        false
    }

    pub fn queue_depth(&self) -> usize {
        self.sched.depth()
    }

    /// Close the queue and join all threads (drains in-flight work first).
    /// The rebalancer stops before the queue closes, so no new migration
    /// directives are issued while workers drain; whatever migrations are
    /// still queued after every worker joined get a final error record —
    /// a lost hand-off must never leave a client waiting forever.
    pub fn shutdown(mut self) {
        self.rebalance_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.rebalancer.take() {
            let _ = j.join();
        }
        self.sched.close();
        for j in self.worker_joins.drain(..) {
            let _ = j.join();
        }
        // network wind-down: clearing the remote link drops the transport's
        // only Sender so it drains queued donations and exits; the stop flag
        // winds down the listener, heartbeat, and any reply relays still
        // waiting on an adopter (those synthesize a final error record, so
        // no client hangs)
        if let Some(hub) = &self.rebalance {
            hub.clear_remote();
        }
        self.net_stop.store(true, Ordering::Relaxed);
        for j in self.net_joins.drain(..) {
            let _ = j.join();
        }
        for j in self.relay_joins.lock().drain(..) {
            let _ = j.join();
        }
        if let Some(hub) = &self.rebalance {
            for m in hub.drain() {
                self.cancels.clear(m.id);
                let ch = self.pending.lock().remove(&m.id);
                if let Some(ch) = ch {
                    // same contract as fail_parked: flush the held-back
                    // stream tail, then the Failed record
                    let (tail, resp) =
                        m.into_failure("worker shut down during session migration");
                    if let Some(c) = tail {
                        let _ = ch.send(Reply::Chunk(c));
                    }
                    let _ = ch.send(Reply::Done(resp));
                }
            }
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

/// Inbound half of the wire hand-off: decodes a received snapshot payload,
/// assigns it a fresh local id, and injects it into the shallowest alive
/// worker through the ordinary [`RebalanceHub::transfer`] path — so a
/// wire-adopted session is indistinguishable from a locally-migrated one
/// from the worker's point of view.
struct NetGateway {
    hub: Arc<RebalanceHub>,
    pending: Arc<RankedMutex<HashMap<u64, Sender<Reply>>>>,
    next_id: Arc<AtomicU64>,
    ngram_caches: Option<Arc<NgramCacheRegistry>>,
    metrics: Arc<RankedMutex<Registry>>,
    prefill_only: bool,
    cancels: Arc<CancelSet>,
    tracer: Option<Arc<Tracer>>,
}

impl net::Adopt for NetGateway {
    fn adopt(&self, meta: &Json, payload: Vec<u8>)
             -> Result<(u64, Receiver<Reply>), String> {
        let t0 = self.tracer.as_ref().map(|t| t.now_us());
        let caches = self.ngram_caches.as_deref();
        let snap = SessionSnapshot::from_bytes_with(&payload, caches)
            .map_err(|e| format!("snapshot decode failed: {e}"))?;
        let loads = self.hub.loads();
        let to = loads
            .iter()
            .enumerate()
            .filter(|(_, l)| l.alive)
            .min_by_key(|(_, l)| l.depth())
            .map(|(i, _)| i)
            .ok_or_else(|| "no alive worker to adopt the session".to_string())?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let m = MigratedSession::from_wire(meta, snap, to, id);
        let trace_id = m.trace_id;
        let (tx, rx) = channel();
        self.pending.lock().insert(id, tx);
        if self.hub.transfer(m).is_err() {
            self.pending.lock().remove(&id);
            return Err("adopting worker exited during hand-off".to_string());
        }
        if let (Some(t), Some(t0)) = (&self.tracer, t0) {
            // net-lane span; the donor's trace_id came over the wire, so a
            // merged dump stitches both processes under one id
            t.push(t.span(t.net_tid(), trace_id, "adopt", "net", t0)
                .arg("bytes", payload.len().to_string()));
        }
        let mut reg = self.metrics.lock();
        reg.inc("net_adopted", 1);
        reg.observe("net_transfer_bytes", payload.len() as f64);
        Ok((id, rx))
    }

    fn cancel_local(&self, id: u64) {
        // mirror `ServerHandle::cancel`: mark only ids still pending (the
        // dispatcher sweeps the mark on Done under the same lock)
        let pending = self.pending.lock();
        if pending.contains_key(&id) {
            self.cancels.request(id);
        }
    }

    fn load_json(&self) -> Json {
        let loads = self.hub.loads();
        let live: usize = loads.iter().map(|l| l.live).sum();
        let parked: usize = loads.iter().map(|l| l.parked).sum();
        Json::obj(vec![
            ("live", Json::num(live as f64)),
            ("parked", Json::num(parked as f64)),
            ("prefill_only", Json::Bool(self.prefill_only)),
        ])
    }
}

/// Everything the outbound transport thread owns.
struct NetTransport {
    rx: Receiver<RemoteDonation>,
    hub: Arc<RebalanceHub>,
    peers: Arc<Peers>,
    metrics: Arc<RankedMutex<Registry>>,
    relay_joins: Arc<RankedMutex<Vec<std::thread::JoinHandle<()>>>>,
    cuts: Arc<RankedMutex<Vec<usize>>>,
    stop: Arc<AtomicBool>,
    replies: Sender<Reply>,
    tracer: Option<Arc<Tracer>>,
    remote_cancels: Arc<RankedMutex<HashMap<u64, (String, u64)>>>,
}

/// Outbound half of the wire hand-off: drains [`RemoteDonation`]s, streams
/// each snapshot to its peer with [`net::send_session`] (resumable +
/// checksummed), and settles the outcome — adopted sessions get a reply
/// relay thread, bounced ones re-park on the donor worker. Exits when the
/// hub's remote link is cleared (the only Sender drops).
fn spawn_transport(t: NetTransport) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while let Ok(RemoteDonation { peer, m }) = t.rx.recv() {
            t.metrics.lock().inc("net_transfers", 1);
            let Some(addr) = t.peers.addr(peer) else {
                t.metrics.lock().inc("net_bounced", 1);
                bounce_home(&t.hub, m, "unknown peer index", &t.replies, &t.metrics);
                continue;
            };
            let meta = m.wire_meta();
            let payload = m.snap.to_bytes();
            let opts = TransferOpts { cuts: t.cuts.clone(), ..Default::default() };
            let t0 = t.tracer.as_ref().map(|tr| tr.now_us());
            let report = net::send_session(&addr, &meta, &payload, &opts);
            if let (Some(tr), Some(t0)) = (&t.tracer, t0) {
                let outcome = match &report.outcome {
                    SendOutcome::Adopted(_) => "adopted",
                    SendOutcome::Bounced(_) => "bounced",
                };
                tr.push(tr.span(tr.net_tid(), m.trace_id, "transfer", "net", t0)
                    .arg("bytes", payload.len().to_string())
                    .arg("outcome", outcome));
            }
            if report.resumes > 0 {
                t.metrics.lock().inc("net_resumes", report.resumes);
            }
            match report.outcome {
                SendOutcome::Adopted(lines) => {
                    {
                        let mut mm = t.metrics.lock();
                        mm.inc("net_adopted", 1);
                        mm.observe("net_transfer_bytes", payload.len() as f64);
                    }
                    // the session now lives on the peer — drop our copy and
                    // relay the adopter's replies to the waiting client
                    let donor_id = m.id;
                    let trace_id = m.trace_id;
                    let xfer = fnv64(&payload);
                    // register for cancel forwarding BEFORE the relay runs:
                    // a client cancel between now and the final record must
                    // reach the adopter, not a worker that no longer holds
                    // the session
                    t.remote_cancels.lock().insert(donor_id,
                                                            (addr.clone(), xfer));
                    let replies_c = t.replies.clone();
                    let metrics_c = t.metrics.clone();
                    let stop_c = t.stop.clone();
                    let tracer_c = t.tracer.clone();
                    let rc_c = t.remote_cancels.clone();
                    t.relay_joins.lock().push(std::thread::spawn(move || {
                        relay_replies(lines, &addr, xfer, donor_id, replies_c,
                                      metrics_c, stop_c, tracer_c, trace_id);
                        rc_c.lock().remove(&donor_id);
                    }));
                }
                SendOutcome::Bounced(why) => {
                    t.metrics.lock().inc("net_bounced", 1);
                    bounce_home(&t.hub, m, &why, &t.replies, &t.metrics);
                }
            }
        }
    })
}

/// A donation that could not be delivered re-parks on the donor worker
/// (`m.to` still names it), preserving either-adopted-or-bounced. If even
/// the donor is gone, the client gets a final error record — never a hang.
fn bounce_home(hub: &RebalanceHub, m: MigratedSession, why: &str,
               replies: &Sender<Reply>, metrics: &Arc<RankedMutex<Registry>>) {
    if let Err(m) = hub.transfer(m) {
        metrics.lock().inc("net_transfer_fail", 1);
        let (tail, resp) = m.into_failure(&format!("remote hand-off failed: {why}"));
        if let Some(c) = tail {
            let _ = replies.send(Reply::Chunk(c));
        }
        let _ = replies.send(Reply::Done(resp));
    }
}

/// Reconnect attempts after a dropped reply tunnel before giving up and
/// synthesizing a final error record.
const ATTACH_ATTEMPTS: usize = 5;

/// Donor-side reply relay for one adopted-away session: forwards the
/// adopter's chunk lines and final record into the donor's own dispatcher
/// (ids were rewritten to `donor_id` by the adopter). A dropped tunnel
/// re-attaches with the count of lines already forwarded, so the adopter
/// replays only what was lost — exhausted retries or shutdown synthesize an
/// error record so the client never hangs.
#[allow(clippy::too_many_arguments)]
fn relay_replies(mut lines: net::NetLines, addr: &str, xfer: u64, donor_id: u64,
                 replies: Sender<Reply>, metrics: Arc<RankedMutex<Registry>>,
                 stop: Arc<AtomicBool>, tracer: Option<Arc<Tracer>>,
                 trace_id: u64) {
    let relay_t0 = tracer.as_ref().map(|t| t.now_us());
    let end_span = |have: usize, outcome: &str| {
        if let (Some(t), Some(t0)) = (&tracer, relay_t0) {
            t.push(t.span(t.net_tid(), trace_id, "relay", "net", t0)
                .arg("lines", have.to_string())
                .arg("outcome", outcome));
        }
    };
    let mut have: usize = 0;
    'relay: loop {
        loop {
            let line = match lines.next() {
                Ok(Some(l)) => l,
                Ok(None) => {
                    if stop.load(Ordering::Relaxed) {
                        end_span(have, "shutdown");
                        fail_relay(donor_id, &replies, "server shut down mid-relay");
                        return;
                    }
                    continue;
                }
                Err(_) => break, // tunnel dropped: re-attach below
            };
            if let Ok(resp) = Response::from_json_line(&line) {
                end_span(have, "done");
                let _ = replies.send(Reply::Done(resp));
                return;
            }
            if let Ok(c) = StreamChunk::from_json_line(&line) {
                have += 1;
                let _ = replies.send(Reply::Chunk(c));
            }
        }
        for _ in 0..ATTACH_ATTEMPTS {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            crate::util::sync::nap(Duration::from_millis(50));
            let a0 = tracer.as_ref().map(|t| t.now_us());
            if let Ok(nl) = net::attach(addr, xfer, have) {
                lines = nl;
                if let (Some(t), Some(t0)) = (&tracer, a0) {
                    t.push(t.span(t.net_tid(), trace_id, "attach", "net", t0)
                        .arg("have", have.to_string()));
                }
                metrics.lock().inc("net_attach_resumes", 1);
                continue 'relay;
            }
        }
        end_span(have, "lost");
        fail_relay(donor_id, &replies, "lost contact with adopting peer");
        return;
    }
}

fn fail_relay(donor_id: u64, replies: &Sender<Reply>, why: &str) {
    let _ = replies.send(Reply::Done(Response::err(donor_id, why.to_string())));
}

/// TCP front: JSON-lines protocol, one connection per client.
/// Runs until `max_conns` connections have been served (None = forever).
pub fn serve_tcp(addr: &str, cfg: ServerConfig, max_conns: Option<usize>) -> Result<()> {
    let trace_out = cfg.trace_out.clone();
    let handle = Arc::new(ServerHandle::start(cfg)?);
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    info!("server", "listening on {addr}");
    let mut served = 0usize;
    let mut conn_joins = Vec::new();
    for stream in listener.incoming() {
        let stream = stream?;
        let h = handle.clone();
        conn_joins.push(std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &h) {
                crate::util::log::log(crate::util::log::Level::Warn, "server",
                                      &format!("connection error: {e}"));
            }
        }));
        served += 1;
        if let Some(m) = max_conns {
            if served >= m {
                break;
            }
        }
    }
    for j in conn_joins {
        let _ = j.join();
    }
    if let Ok(h) = Arc::try_unwrap(handle) {
        // flush the Chrome trace dump on a clean exit (a SIGTERM'd server
        // never reaches this — scrape `{"trace": true}` instead)
        if let Some(path) = &trace_out {
            if h.tracer.is_some() {
                std::fs::write(path, h.trace_json().dump())
                    .with_context(|| format!("writing trace dump {path}"))?;
                info!("server", "trace dump written to {path}");
            }
        }
        h.shutdown();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, handle: &ServerHandle) -> Result<()> {
    let peer = stream.peer_addr()?;
    info!("server", "connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // control line: {"cancel": <id>} — ids are reported in every chunk
        // and final record, so streaming clients can cancel from a second
        // connection.
        let parsed = Json::parse(&line);
        if let Ok(j) = &parsed {
            if let Some(cid) = j.get("cancel").and_then(Json::as_usize) {
                let ok = handle.cancel(cid as u64);
                let ack = Json::obj(vec![
                    ("cancel", Json::num(cid as f64)),
                    ("ok", Json::Bool(ok)),
                ]);
                out.write_all(ack.dump().as_bytes())?;
                out.write_all(b"\n")?;
                out.flush()?;
                continue;
            }
            // control line: {"report": true} — one-line machine-readable
            // metrics report (counters + histogram percentile summaries);
            // the bench harness and operators scrape this.
            if j.get("report").and_then(Json::as_bool) == Some(true) {
                let rep = Json::obj(vec![("report", handle.report_json())]);
                out.write_all(rep.dump().as_bytes())?;
                out.write_all(b"\n")?;
                out.flush()?;
                continue;
            }
            // control line: {"trace": true} — one-line Chrome trace-event
            // dump of everything the tracer holds (null when tracing is
            // off); how a bench harness or operator scrapes the timeline
            // without waiting for the server to exit. A request carrying
            // the per-request "trace" flag also has "prompt" — not this.
            if j.get("trace").and_then(Json::as_bool) == Some(true)
                && j.get("prompt").is_none()
            {
                let rep = Json::obj(vec![("trace", handle.trace_json())]);
                out.write_all(rep.dump().as_bytes())?;
                out.write_all(b"\n")?;
                out.flush()?;
                continue;
            }
            // control line: {"metrics": "prometheus"} — text exposition of
            // the serving registry, wrapped in one JSON line so it rides
            // the same protocol as everything else.
            if j.get("metrics").and_then(Json::as_str) == Some("prometheus") {
                let rep =
                    Json::obj(vec![("metrics_prom", Json::str(handle.prometheus()))]);
                out.write_all(rep.dump().as_bytes())?;
                out.write_all(b"\n")?;
                out.flush()?;
                continue;
            }
        }
        let submitted = parsed
            .map_err(|e| anyhow::anyhow!("bad request json: {e}"))
            .and_then(|j| Request::from_json(0, &j))
            .and_then(|req| handle.submit(req));
        match submitted {
            Ok(rs) => loop {
                match rs.recv() {
                    Ok(Reply::Chunk(c)) => {
                        out.write_all(c.to_json_line().as_bytes())?;
                        out.write_all(b"\n")?;
                        out.flush()?;
                    }
                    Ok(Reply::Done(r)) => {
                        out.write_all(r.to_json_line().as_bytes())?;
                        out.write_all(b"\n")?;
                        out.flush()?;
                        break;
                    }
                    Err(_) => {
                        let r = Response::err(0, "server shutting down".into());
                        out.write_all(r.to_json_line().as_bytes())?;
                        out.write_all(b"\n")?;
                        out.flush()?;
                        break;
                    }
                }
            },
            Err(e) => {
                let r = Response::err(0, e.to_string());
                out.write_all(r.to_json_line().as_bytes())?;
                out.write_all(b"\n")?;
                out.flush()?;
            }
        }
    }
    Ok(())
}

/// Minimal client for the JSON-lines protocol (examples + CLI): one request,
/// one final line (non-streaming).
pub fn client_request(addr: &str, req_json: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.write_all(req_json.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(line.trim_end().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(live: usize, parked: usize, alive: bool) -> WorkerLoad {
        WorkerLoad { live, parked, alive }
    }

    #[test]
    fn policy_moves_from_deepest_parked_to_shallowest() {
        let p = RebalancePolicy::default();
        // worker 1 is deepest AND has parked sessions; worker 2 is idle
        let loads =
            [load(2, 0, true), load(3, 3, true), load(0, 0, true), load(1, 1, true)];
        assert_eq!(p.pick(&loads), Some((1, 2)));
    }

    #[test]
    fn policy_is_quiet_when_balanced_or_without_donors() {
        let p = RebalancePolicy::default();
        // depth gap below min_gap: no move (a move of one session would
        // just swap which worker is deeper)
        assert_eq!(p.pick(&[load(2, 1, true), load(2, 0, true)]), None);
        // gap exactly min_gap: the move equalizes, so it happens
        assert_eq!(p.pick(&[load(3, 1, true), load(2, 0, true)]), Some((0, 1)));
        // deep workers with nothing parked cannot donate
        assert_eq!(p.pick(&[load(5, 0, true), load(0, 0, true)]), None);
        // single worker: donor == target
        assert_eq!(p.pick(&[load(5, 3, true)]), None);
        assert_eq!(p.pick(&[]), None);
    }

    #[test]
    fn policy_skips_exited_workers() {
        let p = RebalancePolicy::default();
        // the shallowest worker exited: next-shallowest live one is chosen
        let loads = [load(4, 2, true), load(0, 0, false), load(1, 0, true)];
        assert_eq!(p.pick(&loads), Some((0, 2)));
        // the only deep worker exited: nothing to do
        let loads = [load(4, 2, false), load(1, 0, true), load(1, 0, true)];
        assert_eq!(p.pick(&loads), None);
    }

    #[test]
    fn policy_min_gap_floor_prevents_oscillation() {
        // even an explicit min_gap of 0 behaves as 1: equal depths never
        // trigger a move
        let p = RebalancePolicy { min_gap: 0 };
        assert_eq!(p.pick(&[load(2, 2, true), load(2, 0, true)]), None);
        assert_eq!(p.pick(&[load(3, 2, true), load(2, 0, true)]), Some((0, 1)));
    }
}

/// Streaming client: sends one request, invokes `on_chunk` for every chunk
/// line, returns the final (`"done":true`) record line.
pub fn client_request_stream(addr: &str, req_json: &str,
                             mut on_chunk: impl FnMut(&str)) -> Result<String> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.write_all(req_json.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("connection closed before the final record");
        }
        let t = line.trim_end();
        let done = Json::parse(t)
            .ok()
            .and_then(|j| j.get("done").and_then(Json::as_bool))
            .unwrap_or(true);
        if done {
            return Ok(t.to_string());
        }
        on_chunk(t);
    }
}
