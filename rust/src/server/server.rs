//! The serving front (vLLM-router-like, thread-based — no tokio offline):
//!
//!   TCP conn ──lines──> parse ──> Scheduler (FIFO/SJF, back-pressure)
//!                                   │ pop
//!                              Worker pool (one PJRT runtime each)
//!                                   │ Response
//!                              dispatcher ──> per-connection channel
//!
//! Also exposes an in-process `ServerHandle::submit` used by the examples
//! and the e2e bench driver.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::info;
use crate::metrics::Registry;
use crate::ngram::NgramCacheRegistry;
use crate::server::request::{Request, Response};
use crate::server::scheduler::{Policy, Scheduler};
use crate::server::worker::{Worker, WorkerConfig};

pub struct ServerConfig {
    pub workers: usize,
    pub policy: Policy,
    pub queue_depth: usize,
    /// server-level toggle for the cross-request shared n-gram cache. When
    /// true, one `NgramCacheRegistry` spans all workers; individual
    /// requests can still opt out via `share_ngrams: false`. When false,
    /// no registry exists and every request decodes against a cold pool.
    pub share_ngrams: bool,
    pub worker: WorkerConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            policy: Policy::Fifo,
            queue_depth: 256,
            share_ngrams: true,
            worker: WorkerConfig::default(),
        }
    }
}

/// In-process handle: submit requests, receive responses, shut down.
pub struct ServerHandle {
    sched: Arc<Scheduler>,
    pending: Arc<Mutex<HashMap<u64, Sender<Response>>>>,
    next_id: AtomicU64,
    pub metrics: Arc<Mutex<Registry>>,
    /// cross-request n-gram caches (None when sharing is disabled).
    pub ngram_caches: Option<Arc<NgramCacheRegistry>>,
    worker_joins: Vec<std::thread::JoinHandle<()>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn start(cfg: ServerConfig) -> Result<ServerHandle> {
        let sched = Arc::new(Scheduler::new(cfg.policy, cfg.queue_depth));
        let pending: Arc<Mutex<HashMap<u64, Sender<Response>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(Mutex::new(Registry::new()));
        let ngram_caches =
            cfg.share_ngrams.then(|| Arc::new(NgramCacheRegistry::new()));
        let (tx, rx): (Sender<Response>, Receiver<Response>) = channel();

        let mut worker_joins = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let sched_c = sched.clone();
            let tx_c = tx.clone();
            let wcfg = cfg.worker.clone();
            let caches_c = ngram_caches.clone();
            worker_joins.push(std::thread::spawn(move || {
                match Worker::start(wid, wcfg, caches_c) {
                    Ok(w) => w.run(sched_c, tx_c),
                    Err(e) => eprintln!("[ERROR] worker {wid} failed to start: {e}"),
                }
            }));
        }
        drop(tx);

        // dispatcher: route worker responses to the submitting channel
        let pending_c = pending.clone();
        let metrics_c = metrics.clone();
        let dispatcher = std::thread::spawn(move || {
            while let Ok(resp) = rx.recv() {
                {
                    let mut m = metrics_c.lock().unwrap();
                    if resp.error.is_none() {
                        m.inc("responses_ok", 1);
                        m.inc("tokens_out", resp.tokens as u64);
                        m.observe("latency_ms", resp.wall_ms);
                        m.observe("queue_ms", resp.queue_ms);
                        m.observe("compression", resp.compression);
                        if resp.pool_shared {
                            m.inc(
                                if resp.pool_warm {
                                    "ngram_warm_requests"
                                } else {
                                    "ngram_cold_requests"
                                },
                                1,
                            );
                            m.observe("pool_hit_rate", resp.pool_hit_rate);
                        }
                    } else {
                        m.inc("responses_err", 1);
                    }
                }
                let reply = pending_c.lock().unwrap().remove(&resp.id);
                if let Some(ch) = reply {
                    let _ = ch.send(resp);
                }
            }
        });

        Ok(ServerHandle {
            sched,
            pending,
            next_id: AtomicU64::new(1),
            metrics,
            ngram_caches,
            worker_joins,
            dispatcher: Some(dispatcher),
        })
    }

    /// Server metrics report including per-cache n-gram counters.
    pub fn report(&self) -> String {
        let mut s = self.metrics.lock().unwrap().report();
        if let Some(reg) = &self.ngram_caches {
            s.push_str(&reg.report());
        }
        s
    }

    /// Submit a request; returns the channel the response will arrive on.
    pub fn submit(&self, mut req: Request) -> Result<Receiver<Response>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        let (tx, rx) = channel();
        self.pending.lock().unwrap().insert(id, tx);
        self.metrics.lock().unwrap().inc("requests", 1);
        if let Err(rejected) = self.sched.push(req) {
            self.pending.lock().unwrap().remove(&id);
            self.metrics.lock().unwrap().inc("rejected", 1);
            anyhow::bail!("queue full, request {} rejected", rejected.id);
        }
        Ok(rx)
    }

    pub fn queue_depth(&self) -> usize {
        self.sched.depth()
    }

    /// Close the queue and join all threads (drains in-flight work first).
    pub fn shutdown(mut self) {
        self.sched.close();
        for j in self.worker_joins.drain(..) {
            let _ = j.join();
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

/// TCP front: JSON-lines protocol, one connection per client.
/// Runs until `max_conns` connections have been served (None = forever).
pub fn serve_tcp(addr: &str, cfg: ServerConfig, max_conns: Option<usize>) -> Result<()> {
    let handle = Arc::new(ServerHandle::start(cfg)?);
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    info!("server", "listening on {addr}");
    let mut served = 0usize;
    let mut conn_joins = Vec::new();
    for stream in listener.incoming() {
        let stream = stream?;
        let h = handle.clone();
        conn_joins.push(std::thread::spawn(move || {
            if let Err(e) = handle_conn(stream, &h) {
                crate::util::log::log(crate::util::log::Level::Warn, "server",
                                      &format!("connection error: {e}"));
            }
        }));
        served += 1;
        if let Some(m) = max_conns {
            if served >= m {
                break;
            }
        }
    }
    for j in conn_joins {
        let _ = j.join();
    }
    if let Ok(h) = Arc::try_unwrap(handle) {
        h.shutdown();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, handle: &ServerHandle) -> Result<()> {
    let peer = stream.peer_addr()?;
    info!("server", "connection from {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Request::from_json_line(0, &line) {
            Ok(req) => match handle.submit(req) {
                Ok(rx) => rx.recv().unwrap_or_else(|_| {
                    Response::err(0, "server shutting down".into())
                }),
                Err(e) => Response::err(0, e.to_string()),
            },
            Err(e) => Response::err(0, e.to_string()),
        };
        out.write_all(resp.to_json_line().as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
    }
    Ok(())
}

/// Minimal client for the JSON-lines protocol (examples + CLI).
pub fn client_request(addr: &str, req_json: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.write_all(req_json.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(line.trim_end().to_string())
}
