//! Engine workers: each worker thread owns its PJRT client, model runtime,
//! and a cache of decoder instances (the PJRT client is not Send — per-thread
//! ownership is mandatory, and it also mirrors lookahead parallelism's
//! full-model-per-device design).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::engine::autoregressive::AutoRegressive;
use crate::engine::jacobi::Jacobi;
use crate::engine::lookahead::Lookahead;
use crate::engine::prompt_lookup::PromptLookup;
use crate::engine::spec_decode::SpecDecode;
use crate::engine::Decoder;
use crate::info;
use crate::ngram::{NgramCacheRegistry, PoolHandle};
use crate::runtime::{cpu_client, Manifest, ModelRuntime};
use crate::server::request::{Request, Response};
use crate::server::scheduler::Scheduler;
use crate::tokenizer::ByteTokenizer;

#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub artifacts_dir: String,
    pub model: String,
    /// default (W,N,G) when the request does not override it
    pub wng: (usize, usize, usize),
    pub draft_model: String,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            artifacts_dir: "artifacts".into(),
            model: "tiny".into(),
            wng: (5, 3, 5),
            draft_model: "draft".into(),
        }
    }
}

pub struct Worker {
    pub id: usize,
    cfg: WorkerConfig,
    manifest: Manifest,
    rt: ModelRuntime,
    engines: HashMap<String, Box<dyn Decoder>>,
    tok: ByteTokenizer,
    /// server-level shared n-gram caches (None = sharing disabled).
    ngram_caches: Option<Arc<NgramCacheRegistry>>,
}

impl Worker {
    pub fn start(id: usize, cfg: WorkerConfig,
                 ngram_caches: Option<Arc<NgramCacheRegistry>>) -> Result<Worker> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let client = cpu_client()?;
        let rt = ModelRuntime::load(&client, &manifest, &cfg.model)?;
        Ok(Worker {
            id,
            cfg,
            manifest,
            rt,
            engines: HashMap::new(),
            tok: ByteTokenizer::new(),
            ngram_caches,
        })
    }

    fn engine_key(&self, req: &Request) -> String {
        match (&req.method[..], req.wng) {
            ("lookahead", Some((w, n, g))) => format!("lookahead:{w},{n},{g}"),
            (m, _) => m.to_string(),
        }
    }

    /// (Associated fn over disjoint fields so `handle` can call it while
    /// holding the engine-map entry.)
    fn make_engine(cfg: &WorkerConfig, manifest: &Manifest, rt: &ModelRuntime,
                   req: &Request) -> Result<Box<dyn Decoder>> {
        let (w, n, g) = req.wng.unwrap_or(cfg.wng);
        Ok(match &req.method[..] {
            "lookahead" => Box::new(Lookahead::with_wng(w, n, g)),
            "autoregressive" | "greedy" | "ar" => Box::new(AutoRegressive::new()),
            "jacobi" => Box::new(Jacobi::new(8)),
            "prompt_lookup" => Box::new(PromptLookup::new(8, 1)),
            "spec_decode" => {
                let draft = ModelRuntime::load(&rt.client, manifest, &cfg.draft_model)?;
                Box::new(SpecDecode::new(draft, 4))
            }
            other => return Err(anyhow!("unknown decoding method '{other}'")),
        })
    }

    /// Token budget: keep the BOS + the most recent prompt bytes that fit.
    fn encode_prompt(&self, prompt: &str) -> Vec<u32> {
        let mut ids = self.tok.encode_with_bos(prompt);
        let cap = self.rt.prefill_len;
        if ids.len() > cap {
            let tail = ids.len() - (cap - 1);
            let mut v = vec![crate::tokenizer::BOS_ID];
            v.extend_from_slice(&ids[tail..]);
            ids = v;
        }
        ids
    }

    /// Bind the request to an n-gram store: the server's shared cache when
    /// the server handed this worker a registry (`ServerConfig.share_ngrams`,
    /// per-request overridable), else a cold private pool. Engines without a
    /// pool get a detached handle.
    ///
    /// Sampled requests (`temperature > 0`) default to a private pool even
    /// when the server shares: Algorithm 4 preserves the output
    /// *distribution* with any candidate set, but the per-seed token
    /// sequence depends on which candidates the cache holds — a warm cache
    /// would silently break seeded reproducibility. An explicit
    /// `share_ngrams: true` on the request still opts in.
    /// (Associated fn: `handle` calls it while holding `&mut` on the engine
    /// map.)
    fn bind_pool_for(cfg: &WorkerConfig, caches: &Option<Arc<NgramCacheRegistry>>,
                     req: &Request, engine: &dyn Decoder) -> PoolHandle {
        let Some(spec) = engine.pool_spec() else {
            return PoolHandle::none();
        };
        let greedy = req.temperature <= 0.0;
        let share = req.share_ngrams.unwrap_or(greedy);
        match (caches, share) {
            (Some(reg), true) => PoolHandle::shared(reg.get_or_create(&cfg.model, spec)),
            _ => PoolHandle::private(spec),
        }
    }

    pub fn handle(&mut self, req: &Request, queued_ms: f64) -> Response {
        let key = self.engine_key(req);
        let ids = self.encode_prompt(&req.prompt);
        let engine = match self.engines.entry(key) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                match Self::make_engine(&self.cfg, &self.manifest, &self.rt, req) {
                    Ok(e) => v.insert(e),
                    Err(e) => return Response::err(req.id, e.to_string()),
                }
            }
        };
        let mut pool = Self::bind_pool_for(&self.cfg, &self.ngram_caches, req,
                                           engine.as_ref());
        match engine.generate_with_pool(&self.rt, &ids, &req.gen_params(), &mut pool) {
            Ok(out) => Response::ok(req.id, out.text, &out.stats, queued_ms),
            Err(e) => Response::err(req.id, e.to_string()),
        }
    }

    /// Worker main loop: drain the scheduler until it closes.
    pub fn run(mut self, sched: Arc<Scheduler>, replies: Sender<Response>) {
        info!("worker", "worker {} ready (model={})", self.id, self.cfg.model);
        while let Some(popped) = sched.pop() {
            let resp = self.handle(&popped.req, popped.queued_ms);
            if replies.send(resp).is_err() {
                break; // server gone
            }
        }
        info!("worker", "worker {} shutting down", self.id);
    }
}
