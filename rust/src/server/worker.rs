//! Engine workers: each worker thread owns its PJRT client, model runtime,
//! and a cache of decoder instances (the PJRT client is not Send — per-thread
//! ownership is mandatory, and it also mirrors lookahead parallelism's
//! full-model-per-device design).
//!
//! Scheduling: instead of running one request to completion, a worker keeps
//! up to `max_live` open [`DecodeSession`]s and round-robins a configurable
//! time-slice of decode steps across them. Long generations therefore no
//! longer block short ones behind them (the single-worker head-of-line
//! case), streaming requests emit chunks as steps commit, and cancellation
//! is observed between steps — a cancelled request stops within one step.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::autoregressive::AutoRegressive;
use crate::engine::jacobi::Jacobi;
use crate::engine::lookahead::Lookahead;
use crate::engine::prompt_lookup::PromptLookup;
use crate::engine::spec_decode::SpecDecode;
use crate::engine::{Decoder, DecodeSession, FinishReason, StepOutcome};
use crate::info;
use crate::ngram::{NgramCacheRegistry, PoolHandle};
use crate::runtime::{cpu_client, Manifest, ModelRuntime};
use crate::server::request::{Reply, Request, Response, StreamChunk};
use crate::server::scheduler::{CancelSet, Popped, Scheduler};
use crate::tokenizer::{ByteTokenizer, Utf8StreamDecoder};

#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub artifacts_dir: String,
    pub model: String,
    /// default (W,N,G) when the request does not override it
    pub wng: (usize, usize, usize),
    pub draft_model: String,
    /// decode steps each live session gets per scheduling round.
    pub time_slice: usize,
    /// max concurrently interleaved sessions per worker.
    pub max_live: usize,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            artifacts_dir: "artifacts".into(),
            model: "tiny".into(),
            wng: (5, 3, 5),
            draft_model: "draft".into(),
            time_slice: 4,
            max_live: 4,
        }
    }
}

/// One open request on a worker: the session plus its streaming state.
struct LiveSession<'rt> {
    id: u64,
    stream: bool,
    queued_ms: f64,
    seq: u64,
    dec: Utf8StreamDecoder,
    deadline: Option<Instant>,
    sess: Box<dyn DecodeSession + 'rt>,
    error: Option<String>,
}

pub struct Worker {
    pub id: usize,
    cfg: WorkerConfig,
    manifest: Manifest,
    rt: ModelRuntime,
    tok: ByteTokenizer,
    /// server-level shared n-gram caches (None = sharing disabled).
    ngram_caches: Option<Arc<NgramCacheRegistry>>,
    /// server-level cancellation marks, checked between steps.
    cancels: Arc<CancelSet>,
}

impl Worker {
    pub fn start(id: usize, cfg: WorkerConfig,
                 ngram_caches: Option<Arc<NgramCacheRegistry>>,
                 cancels: Arc<CancelSet>) -> Result<Worker> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let client = cpu_client()?;
        let rt = ModelRuntime::load(&client, &manifest, &cfg.model)?;
        Ok(Worker {
            id,
            cfg,
            manifest,
            rt,
            tok: ByteTokenizer::new(),
            ngram_caches,
            cancels,
        })
    }

    fn engine_key(req: &Request) -> String {
        match (&req.method[..], req.wng) {
            ("lookahead", Some((w, n, g))) => format!("lookahead:{w},{n},{g}"),
            (m, _) => m.to_string(),
        }
    }

    fn make_engine(cfg: &WorkerConfig, manifest: &Manifest, rt: &ModelRuntime,
                   req: &Request) -> Result<Box<dyn Decoder>> {
        let (w, n, g) = req.wng.unwrap_or(cfg.wng);
        Ok(match &req.method[..] {
            "lookahead" => Box::new(Lookahead::with_wng(w, n, g)),
            "autoregressive" | "greedy" | "ar" => Box::new(AutoRegressive::new()),
            "jacobi" => Box::new(Jacobi::new(8)),
            "prompt_lookup" => Box::new(PromptLookup::new(8, 1)),
            "spec_decode" => {
                let draft = ModelRuntime::load(&rt.client, manifest, &cfg.draft_model)?;
                Box::new(SpecDecode::new(draft, 4))
            }
            other => return Err(anyhow!("unknown decoding method '{other}'")),
        })
    }

    /// Token budget: keep the BOS + the most recent prompt bytes that fit.
    fn encode_prompt(tok: &ByteTokenizer, rt: &ModelRuntime, prompt: &str) -> Vec<u32> {
        let mut ids = tok.encode_with_bos(prompt);
        let cap = rt.prefill_len;
        if ids.len() > cap {
            let tail = ids.len() - (cap - 1);
            let mut v = vec![crate::tokenizer::BOS_ID];
            v.extend_from_slice(&ids[tail..]);
            ids = v;
        }
        ids
    }

    /// Bind the request to an n-gram store: the server's shared cache when
    /// the server handed this worker a registry (`ServerConfig.share_ngrams`,
    /// per-request overridable), else a cold private pool. Engines without a
    /// pool get a detached handle.
    ///
    /// Sampled requests (`temperature > 0`) default to a private pool even
    /// when the server shares: Algorithm 4 preserves the output
    /// *distribution* with any candidate set, but the per-seed token
    /// sequence depends on which candidates the cache holds — a warm cache
    /// would silently break seeded reproducibility. An explicit
    /// `share_ngrams: true` on the request still opts in.
    fn bind_pool_for(cfg: &WorkerConfig, caches: &Option<Arc<NgramCacheRegistry>>,
                     req: &Request, engine: &dyn Decoder) -> PoolHandle {
        let Some(spec) = engine.pool_spec() else {
            return PoolHandle::none();
        };
        let greedy = req.temperature <= 0.0;
        let share = req.share_ngrams.unwrap_or(greedy);
        match (caches, share) {
            (Some(reg), true) => PoolHandle::shared(reg.get_or_create(&cfg.model, spec)),
            _ => PoolHandle::private(spec),
        }
    }

    /// Open a session for a popped request. Engines are cached per
    /// (method, wng) key; sessions never borrow the engine, so one cached
    /// engine can back several interleaved sessions.
    fn open<'rt>(cfg: &WorkerConfig, manifest: &Manifest, rt: &'rt ModelRuntime,
                 engines: &mut HashMap<String, Box<dyn Decoder>>,
                 caches: &Option<Arc<NgramCacheRegistry>>, tok: &ByteTokenizer,
                 popped: Popped) -> Result<LiveSession<'rt>, (u64, String)> {
        let req = popped.req;
        let rid = req.id;
        let key = Self::engine_key(&req);
        if !engines.contains_key(&key) {
            let engine = Self::make_engine(cfg, manifest, rt, &req)
                .map_err(|e| (rid, e.to_string()))?;
            engines.insert(key.clone(), engine);
        }
        let engine = engines.get(&key).unwrap();
        let ids = Self::encode_prompt(tok, rt, &req.prompt);
        let pool = Self::bind_pool_for(cfg, caches, &req, engine.as_ref());
        let sess = engine
            .begin(rt, &ids, &req.gen_params(), pool)
            .map_err(|e| (rid, e.to_string()))?;
        Ok(LiveSession {
            id: rid,
            stream: req.stream,
            queued_ms: popped.queued_ms,
            seq: 0,
            dec: Utf8StreamDecoder::new(),
            deadline: req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms)),
            sess,
            error: None,
        })
    }

    /// Run one time-slice for a session: up to `slice` steps, checking
    /// cancellation and the deadline before each. Emits streaming chunks.
    /// Returns true when the session is ready to retire.
    fn drive(ls: &mut LiveSession, slice: usize, tok: &ByteTokenizer,
             cancels: &CancelSet, replies: &Sender<Reply>) -> bool {
        for _ in 0..slice {
            if ls.sess.finished().is_some() {
                return true;
            }
            if cancels.contains(ls.id) {
                ls.sess.cancel(FinishReason::Cancelled);
                return true;
            }
            if let Some(d) = ls.deadline {
                if Instant::now() >= d {
                    ls.sess.cancel(FinishReason::Deadline);
                    return true;
                }
            }
            match ls.sess.step() {
                Ok(StepOutcome::Committed { tokens }) => {
                    if ls.stream && !tokens.is_empty() {
                        let delta = ls.dec.push(&tok.bytes(&tokens));
                        if !delta.is_empty() {
                            ls.seq += 1;
                            let _ = replies.send(Reply::Chunk(StreamChunk {
                                id: ls.id,
                                seq: ls.seq,
                                delta,
                            }));
                        }
                    }
                }
                Ok(StepOutcome::Finished { .. }) => return true,
                Err(e) => {
                    ls.error = Some(e.to_string());
                    return true;
                }
            }
        }
        ls.sess.finished().is_some()
    }

    /// Deliver the final record for a finished/cancelled/failed session.
    /// Returns false when the reply channel is gone (server shut down).
    fn retire(ls: LiveSession, cancels: &CancelSet, replies: &Sender<Reply>) -> bool {
        cancels.clear(ls.id);
        let LiveSession { id, stream, queued_ms, mut dec, seq, sess, error, .. } = ls;
        if let Some(msg) = error {
            return replies.send(Reply::Done(Response::err(id, msg))).is_ok();
        }
        let finish = sess.finished().map_or("", |r| r.as_str());
        let (out, _pool) = sess.into_output();
        if stream {
            // flush any held-back partial UTF-8 sequence as a last chunk
            let tail = dec.finish();
            if !tail.is_empty() {
                let _ = replies.send(Reply::Chunk(StreamChunk {
                    id,
                    seq: seq + 1,
                    delta: tail,
                }));
            }
        }
        let resp = Response::ok(id, out.text, &out.stats, queued_ms).with_finish(finish);
        replies.send(Reply::Done(resp)).is_ok()
    }

    /// Worker main loop: admit up to `max_live` sessions (blocking on the
    /// scheduler only when idle), then round-robin `time_slice` steps per
    /// session per round until the scheduler closes and all sessions drain.
    pub fn run(self, sched: Arc<Scheduler>, replies: Sender<Reply>) {
        info!("worker", "worker {} ready (model={}, time_slice={}, max_live={})",
              self.id, self.cfg.model, self.cfg.time_slice, self.cfg.max_live);
        let Worker { id, cfg, manifest, rt, tok, ngram_caches, cancels } = self;
        let max_live = cfg.max_live.max(1);
        let slice = cfg.time_slice.max(1);
        let mut engines: HashMap<String, Box<dyn Decoder>> = HashMap::new();
        let mut live: Vec<LiveSession<'_>> = Vec::new();
        'serve: loop {
            // -- admission: top up the live set ------------------------------
            while live.len() < max_live {
                let popped = if live.is_empty() { sched.pop() } else { sched.try_pop() };
                let Some(popped) = popped else {
                    if live.is_empty() {
                        break 'serve; // scheduler closed and drained
                    }
                    break; // queue momentarily empty; keep stepping
                };
                match Self::open(&cfg, &manifest, &rt, &mut engines, &ngram_caches,
                                 &tok, popped) {
                    Ok(ls) => live.push(ls),
                    Err((rid, msg)) => {
                        cancels.clear(rid);
                        if replies.send(Reply::Done(Response::err(rid, msg))).is_err() {
                            break 'serve;
                        }
                    }
                }
            }
            // -- one scheduling round: a slice per live session --------------
            let mut i = 0;
            while i < live.len() {
                if Self::drive(&mut live[i], slice, &tok, &cancels, &replies) {
                    let ls = live.swap_remove(i);
                    if !Self::retire(ls, &cancels, &replies) {
                        break 'serve; // server gone
                    }
                } else {
                    i += 1;
                }
            }
        }
        info!("worker", "worker {} shutting down", id);
    }
}
