//! Engine workers: each worker thread owns its PJRT client, model runtime,
//! and a cache of decoder instances (the PJRT client is not Send — per-thread
//! ownership is mandatory, and it also mirrors lookahead parallelism's
//! full-model-per-device design).
//!
//! Scheduling: instead of running one request to completion, a worker keeps
//! up to `max_live` open [`DecodeSession`]s and round-robins a configurable
//! time-slice of decode steps across them. Long generations therefore no
//! longer block short ones behind them (the single-worker head-of-line
//! case), streaming requests emit chunks as steps commit, and cancellation
//! is observed between steps — a cancelled request stops within one step.
//!
//! Continuous batching (`WorkerConfig::batch_decode`, default on): each
//! scheduling round groups live sessions by their [`BatchStep`] group key
//! (engine + executable + layout) and runs one *fused* decode call per
//! group per step via [`crate::engine::step_group`], instead of one model
//! call per session per step — the memory-bandwidth-bound decode cost is
//! paid once per round. Sessions without batch support (or singleton
//! groups) keep the per-session drive path; cancellation and deadlines are
//! checked between fused rounds, so both still land within one decode
//! step. Batched and sequential execution commit byte-identical token
//! streams (`rust/tests/batched_equivalence.rs`).

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::control::{level_from_state, switch_session, AdaptiveConfig,
                     AdaptiveController, Controller, CtlCarry, EngineLevel,
                     EngineSwitch, RoundObs};
use crate::engine::autoregressive::AutoRegressive;
use crate::engine::jacobi::Jacobi;
use crate::engine::lookahead::Lookahead;
use crate::engine::prompt_lookup::PromptLookup;
use crate::engine::spec_decode::SpecDecode;
use crate::engine::{step_group, BatchStep, Decoder, DecodeSession, FinishReason,
                    StepOutcome};
use crate::info;
use crate::kv::{KvHandle, KvManager, PrefixCache, SessionSnapshot};
use crate::layout::Wng;
pub use crate::server::config::WorkerConfig;
use crate::metrics::Registry;
use crate::ngram::{NgramCacheRegistry, PoolHandle};
use crate::runtime::{cpu_client, Manifest, ModelRuntime};
use crate::server::request::{Reply, Request, Response, StreamChunk};
use crate::server::scheduler::{CancelSet, Directive, MigratedSession, Popped,
                               PopOutcome, RebalanceHub, Scheduler};
use crate::tokenizer::{ByteTokenizer, Utf8StreamDecoder};
use crate::trace::{self, Tracer};
use crate::util::sync::RankedMutex;

/// How long an idle worker waits in [`Scheduler::pop_timeout`] before
/// re-checking its rebalance-hub inbox for adopted sessions.
const ADOPT_POLL: Duration = Duration::from_millis(25);

/// One open request on a worker: the session plus its streaming state.
struct LiveSession<'rt> {
    id: u64,
    stream: bool,
    queued_ms: f64,
    seq: u64,
    dec: Utf8StreamDecoder,
    deadline: Option<Instant>,
    sess: Box<dyn DecodeSession + 'rt>,
    error: Option<String>,
    /// scheduling rounds since this session was admitted or last revived
    /// ("hottest" has the lowest count; the park victim has the highest).
    rounds: u64,
    /// controller tracking (None = unknown engine method: never observed,
    /// never switched).
    ctl: Option<SessCtl>,
    /// tracing identity minted at admission (0 = untraced / sampled out);
    /// guards every per-session span recording site.
    trace_id: u64,
    /// bounded per-request timeline copy (Some only when the request set
    /// `"trace": true` on a tracing server); attached to the final record.
    tl: Option<Vec<trace::Span>>,
}

/// Controller bookkeeping on a live session: the engine level it currently
/// runs under, the [`CtlCarry`] that survives parks/migrations, and the
/// stats baseline of the last observed round.
struct SessCtl {
    level: EngineLevel,
    carry: CtlCarry,
    /// session stats totals at the last controller observation; the deltas
    /// are the per-round accept-length sample.
    seen_steps: usize,
    seen_tokens: usize,
}

/// A suspended request: its streaming state stays with the worker, the
/// session itself lives in the [`KvManager`] as a host snapshot.
struct ParkedSession {
    id: u64,
    stream: bool,
    queued_ms: f64,
    seq: u64,
    dec: Utf8StreamDecoder,
    deadline: Option<Instant>,
    handle: KvHandle,
    /// controller bookkeeping carried across the park (the engine level
    /// itself is re-derived from the snapshot on revive).
    ctl: Option<CtlCarry>,
    /// tracing identity, carried across the park (0 = untraced).
    trace_id: u64,
    /// per-request timeline copy, carried across the park.
    tl: Option<Vec<trace::Span>>,
}

impl ParkedSession {
    /// Repackage for a cross-worker hand-off: the revived snapshot replaces
    /// the local [`KvHandle`], everything else travels as-is.
    fn into_migrated(self, to: usize, snap: SessionSnapshot) -> MigratedSession {
        // the trace_id migrates (spans on both sides stitch under it); the
        // per-request timeline copy does not — it stays a best-effort local
        // view, and the global tracer still holds every span
        MigratedSession {
            to,
            id: self.id,
            stream: self.stream,
            queued_ms: self.queued_ms,
            seq: self.seq,
            dec: self.dec,
            deadline: self.deadline,
            snap,
            ctl: self.ctl,
            trace_id: self.trace_id,
        }
    }

    /// The inverse: a migration adopted (or bounced back) into the local
    /// parked set, its snapshot parked in `kv`. The exhaustive destructure
    /// keeps this the single place a migration's fields map back.
    fn from_migrated(m: MigratedSession, kv: &mut KvManager) -> ParkedSession {
        let MigratedSession {
            to: _, id, stream, queued_ms, seq, dec, deadline, snap, ctl, trace_id,
        } = m;
        let handle = kv.park(snap);
        ParkedSession {
            id, stream, queued_ms, seq, dec, deadline, handle, ctl, trace_id,
            tl: None,
        }
    }
}

pub struct Worker {
    pub id: usize,
    cfg: WorkerConfig,
    manifest: Manifest,
    rt: ModelRuntime,
    tok: ByteTokenizer,
    /// server-level shared n-gram caches (None = sharing disabled).
    ngram_caches: Option<Arc<NgramCacheRegistry>>,
    /// server-level cancellation marks, checked between steps.
    cancels: Arc<CancelSet>,
    /// server metrics (batched_rounds counter + batch_size histogram);
    /// None for workers driven outside a [`crate::server::ServerHandle`].
    metrics: Option<Arc<RankedMutex<Registry>>>,
    /// cross-worker rebalance rendezvous: load reports out, donation
    /// directives and adopted sessions in. None = rebalancing disabled.
    hub: Option<Arc<RebalanceHub>>,
    /// the prefix trie this worker's runtime consults (kept to tell a
    /// prefix-fork prefill from a cold one in the prefill span).
    prefix: Option<Arc<PrefixCache>>,
    /// span recorder shared across the server (None = tracing disabled:
    /// zero span allocation on the decode path).
    tracer: Option<Arc<Tracer>>,
}

impl Worker {
    pub fn start(id: usize, cfg: WorkerConfig,
                 ngram_caches: Option<Arc<NgramCacheRegistry>>,
                 cancels: Arc<CancelSet>,
                 metrics: Option<Arc<RankedMutex<Registry>>>,
                 prefix: Option<Arc<PrefixCache>>,
                 hub: Option<Arc<RebalanceHub>>,
                 tracer: Option<Arc<Tracer>>) -> Result<Worker> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let client = cpu_client()?;
        let rt = ModelRuntime::load(&client, &manifest, &cfg.model)?;
        let prefix = if cfg.prefix_cache {
            // server-shared trie when one was handed down, else private
            let pc = prefix.unwrap_or_else(|| Arc::new(PrefixCache::with_defaults()));
            rt.set_prefix_cache(Some(pc.clone()));
            Some(pc)
        } else {
            None
        };
        Ok(Worker {
            id,
            cfg,
            manifest,
            rt,
            tok: ByteTokenizer::new(),
            ngram_caches,
            cancels,
            metrics,
            hub,
            prefix,
            tracer,
        })
    }

    /// Record a span into the global tracer and, when the session asked
    /// for a per-request timeline, into its bounded local copy.
    fn record(tracer: &Option<Arc<Tracer>>, tl: &mut Option<Vec<trace::Span>>,
              span: trace::Span) {
        if let Some(tl) = tl {
            trace::timeline_push(tl, span.clone());
        }
        if let Some(t) = tracer {
            t.push(span);
        }
    }

    /// The shared draft runtime for `name`, loading (and caching) it on
    /// first use — fresh spec-decode engines and spec-decode snapshot
    /// resumes draw from the same per-worker pool.
    fn draft_runtime(rt: &ModelRuntime, manifest: &Manifest,
                     drafts: &mut HashMap<String, Rc<ModelRuntime>>, name: &str)
                     -> Result<Rc<ModelRuntime>> {
        if let Some(d) = drafts.get(name) {
            return Ok(d.clone());
        }
        let d = Rc::new(ModelRuntime::load(&rt.client, manifest, name)?);
        drafts.insert(name.to_string(), d.clone());
        Ok(d)
    }

    /// Resume a parked/adopted snapshot, providing a draft runtime when the
    /// engine needs one (spec-decode).
    fn resume_snap<'rt>(rt: &'rt ModelRuntime, manifest: &Manifest,
                        drafts: &mut HashMap<String, Rc<ModelRuntime>>,
                        snap: SessionSnapshot)
                        -> Result<Box<dyn DecodeSession + 'rt>> {
        match snap.draft_model().map(str::to_string) {
            Some(name) => {
                let draft = Self::draft_runtime(rt, manifest, drafts, &name)?;
                snap.resume_with(rt, Some(draft))
            }
            None => snap.resume(rt),
        }
    }

    fn engine_key(req: &Request) -> String {
        match (&req.method[..], req.wng) {
            ("lookahead", Some((w, n, g))) => format!("lookahead:{w},{n},{g}"),
            (m, _) => m.to_string(),
        }
    }

    fn make_engine(cfg: &WorkerConfig, manifest: &Manifest, rt: &ModelRuntime,
                   drafts: &mut HashMap<String, Rc<ModelRuntime>>, req: &Request)
                   -> Result<Box<dyn Decoder>> {
        let (w, n, g) = req.wng.unwrap_or(cfg.wng);
        Ok(match &req.method[..] {
            "lookahead" => Box::new(Lookahead::with_wng(w, n, g)),
            "autoregressive" | "greedy" | "ar" => Box::new(AutoRegressive::new()),
            "jacobi" => Box::new(Jacobi::new(8)),
            "prompt_lookup" => Box::new(PromptLookup::new(8, 1)),
            "spec_decode" => {
                let draft =
                    Self::draft_runtime(rt, manifest, drafts, &cfg.draft_model)?;
                Box::new(SpecDecode::with_shared(draft, 4))
            }
            other => return Err(anyhow!("unknown decoding method '{other}'")),
        })
    }

    /// Token budget: keep the BOS + the most recent prompt bytes that fit.
    fn encode_prompt(tok: &ByteTokenizer, rt: &ModelRuntime, prompt: &str) -> Vec<u32> {
        let mut ids = tok.encode_with_bos(prompt);
        let cap = rt.prefill_len;
        if ids.len() > cap {
            let tail = ids.len() - (cap - 1);
            let mut v = vec![crate::tokenizer::BOS_ID];
            v.extend_from_slice(&ids[tail..]);
            ids = v;
        }
        ids
    }

    /// Bind the request to an n-gram store: the server's shared cache when
    /// the server handed this worker a registry (`ServerConfig.share_ngrams`,
    /// per-request overridable), else a cold private pool. Engines without a
    /// pool get a detached handle.
    ///
    /// Sampled requests (`temperature > 0`) default to a private pool even
    /// when the server shares: Algorithm 4 preserves the output
    /// *distribution* with any candidate set, but the per-seed token
    /// sequence depends on which candidates the cache holds — a warm cache
    /// would silently break seeded reproducibility. An explicit
    /// `share_ngrams: true` on the request still opts in.
    fn bind_pool_for(cfg: &WorkerConfig, caches: &Option<Arc<NgramCacheRegistry>>,
                     req: &Request, engine: &dyn Decoder) -> PoolHandle {
        let Some(spec) = engine.pool_spec() else {
            return PoolHandle::none();
        };
        let greedy = req.temperature <= 0.0;
        let share = req.share_ngrams.unwrap_or(greedy);
        match (caches, share) {
            (Some(reg), true) => PoolHandle::shared_scoped(
                reg.get_or_create_scoped(req.tenant.as_deref(), &cfg.model, spec),
                req.tenant.clone(),
            ),
            _ => PoolHandle::private(spec),
        }
    }

    /// Open a session for a popped request. Engines are cached per
    /// (method, wng) key; sessions never borrow the engine, so one cached
    /// engine can back several interleaved sessions.
    #[allow(clippy::too_many_arguments)]
    fn open<'rt>(cfg: &WorkerConfig, manifest: &Manifest, rt: &'rt ModelRuntime,
                 engines: &mut HashMap<String, Box<dyn Decoder>>,
                 drafts: &mut HashMap<String, Rc<ModelRuntime>>,
                 caches: &Option<Arc<NgramCacheRegistry>>, tok: &ByteTokenizer,
                 prefix: &Option<Arc<PrefixCache>>,
                 tracer: &Option<Arc<Tracer>>, wid: usize,
                 popped: Popped) -> Result<LiveSession<'rt>, (u64, String)> {
        let req = popped.req;
        let rid = req.id;
        // tracing identity: minted per admission, 0 when sampled out; a
        // per-request "trace": true forces the mint past the sampler
        let (trace_id, t_admit) = match tracer {
            Some(t) => (t.mint(req.trace), t.now_us()),
            None => (0, 0),
        };
        let mut tl = (trace_id != 0 && req.trace).then(Vec::new);
        let key = Self::engine_key(&req);
        if !engines.contains_key(&key) {
            let engine = Self::make_engine(cfg, manifest, rt, drafts, &req)
                .map_err(|e| (rid, e.to_string()))?;
            engines.insert(key.clone(), engine);
        }
        let engine = engines.get(&key).unwrap();
        let ids = Self::encode_prompt(tok, rt, &req.prompt);
        let pool = Self::bind_pool_for(cfg, caches, &req, engine.as_ref());
        if let Some(t) = tracer {
            if trace_id != 0 {
                let span = t
                    .span(wid, trace_id, "admit", "session", t_admit)
                    .arg("queued_ms", format!("{:.2}", popped.queued_ms))
                    .arg("method", req.method.clone());
                Self::record(tracer, &mut tl, span);
            }
        }
        // prefix-trie namespace for the prefill inside begin(): tenants
        // must never fork (or time) each other's cached prefixes
        rt.set_prefix_namespace(req.tenant.as_deref());
        let pf_hits = (trace_id != 0)
            .then(|| prefix.as_ref().map_or(0, |p| p.stats().hits));
        let t_prefill = tracer.as_ref().map_or(0, |t| t.now_us());
        let sess = engine
            .begin(rt, &ids, &req.gen_params(), pool)
            .map_err(|e| (rid, e.to_string()))?;
        if let Some(t) = tracer {
            if trace_id != 0 {
                // a trie hit during begin() means this prefill forked a
                // stored snapshot instead of running cold
                let forked = pf_hits.is_some_and(|h0| {
                    prefix.as_ref().map_or(0, |p| p.stats().hits) > h0
                });
                let span = t
                    .span(wid, trace_id, "prefill", "prefill", t_prefill)
                    .arg("mode", if forked { "fork" } else { "cold" })
                    .arg("prompt_tokens", ids.len().to_string());
                Self::record(tracer, &mut tl, span);
            }
        }
        // controller tracking: only greedy sessions may ever switch (all
        // five engines are byte-exact under greedy; sampled engines consume
        // per-engine RNG streams a switch would disturb)
        let ctl = Self::level_for(cfg, &req).map(|level| SessCtl {
            level,
            carry: CtlCarry {
                prompt_ids: ids,
                tenant: req.tenant.clone(),
                adaptive: req.controller.as_deref().unwrap_or(&cfg.controller)
                    == "adaptive"
                    && req.temperature <= 0.0,
            },
            seen_steps: 0,
            seen_tokens: 0,
        });
        Ok(LiveSession {
            id: rid,
            stream: req.stream,
            queued_ms: popped.queued_ms,
            seq: 0,
            dec: Utf8StreamDecoder::new(),
            deadline: req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms)),
            sess,
            error: None,
            rounds: 0,
            ctl,
            trace_id,
            tl,
        })
    }

    /// The [`EngineLevel`] a request's session starts under — must mirror
    /// `make_engine`'s construction choices exactly.
    fn level_for(cfg: &WorkerConfig, req: &Request) -> Option<EngineLevel> {
        let (w, n, g) = req.wng.unwrap_or(cfg.wng);
        Some(match &req.method[..] {
            "lookahead" => EngineLevel::Lookahead { w, n, g },
            "autoregressive" | "greedy" | "ar" => EngineLevel::Autoregressive,
            "jacobi" => EngineLevel::Jacobi { k: 8 },
            "prompt_lookup" => EngineLevel::PromptLookup { k: 8, match_len: 1 },
            "spec_decode" => EngineLevel::SpecDecode { gamma: 4 },
            _ => return None,
        })
    }

    /// Emit the streaming chunk for one committed step (no-op for
    /// non-streaming sessions or empty deltas).
    fn emit_commit(ls: &mut LiveSession, tokens: &[u32], tok: &ByteTokenizer,
                   replies: &Sender<Reply>) {
        if ls.stream && !tokens.is_empty() {
            let delta = ls.dec.push(&tok.bytes(tokens));
            if !delta.is_empty() {
                ls.seq += 1;
                let _ = replies.send(Reply::Chunk(StreamChunk {
                    id: ls.id,
                    seq: ls.seq,
                    delta,
                }));
            }
        }
    }

    /// Check the session's stop signals (cancellation mark, deadline);
    /// returns true when the session is stopped or already finished.
    fn check_stops(ls: &mut LiveSession, cancels: &CancelSet) -> bool {
        if ls.sess.finished().is_some() || ls.error.is_some() {
            return true;
        }
        if cancels.contains(ls.id) {
            ls.sess.cancel(FinishReason::Cancelled);
            return true;
        }
        if let Some(d) = ls.deadline {
            if Instant::now() >= d {
                ls.sess.cancel(FinishReason::Deadline);
                return true;
            }
        }
        false
    }

    /// Run one time-slice for a session: up to `slice` steps, checking
    /// cancellation and the deadline before each. Emits streaming chunks.
    /// Readiness to retire is left on the session (`finished()` / `error`)
    /// for the caller's post-round sweep.
    fn drive(ls: &mut LiveSession, slice: usize, tok: &ByteTokenizer,
             cancels: &CancelSet, replies: &Sender<Reply>) {
        for _ in 0..slice {
            if Self::check_stops(ls, cancels) {
                return;
            }
            match ls.sess.step() {
                Ok(StepOutcome::Committed { tokens }) => {
                    Self::emit_commit(ls, &tokens, tok, replies);
                }
                Ok(StepOutcome::Finished { .. }) => return,
                Err(e) => {
                    ls.error = Some(e.to_string());
                    return;
                }
            }
        }
    }

    /// The per-session batch-group key; None = this session cannot batch.
    fn group_key(ls: &LiveSession) -> Option<String> {
        ls.sess.batch_ref().map(|b| b.group_key())
    }

    /// One `BatchedRound`: group live sessions by batch key and give every
    /// group `slice` *fused* decode steps (one `step_group` call per step
    /// per group). Singleton and non-batchable sessions fall back to the
    /// sequential [`Worker::drive`] path for their slice. Stop signals are
    /// checked between fused rounds, so a cancel or deadline inside a
    /// batched round still lands within one decode step. Retirement is the
    /// caller's job (sweep on `finished()`/`error`).
    #[allow(clippy::too_many_arguments)]
    fn batched_round<'rt>(rt: &'rt ModelRuntime, live: &mut [LiveSession<'rt>],
                          slice: usize, tok: &ByteTokenizer, cancels: &CancelSet,
                          replies: &Sender<Reply>,
                          metrics: &Option<Arc<RankedMutex<Registry>>>,
                          tracer: &Option<Arc<Tracer>>, wid: usize) {
        // contiguous runs of one group key; stable per-key arrival order.
        // group_key allocates, so keys are computed once for the sort
        // (cached) and once more for the run scan — 2N small allocations
        // per round, not O(N log N).
        let t_plan = tracer.as_ref().map(|t| t.now_us());
        live.sort_by_cached_key(Self::group_key);
        let keys: Vec<Option<String>> = live.iter().map(Self::group_key).collect();
        if let (Some(t), Some(t0)) = (tracer, t_plan) {
            // worker-lane span (trace_id 0): batch planning is cross-session
            t.push(t.span(wid, 0, "plan", "decode", t0)
                .arg("sessions", live.len().to_string()));
        }
        let mut at = 0;
        while at < live.len() {
            let mut end = at + 1;
            while end < keys.len() && keys[end] == keys[at] {
                end += 1;
            }
            if keys[at].is_none() || end - at == 1 {
                for ls in live[at..end].iter_mut() {
                    Self::drive(ls, slice, tok, cancels, replies);
                }
            } else {
                let t_launch = tracer.as_ref().map(|t| t.now_us());
                Self::drive_group(rt, &mut live[at..end], slice, tok, cancels,
                                  replies, metrics);
                if let (Some(t), Some(t0)) = (tracer, t_launch) {
                    t.push(t.span(wid, 0, "launch", "decode", t0)
                        .arg("group", keys[at].clone().unwrap_or_default())
                        .arg("batch", (end - at).to_string())
                        .arg("slice", slice.to_string()));
                }
            }
            at = end;
        }
    }

    /// `slice` fused steps for one compatible group.
    fn drive_group<'rt>(rt: &'rt ModelRuntime, group: &mut [LiveSession<'rt>],
                        slice: usize, tok: &ByteTokenizer, cancels: &CancelSet,
                        replies: &Sender<Reply>,
                        metrics: &Option<Arc<RankedMutex<Registry>>>) {
        for _ in 0..slice {
            // stop checks between fused rounds (cancel/deadline land
            // within one decode step, batched or not)
            let mut active: Vec<usize> = Vec::new();
            for (i, ls) in group.iter_mut().enumerate() {
                if !Self::check_stops(ls, cancels) {
                    active.push(i);
                }
            }
            if active.is_empty() {
                return;
            }
            let mut refs: Vec<&mut (dyn DecodeSession + '_)> = group
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| active.contains(i))
                .map(|(_, ls)| ls.sess.as_mut())
                .collect();
            let out = step_group(rt, &mut refs);
            drop(refs);
            if let Some(m) = metrics {
                let mut m = m.lock();
                for sz in &out.fused {
                    m.inc("batched_rounds", 1);
                    m.observe("batch_size", *sz as f64);
                }
            }
            for (k, res) in out.outcomes.into_iter().enumerate() {
                let ls = &mut group[active[k]];
                match res {
                    Ok(StepOutcome::Committed { tokens }) => {
                        Self::emit_commit(ls, &tokens, tok, replies);
                    }
                    Ok(StepOutcome::Finished { .. }) => {}
                    Err(e) => ls.error = Some(e.to_string()),
                }
            }
        }
    }

    /// The default adaptive ladders filtered to the levels this model's
    /// executable inventory can actually serve, so the controller never
    /// proposes a switch the runtime would reject.
    fn adaptive_config_for(rt: &ModelRuntime) -> AdaptiveConfig {
        let mut cfg = AdaptiveConfig::default();
        cfg.lookahead_levels.retain(|&(w, n, g)| {
            Self::target_available(rt, &EngineLevel::Lookahead { w, n, g })
        });
        cfg.jacobi_ks
            .retain(|&k| Self::target_available(rt, &EngineLevel::Jacobi { k }));
        cfg.spec_gammas.retain(|&gamma| {
            Self::target_available(rt, &EngineLevel::SpecDecode { gamma })
        });
        cfg
    }

    /// Can the loaded model serve `target`? Mirrors each engine's
    /// begin/resume validation, so a doomed switch is rejected *before*
    /// the session is suspended.
    fn target_available(rt: &ModelRuntime, target: &EngineLevel) -> bool {
        match target {
            EngineLevel::Autoregressive => true,
            EngineLevel::Lookahead { w, n, g } => {
                *w >= 1 && *n >= 2 && *g >= 1
                    && (rt.mm.find_decode_la(*w, *n, *g, "jnp").is_some()
                        || rt.mm.find_decode_gen(Wng::new(*w, *n, *g).t_in()).is_some())
            }
            EngineLevel::Jacobi { k } => *k >= 2 && rt.mm.decode_lin_exe(*k).is_ok(),
            EngineLevel::PromptLookup { k, match_len } => {
                *k >= 2 && *match_len >= 1 && rt.mm.decode_lin_exe(*k).is_ok()
            }
            EngineLevel::SpecDecode { gamma } => {
                *gamma >= 1 && rt.mm.decode_lin_exe(gamma + 1).is_ok()
            }
        }
    }

    /// Warm-cache signal: the shared prompt_lookup n-gram cache a promoted
    /// session would draw from (tenant-scoped) already holds harvested
    /// entries.
    fn ngram_warm(cfg: &WorkerConfig, caches: &Option<Arc<NgramCacheRegistry>>,
                  tenant: Option<&str>) -> bool {
        // entries before the shared pool counts as warm (a couple of
        // one-off inserts should not flip every AR session to lookup)
        const WARM_ENTRIES: usize = 8;
        let Some(reg) = caches else { return false };
        let Some(spec) = PromptLookup::new(8, 1).pool_spec() else { return false };
        let stats = reg.get_or_create_scoped(tenant, &cfg.model, spec).stats();
        stats.entries >= WARM_ENTRIES
    }

    fn bump(metrics: &Option<Arc<RankedMutex<Registry>>>, key: &str) {
        if let Some(m) = metrics {
            m.lock().inc(key, 1);
        }
    }

    /// Controller hook, once per scheduling round — a commit boundary for
    /// every live session: record each tracked session's accept-length
    /// delta in the per-engine histogram, and for adaptive sessions feed
    /// the observation to the controller and apply any engine switch over
    /// the suspend/resume path.
    #[allow(clippy::too_many_arguments)]
    fn control_round<'rt>(cfg: &WorkerConfig, manifest: &Manifest,
                          rt: &'rt ModelRuntime,
                          drafts: &mut HashMap<String, Rc<ModelRuntime>>,
                          caches: &Option<Arc<NgramCacheRegistry>>,
                          controller: &mut dyn Controller,
                          live: &mut [LiveSession<'rt>],
                          metrics: &Option<Arc<RankedMutex<Registry>>>,
                          tracer: &Option<Arc<Tracer>>, wid: usize) {
        for ls in live.iter_mut() {
            let target = {
                let Some(ctl) = ls.ctl.as_mut() else { continue };
                let stats = ls.sess.stats();
                let steps = stats.decode_steps - ctl.seen_steps;
                let tokens = stats.generated_tokens - ctl.seen_tokens;
                ctl.seen_steps = stats.decode_steps;
                ctl.seen_tokens = stats.generated_tokens;
                if steps == 0 {
                    continue; // no committed work this round: nothing to observe
                }
                if let Some(m) = metrics {
                    m.lock().observe(
                        &format!("accept_len_{}", ctl.level.method()),
                        tokens as f64 / steps as f64,
                    );
                }
                // switching requires a healthy, unfinished, suspendable
                // session whose effective mode is adaptive
                if !ctl.carry.adaptive || ls.error.is_some()
                    || ls.sess.finished().is_some()
                    || !ls.sess.suspendable()
                {
                    continue;
                }
                let obs = RoundObs {
                    steps: steps as u64,
                    tokens: tokens as u64,
                    ngram_warm: Self::ngram_warm(cfg, caches,
                                                 ctl.carry.tenant.as_deref()),
                };
                Self::bump(metrics, "ctl_decisions");
                let t_decide = tracer.as_ref().map(|t| t.now_us());
                let decision = controller.decide(ls.id, &ctl.level, &obs);
                if let (Some(t), Some(t0)) = (tracer, t_decide) {
                    if ls.trace_id != 0 {
                        let to = match &decision {
                            EngineSwitch::Stay => "stay".to_string(),
                            EngineSwitch::Switch(tg) => tg.method().to_string(),
                        };
                        let span = t
                            .span(wid, ls.trace_id, "decide", "ctl", t0)
                            .arg("from", ctl.level.method())
                            .arg("to", to);
                        Self::record(tracer, &mut ls.tl, span);
                    }
                }
                match decision {
                    EngineSwitch::Stay => continue,
                    EngineSwitch::Switch(target) => target,
                }
            };
            Self::apply_switch(cfg, manifest, rt, drafts, ls, target, metrics,
                               tracer, wid);
        }
    }

    /// Apply a controller decision: pre-validate the target so the
    /// post-suspend failure path stays cold, then switch the session over
    /// suspend/resume (committed prefix byte-identical across the switch).
    #[allow(clippy::too_many_arguments)]
    fn apply_switch<'rt>(cfg: &WorkerConfig, manifest: &Manifest,
                         rt: &'rt ModelRuntime,
                         drafts: &mut HashMap<String, Rc<ModelRuntime>>,
                         ls: &mut LiveSession<'rt>, target: EngineLevel,
                         metrics: &Option<Arc<RankedMutex<Registry>>>,
                         tracer: &Option<Arc<Tracer>>, wid: usize) {
        let Some(ctl) = ls.ctl.as_mut() else { return };
        if !Self::target_available(rt, &target) {
            Self::bump(metrics, "ctl_rejected");
            return;
        }
        let draft = match target {
            EngineLevel::SpecDecode { .. } => {
                match Self::draft_runtime(rt, manifest, drafts, &cfg.draft_model) {
                    Ok(d) => {
                        // a promotion from a draft-less engine must rebuild
                        // the draft cache by prefilling the full history —
                        // reject histories the draft prefill cannot hold
                        let hist =
                            ctl.carry.prompt_ids.len() + ls.sess.tokens().len();
                        if !matches!(ctl.level, EngineLevel::SpecDecode { .. })
                            && hist > d.prefill_len
                        {
                            Self::bump(metrics, "ctl_rejected");
                            return;
                        }
                        Some(d)
                    }
                    Err(_) => {
                        Self::bump(metrics, "ctl_rejected");
                        return;
                    }
                }
            }
            _ => None,
        };
        let from = ctl.level.method();
        let t_switch = tracer.as_ref().map(|t| t.now_us());
        match switch_session(&mut ls.sess, rt, &target,
                             Some(&ctl.carry.prompt_ids), draft) {
            Ok(()) => {
                if let Some(m) = metrics {
                    let mut m = m.lock();
                    m.inc("ctl_switches", 1);
                    m.inc(&format!("ctl_switch_to_{}", target.method()), 1);
                }
                if let (Some(t), Some(t0)) = (tracer, t_switch) {
                    if ls.trace_id != 0 {
                        let span = t
                            .span(wid, ls.trace_id, "switch", "ctl", t0)
                            .arg("from", from)
                            .arg("to", target.method());
                        Self::record(tracer, &mut ls.tl, span);
                    }
                }
                ctl.level = target;
            }
            Err(e) => {
                // a failure after the suspend consumed the old session —
                // the request fails and the retirement sweep delivers the
                // record (pre-validation above keeps this path cold)
                ls.error = Some(format!("engine switch failed: {e}"));
                Self::bump(metrics, "ctl_switch_failed");
            }
        }
    }

    /// Park the coldest suspendable live session: snapshot to the
    /// [`KvManager`], free its device cache. Returns false when no session
    /// can be parked (none suspendable — the budget stays soft-violated).
    /// A failing suspend poisons only its own session (picked up by the
    /// caller's retirement sweep).
    fn park_one<'rt>(live: &mut Vec<LiveSession<'rt>>,
                     parked: &mut VecDeque<ParkedSession>, kv: &mut KvManager,
                     metrics: &Option<Arc<RankedMutex<Registry>>>,
                     tracer: &Option<Arc<Tracer>>, wid: usize) -> bool {
        // coldest = most rounds since admission/revival (ties: first found)
        let mut best: Option<usize> = None;
        for (i, ls) in live.iter().enumerate() {
            if ls.error.is_none() && ls.sess.suspendable()
                && best.is_none_or(|b: usize| ls.rounds > live[b].rounds)
            {
                best = Some(i);
            }
        }
        let Some(i) = best else { return false };
        let mut ls = live.remove(i);
        let t_park = tracer.as_ref().map(|t| t.now_us());
        match ls.sess.suspend() {
            Ok(snap) => {
                let handle = kv.park(snap);
                if let Some(m) = metrics {
                    m.lock().inc("kv_snapshots", 1);
                }
                let mut tl = ls.tl;
                if let (Some(t), Some(t0)) = (tracer, t_park) {
                    if ls.trace_id != 0 {
                        let span = t
                            .span(wid, ls.trace_id, "park", "kv", t0)
                            .arg("rounds", ls.rounds.to_string());
                        Self::record(tracer, &mut tl, span);
                    }
                }
                parked.push_back(ParkedSession {
                    id: ls.id,
                    stream: ls.stream,
                    queued_ms: ls.queued_ms,
                    seq: ls.seq,
                    dec: ls.dec,
                    deadline: ls.deadline,
                    handle,
                    ctl: ls.ctl.map(|c| c.carry),
                    trace_id: ls.trace_id,
                    tl,
                });
                true
            }
            Err(e) => {
                ls.error = Some(format!("suspend failed: {e}"));
                live.push(ls);
                false
            }
        }
    }

    /// Revive the longest-parked session back onto the device. Returns
    /// false only when the reply channel is gone (server shut down).
    fn revive_one<'rt>(rt: &'rt ModelRuntime, manifest: &Manifest,
                       drafts: &mut HashMap<String, Rc<ModelRuntime>>,
                       live: &mut Vec<LiveSession<'rt>>,
                       parked: &mut VecDeque<ParkedSession>, kv: &mut KvManager,
                       cancels: &CancelSet, replies: &Sender<Reply>,
                       metrics: &Option<Arc<RankedMutex<Registry>>>,
                       tracer: &Option<Arc<Tracer>>, wid: usize) -> bool {
        let Some(p) = parked.pop_front() else { return true };
        let t_revive = tracer.as_ref().map(|t| t.now_us());
        let resumed = kv
            .revive(p.handle)
            .ok_or_else(|| anyhow!("parked session {} lost its snapshot", p.id))
            .and_then(|snap| {
                // controller re-entry state, read off the snapshot before
                // the resume consumes it: the engine level the session
                // wakes under, and the stats baseline (pre-park rounds were
                // already observed on the worker that parked it)
                let level = level_from_state(&snap.engine);
                let seen = (snap.stats.decode_steps, snap.stats.generated_tokens);
                Self::resume_snap(rt, manifest, drafts, snap)
                    .map(|sess| (sess, level, seen))
            });
        match resumed {
            Ok((sess, level, (seen_steps, seen_tokens))) => {
                if let Some(m) = metrics {
                    m.lock().inc("kv_restores", 1);
                }
                let ctl = p.ctl.map(|carry| SessCtl {
                    level,
                    carry,
                    seen_steps,
                    seen_tokens,
                });
                let mut tl = p.tl;
                if let (Some(t), Some(t0)) = (tracer, t_revive) {
                    if p.trace_id != 0 {
                        let span = t.span(wid, p.trace_id, "revive", "kv", t0);
                        Self::record(tracer, &mut tl, span);
                    }
                }
                live.push(LiveSession {
                    id: p.id,
                    stream: p.stream,
                    queued_ms: p.queued_ms,
                    seq: p.seq,
                    dec: p.dec,
                    deadline: p.deadline,
                    sess,
                    error: None,
                    rounds: 0,
                    ctl,
                    trace_id: p.trace_id,
                    tl,
                });
                true
            }
            Err(e) => {
                cancels.clear(p.id);
                replies.send(Reply::Done(Response::err(p.id, e.to_string()))).is_ok()
            }
        }
    }

    /// Retire parked sessions whose cancel mark or deadline already fired —
    /// straight from the host snapshot, with no device restore and no wait
    /// for a rotation slot (keeps the "cancel lands within one step"
    /// promise even for suspended sessions). The final record is built the
    /// same way `retire` builds it: full text decode of the committed
    /// tokens (equal to the streamed deltas + tail by the
    /// `Utf8StreamDecoder` one-shot equivalence) and manually sealed pool
    /// stats. Returns false when the reply channel is gone.
    fn sweep_parked(parked: &mut VecDeque<ParkedSession>, kv: &mut KvManager,
                    tok: &ByteTokenizer, cancels: &CancelSet,
                    controller: &mut dyn Controller, replies: &Sender<Reply>)
                    -> bool {
        let mut i = 0;
        while i < parked.len() {
            let reason = if cancels.contains(parked[i].id) {
                Some(FinishReason::Cancelled)
            } else if parked[i].deadline.is_some_and(|d| Instant::now() >= d) {
                Some(FinishReason::Deadline)
            } else {
                None
            };
            let Some(reason) = reason else {
                i += 1;
                continue;
            };
            let Some(p) = parked.remove(i) else { break };
            cancels.clear(p.id);
            controller.retire(p.id);
            let Some(snap) = kv.revive(p.handle) else {
                // the snapshot is gone (regression: this used to `continue`
                // straight past the entry, leaving the client waiting on a
                // stream that would never end) — the contract is that every
                // request gets a final record, so fail it explicitly
                if !Self::fail_parked(p, cancels, replies) {
                    return false;
                }
                continue;
            };
            let mut stats = snap.stats.clone();
            snap.pool.fill_stats(&mut stats);
            stats.wall = snap.wall_offset;
            if p.stream {
                let mut dec = p.dec;
                let tail = dec.finish();
                if !tail.is_empty() {
                    let _ = replies.send(Reply::Chunk(StreamChunk {
                        id: p.id,
                        seq: p.seq + 1,
                        delta: tail,
                    }));
                }
            }
            let text = tok.decode(&snap.out);
            let mut resp = Response::ok(p.id, text, &stats, p.queued_ms)
                .with_finish(reason.as_str());
            if let Some(tl) = &p.tl {
                resp.timeline = Some(trace::timeline_json(tl));
            }
            if replies.send(Reply::Done(resp)).is_err() {
                return false;
            }
        }
        true
    }

    /// Final (Failed) record for a parked session whose snapshot is lost:
    /// flush the held-back stream-decoder tail, then emit the error record
    /// — the client must never hang on a dropped entry. Returns false when
    /// the reply channel is gone.
    fn fail_parked(p: ParkedSession, cancels: &CancelSet,
                   replies: &Sender<Reply>) -> bool {
        cancels.clear(p.id);
        let ParkedSession { id, stream, seq, mut dec, .. } = p;
        if stream {
            let tail = dec.finish();
            if !tail.is_empty() {
                let _ = replies.send(Reply::Chunk(StreamChunk {
                    id,
                    seq: seq + 1,
                    delta: tail,
                }));
            }
        }
        let resp = Response::err(id, format!("parked session {id} lost its snapshot"));
        replies.send(Reply::Done(resp)).is_ok()
    }

    /// Donate the coldest (longest-parked) session to worker `to` through
    /// the rebalance hub. If the target exited between the directive and
    /// the hand-off, the session is re-parked locally — a migration never
    /// strands a request. Returns false when the reply channel is gone.
    fn donate(to: usize, parked: &mut VecDeque<ParkedSession>, kv: &mut KvManager,
              hub: &RebalanceHub, cancels: &CancelSet,
              controller: &mut dyn Controller, replies: &Sender<Reply>,
              metrics: &Option<Arc<RankedMutex<Registry>>>) -> bool {
        let Some(p) = parked.pop_front() else { return true };
        let Some(snap) = kv.revive(p.handle) else {
            // same contract as sweep_parked: a lost snapshot still yields a
            // final record
            controller.retire(p.id);
            return Self::fail_parked(p, cancels, replies);
        };
        let id = p.id;
        match hub.transfer(p.into_migrated(to, snap)) {
            Ok(()) => {
                // the controller's per-session state stays behind (the
                // adopter's controller re-warms from fresh observations)
                controller.retire(id);
                if let Some(m) = metrics {
                    m.lock().inc("rebalanced_sessions", 1);
                }
            }
            Err(m) => {
                // target gone: re-park at the front (it stays the coldest)
                parked.push_front(ParkedSession::from_migrated(m, kv));
            }
        }
        true
    }

    /// Ship the coldest parked session to remote peer `peer` through the
    /// hub's network transport. The migration's `to` is this worker's OWN
    /// id, so a wire-level bounce re-queues it here through the ordinary
    /// transfer path and the next round re-parks it like a local bounce.
    /// Returns None when the reply channel is gone (server shut down),
    /// Some(false) when the transport refused — the session is re-parked
    /// and the caller stops shipping this round — and Some(true) on
    /// hand-off.
    #[allow(clippy::too_many_arguments)]
    fn donate_remote_one(self_id: usize, peer: usize,
                         parked: &mut VecDeque<ParkedSession>, kv: &mut KvManager,
                         hub: &RebalanceHub, cancels: &CancelSet,
                         controller: &mut dyn Controller, replies: &Sender<Reply>,
                         metrics: &Option<Arc<RankedMutex<Registry>>>) -> Option<bool> {
        let Some(p) = parked.pop_front() else { return Some(false) };
        let Some(snap) = kv.revive(p.handle) else {
            controller.retire(p.id);
            if !Self::fail_parked(p, cancels, replies) {
                return None;
            }
            return Some(true);
        };
        let id = p.id;
        match hub.donate_remote(peer, p.into_migrated(self_id, snap)) {
            Ok(()) => {
                controller.retire(id);
                if let Some(m) = metrics {
                    m.lock().inc("rebalanced_sessions", 1);
                }
                Some(true)
            }
            Err(m) => {
                // transport gone (shutdown): re-park at the front
                parked.push_front(ParkedSession::from_migrated(m, kv));
                Some(false)
            }
        }
    }

    /// Adopt a session migrated here: park the snapshot in the local
    /// [`KvManager`]; the normal revive loop restores it to the device when
    /// a slot frees (or the parked sweeps retire it).
    fn adopt(m: MigratedSession, parked: &mut VecDeque<ParkedSession>,
             kv: &mut KvManager, metrics: &Option<Arc<RankedMutex<Registry>>>) {
        if let Some(reg) = metrics {
            reg.lock().inc("rebalance_adopted", 1);
        }
        parked.push_back(ParkedSession::from_migrated(m, kv));
    }

    /// Deliver the final record for a finished/cancelled/failed session.
    /// Returns false when the reply channel is gone (server shut down).
    fn retire(ls: LiveSession, cancels: &CancelSet, replies: &Sender<Reply>) -> bool {
        cancels.clear(ls.id);
        let LiveSession { id, stream, queued_ms, mut dec, seq, sess, error, tl, .. } =
            ls;
        if let Some(msg) = error {
            let mut resp = Response::err(id, msg);
            if let Some(tl) = &tl {
                resp.timeline = Some(trace::timeline_json(tl));
            }
            return replies.send(Reply::Done(resp)).is_ok();
        }
        let finish = sess.finished().map_or("", |r| r.as_str());
        let (out, _pool) = sess.into_output();
        if stream {
            // flush any held-back partial UTF-8 sequence as a last chunk
            let tail = dec.finish();
            if !tail.is_empty() {
                let _ = replies.send(Reply::Chunk(StreamChunk {
                    id,
                    seq: seq + 1,
                    delta: tail,
                }));
            }
        }
        let mut resp =
            Response::ok(id, out.text, &out.stats, queued_ms).with_finish(finish);
        if let Some(tl) = &tl {
            resp.timeline = Some(trace::timeline_json(tl));
        }
        replies.send(Reply::Done(resp)).is_ok()
    }

    /// Worker main loop: admit up to `max_live` sessions (blocking on the
    /// scheduler only when idle), then run one scheduling round — fused
    /// batched rounds when `batch_decode` is on, else `time_slice` steps
    /// per session — until the scheduler closes and all sessions drain.
    ///
    /// With a `kv_budget`, `max_live` counts live + parked sessions: the
    /// admission overflow is parked (suspend = snapshot + device free),
    /// revived FIFO into freed slots, and — while the budget stays
    /// saturated — rotated one per round so every parked session keeps
    /// making progress (time-slicing through the suspend/resume path).
    ///
    /// With a rebalance hub, every round additionally adopts sessions
    /// migrated here, publishes this worker's load, and honors donation
    /// directives by handing its coldest parked snapshot to the assigned
    /// worker; idle workers poll the scheduler with a timeout so adoption
    /// still happens while the request queue is empty.
    pub fn run(self, sched: Arc<Scheduler>, replies: Sender<Reply>) {
        info!("worker",
              "worker {} ready (model={}, time_slice={}, max_live={}, batch={}, \
               kv_budget={}, rebalance={})",
              self.id, self.cfg.model, self.cfg.time_slice, self.cfg.max_live,
              self.cfg.batch_decode, self.cfg.kv_budget, self.hub.is_some());
        let Worker { id, cfg, manifest, rt, tok, ngram_caches, cancels, metrics, hub,
                     prefix, tracer } = self;
        let max_live = cfg.max_live.max(1);
        let slice = cfg.time_slice.max(1);
        let budget = if cfg.kv_budget == 0 { usize::MAX } else { cfg.kv_budget };
        let mut engines: HashMap<String, Box<dyn Decoder>> = HashMap::new();
        let mut drafts: HashMap<String, Rc<ModelRuntime>> = HashMap::new();
        let mut live: Vec<LiveSession<'_>> = Vec::new();
        let mut parked: VecDeque<ParkedSession> = VecDeque::new();
        let mut kv = KvManager::new();
        // the worker always carries an adaptive controller; it is consulted
        // only for sessions whose effective mode (server default or
        // per-request override) is adaptive, so a static server with no
        // overrides never pays for it
        let mut controller: Box<dyn Controller> =
            Box::new(AdaptiveController::new(Self::adaptive_config_for(&rt)));
        'serve: loop {
            // -- adoption: sessions other workers migrated here join the
            //    parked set (counted against max_live by admission) --------
            if let Some(hub) = &hub {
                for m in hub.take_transfers(id) {
                    Self::adopt(m, &mut parked, &mut kv, &metrics);
                }
            }
            // -- admission: top up the live + parked set ---------------------
            while live.len() + parked.len() < max_live {
                let idle = live.is_empty() && parked.is_empty();
                let popped = match (idle, &hub) {
                    (false, _) => sched.try_pop(),
                    (true, None) => sched.pop(),
                    // idle + hub: a bounded wait, so migrations addressed
                    // here are adopted even while no request is queued
                    (true, Some(hub)) => match sched.pop_timeout(ADOPT_POLL) {
                        PopOutcome::Got(p) => Some(p),
                        PopOutcome::Empty => None,
                        PopOutcome::Closed => {
                            // atomically stop being a migration target, then
                            // serve whatever was already addressed here —
                            // an accepted hand-off is never dropped
                            let pending = hub.mark_exited(id);
                            if pending.is_empty() {
                                break 'serve;
                            }
                            for m in pending {
                                Self::adopt(m, &mut parked, &mut kv, &metrics);
                            }
                            break;
                        }
                    },
                };
                let Some(popped) = popped else {
                    if idle && hub.is_none() {
                        break 'serve; // scheduler closed and drained
                    }
                    break; // queue momentarily empty; keep stepping
                };
                match Self::open(&cfg, &manifest, &rt, &mut engines, &mut drafts,
                                 &ngram_caches, &tok, &prefix, &tracer, id, popped) {
                    Ok(ls) => {
                        live.push(ls);
                        // enforce the device budget as each session opens
                        // (opening ran the prefill), so transient residency
                        // is capped at budget + 1 — not max_live
                        while live.len() > budget {
                            if !Self::park_one(&mut live, &mut parked, &mut kv,
                                               &metrics, &tracer, id) {
                                break; // nothing suspendable: budget is soft
                            }
                        }
                    }
                    Err((rid, msg)) => {
                        cancels.clear(rid);
                        if replies.send(Reply::Done(Response::err(rid, msg))).is_err() {
                            break 'serve;
                        }
                    }
                }
            }
            // -- prefill-only: opening a session ran the prefill (and fed
            //    the prefix trie), which is this worker's whole job — park
            //    everything and ship it to a remote decode peer instead of
            //    stepping it. Gated on an alive decode peer so a partitioned
            //    prefill worker degrades to local decode below instead of
            //    livelocking in park/ship-fail/revive. ----------------------
            if cfg.prefill_only {
                if let Some(hub) = &hub {
                    while hub.remote_decode_peer().is_some()
                        && Self::park_one(&mut live, &mut parked, &mut kv, &metrics,
                                          &tracer, id)
                    {}
                    while !parked.is_empty() {
                        let Some(peer) = hub.remote_decode_peer() else { break };
                        match Self::donate_remote_one(id, peer, &mut parked, &mut kv,
                                                      hub, &cancels,
                                                      controller.as_mut(), &replies,
                                                      &metrics) {
                            None => break 'serve,
                            Some(true) => {}
                            Some(false) => break,
                        }
                    }
                }
            }
            // -- one scheduling round ----------------------------------------
            // per-session step/token baselines so the round span can report
            // this round's delta; HashMap::new() is allocation-free, so the
            // untraced path stays allocation-free on the decode hot loop
            let round_t0 = tracer.as_ref().map(|t| t.now_us());
            let mut base: HashMap<u64, (usize, usize)> = HashMap::new();
            if tracer.is_some() {
                for ls in live.iter() {
                    if ls.trace_id != 0 {
                        let s = ls.sess.stats();
                        base.insert(ls.id, (s.decode_steps, s.generated_tokens));
                    }
                }
            }
            if cfg.batch_decode && live.len() > 1 {
                Self::batched_round(&rt, &mut live, slice, &tok, &cancels, &replies,
                                    &metrics, &tracer, id);
            } else {
                // sequential: a slice per live session
                for ls in live.iter_mut() {
                    Self::drive(ls, slice, &tok, &cancels, &replies);
                }
            }
            for ls in live.iter_mut() {
                ls.rounds += 1;
            }
            if let (Some(t), Some(t0)) = (&tracer, round_t0) {
                for ls in live.iter_mut() {
                    if ls.trace_id == 0 {
                        continue;
                    }
                    let s = ls.sess.stats();
                    let (b_steps, b_tokens) = base
                        .get(&ls.id)
                        .copied()
                        .unwrap_or((s.decode_steps, s.generated_tokens));
                    let steps = s.decode_steps - b_steps;
                    if steps == 0 {
                        continue; // parked/fresh this round: nothing ran
                    }
                    let engine =
                        ls.ctl.as_ref().map_or("unknown", |c| c.level.method());
                    let span = t
                        .span(id, ls.trace_id, "round", "decode", t0)
                        .arg("engine", engine)
                        .arg("steps", steps.to_string())
                        .arg("tokens", (s.generated_tokens - b_tokens).to_string());
                    Self::record(&tracer, &mut ls.tl, span);
                }
            }
            // -- controller: observe this round's accept lengths, apply any
            //    engine switches at this commit boundary --------------------
            Self::control_round(&cfg, &manifest, &rt, &mut drafts, &ngram_caches,
                                controller.as_mut(), &mut live, &metrics, &tracer,
                                id);
            // -- retirement sweep: deliver final records for every session
            //    the round finished, cancelled, or failed -------------------
            let mut i = 0;
            while i < live.len() {
                if live[i].sess.finished().is_some() || live[i].error.is_some() {
                    let ls = live.swap_remove(i);
                    controller.retire(ls.id);
                    if !Self::retire(ls, &cancels, &replies) {
                        break 'serve; // server gone
                    }
                } else {
                    i += 1;
                }
            }
            // -- parked stop signals: cancelled / deadline-expired parked
            //    sessions retire from their host snapshot, skipping both
            //    the rotation wait and the device restore ------------------
            if !Self::sweep_parked(&mut parked, &mut kv, &tok, &cancels,
                                   controller.as_mut(), &replies) {
                break 'serve;
            }
            // -- revive parked sessions into freed device slots --------------
            while live.len() < budget && !parked.is_empty() {
                if !Self::revive_one(&rt, &manifest, &mut drafts, &mut live,
                                     &mut parked, &mut kv, &cancels, &replies,
                                     &metrics, &tracer, id) {
                    break 'serve;
                }
            }
            // -- rotation: budget saturated with sessions still parked — swap
            //    the coldest live one out so the parked set keeps stepping ---
            if !parked.is_empty()
                && Self::park_one(&mut live, &mut parked, &mut kv, &metrics,
                                  &tracer, id)
                && !Self::revive_one(&rt, &manifest, &mut drafts, &mut live,
                                     &mut parked, &mut kv, &cancels, &replies,
                                     &metrics, &tracer, id)
            {
                break 'serve;
            }
            // -- rebalance: publish this round's load; honor a donation
            //    directive by shipping the coldest parked snapshot ----------
            if let Some(hub) = &hub {
                hub.report_load(id, live.len(), parked.len());
                match hub.take_directive(id) {
                    Some(Directive::Local(to)) => {
                        if !parked.is_empty()
                            && !Self::donate(to, &mut parked, &mut kv, hub, &cancels,
                                             controller.as_mut(), &replies, &metrics)
                        {
                            break 'serve;
                        }
                    }
                    Some(Directive::Remote(peer)) => {
                        if !parked.is_empty()
                            && Self::donate_remote_one(id, peer, &mut parked,
                                                       &mut kv, hub, &cancels,
                                                       controller.as_mut(),
                                                       &replies, &metrics)
                                .is_none()
                        {
                            break 'serve;
                        }
                    }
                    None => {}
                }
            }
            if let Some(m) = &metrics {
                // per-worker gauge keys — concurrent workers must not clobber
                // each other; the server report sums these into the
                // `suspended_sessions` / `live_sessions` totals
                let mut m = m.lock();
                m.set(&format!("suspended_sessions_w{id}"), parked.len() as u64);
                m.set(&format!("live_sessions_w{id}"), live.len() as u64);
            }
        }
        // -- shutdown path ---------------------------------------------------
        if let Some(hub) = &hub {
            // refuse any further migrations; a hand-off that raced the exit
            // still gets a final record (best-effort — the shutdown sweep in
            // `ServerHandle::shutdown` is the backstop)
            for m in hub.mark_exited(id) {
                cancels.clear(m.id);
                let (tail, resp) =
                    m.into_failure("worker shut down during session migration");
                if let Some(c) = tail {
                    let _ = replies.send(Reply::Chunk(c));
                }
                let _ = replies.send(Reply::Done(resp));
            }
        }
        if let Some(m) = &metrics {
            // zero this worker's gauges: they are set every round, and a
            // worker that exits while the server keeps running would
            // otherwise inflate the summed report forever
            let mut m = m.lock();
            m.set(&format!("suspended_sessions_w{id}"), 0);
            m.set(&format!("live_sessions_w{id}"), 0);
        }
        info!("worker", "worker {} shutting down", id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::StaticController;
    use crate::engine::GenParams;
    use crate::kv::EngineState;
    use crate::metrics::DecodeStats;
    use crate::runtime::HostKv;
    use std::sync::mpsc::channel;

    fn snapshot(id: u64) -> SessionSnapshot {
        SessionSnapshot {
            model: "tiny".into(),
            engine: EngineState::Autoregressive { cur: id as u32, rng: [1, 2, 3, 4] },
            kv: HostKv { len: 1, elem: "i32".into(), data: vec![0; 8] },
            draft_kv: None,
            params: GenParams::default(),
            out: vec![1, 2],
            stats: DecodeStats::default(),
            wall_offset: Duration::ZERO,
            pool: PoolHandle::none(),
        }
    }

    /// A ParkedSession whose KvHandle no longer resolves (the lost-snapshot
    /// scenario): park a snapshot, revive it out from under the handle.
    fn lost_entry(kv: &mut KvManager, id: u64, stream: bool,
                  dec: Utf8StreamDecoder, seq: u64) -> ParkedSession {
        let handle = kv.park(snapshot(id));
        assert!(kv.revive(handle).is_some());
        ParkedSession {
            id,
            stream,
            queued_ms: 0.0,
            seq,
            dec,
            deadline: None,
            handle,
            ctl: None,
            trace_id: 0,
            tl: None,
        }
    }

    #[test]
    fn lost_parked_snapshot_still_emits_a_final_record() {
        // regression: sweep_parked used to `continue` on a lost snapshot,
        // dropping the entry with no record — the client waited forever
        let mut kv = KvManager::new();
        let mut dec = Utf8StreamDecoder::new();
        // held-back partial UTF-8 sequence (first 2 bytes of '€'): the
        // sweep must flush the decoder tail before the final record
        assert_eq!(dec.push(&[0xE2, 0x82]), "");
        let mut parked = VecDeque::new();
        parked.push_back(lost_entry(&mut kv, 42, true, dec, 3));
        let cancels = CancelSet::new();
        cancels.request(42);
        let (tx, rx) = channel();
        let tok = ByteTokenizer::new();

        assert!(Worker::sweep_parked(&mut parked, &mut kv, &tok, &cancels,
                                     &mut StaticController, &tx));
        assert!(parked.is_empty(), "the lost entry must be dropped");
        match rx.recv().unwrap() {
            Reply::Chunk(c) => {
                assert_eq!((c.id, c.seq), (42, 4));
                assert!(!c.delta.is_empty(), "held-back bytes must flush");
            }
            Reply::Done(r) => panic!("tail chunk must precede the record: {r:?}"),
        }
        match rx.recv().unwrap() {
            Reply::Done(r) => {
                assert_eq!(r.id, 42);
                assert!(r.error.is_some(), "a lost snapshot is a Failed record");
            }
            Reply::Chunk(c) => panic!("expected the final record, got chunk {c:?}"),
        }
        assert!(rx.try_recv().is_err(), "exactly one final record");
        assert!(!cancels.contains(42), "the cancel mark must be swept");
    }

    #[test]
    fn lost_snapshot_on_non_streaming_session_fails_without_chunks() {
        let mut kv = KvManager::new();
        let mut parked = VecDeque::new();
        parked.push_back(lost_entry(&mut kv, 7, false, Utf8StreamDecoder::new(), 0));
        let cancels = CancelSet::new();
        cancels.request(7);
        let (tx, rx) = channel();
        let tok = ByteTokenizer::new();
        assert!(Worker::sweep_parked(&mut parked, &mut kv, &tok, &cancels,
                                     &mut StaticController, &tx));
        match rx.recv().unwrap() {
            Reply::Done(r) => assert!(r.error.is_some()),
            Reply::Chunk(c) => panic!("non-streaming sweep must not chunk: {c:?}"),
        }
    }

    #[test]
    fn sweep_leaves_healthy_parked_sessions_alone() {
        // a live (uncancelled, undeadlined) parked entry must survive the
        // sweep even while a lost one next to it is failed
        let mut kv = KvManager::new();
        let healthy_handle = kv.park(snapshot(1));
        let mut parked = VecDeque::new();
        parked.push_back(ParkedSession {
            id: 1,
            stream: false,
            queued_ms: 0.0,
            seq: 0,
            dec: Utf8StreamDecoder::new(),
            deadline: None,
            handle: healthy_handle,
            ctl: None,
            trace_id: 0,
            tl: None,
        });
        parked.push_back(lost_entry(&mut kv, 2, false, Utf8StreamDecoder::new(), 0));
        let cancels = CancelSet::new();
        cancels.request(2);
        let (tx, rx) = channel();
        let tok = ByteTokenizer::new();
        assert!(Worker::sweep_parked(&mut parked, &mut kv, &tok, &cancels,
                                     &mut StaticController, &tx));
        assert_eq!(parked.len(), 1);
        assert_eq!(parked[0].id, 1);
        assert_eq!(rx.recv().unwrap().id(), 2);
    }

    #[test]
    fn donate_reparks_locally_when_the_target_exited() {
        let hub = RebalanceHub::new(2);
        hub.mark_exited(1);
        let mut kv = KvManager::new();
        let handle = kv.park(snapshot(9));
        let mut parked = VecDeque::new();
        parked.push_back(ParkedSession {
            id: 9,
            stream: false,
            queued_ms: 0.0,
            seq: 0,
            dec: Utf8StreamDecoder::new(),
            deadline: None,
            handle,
            ctl: None,
            trace_id: 0,
            tl: None,
        });
        let cancels = CancelSet::new();
        let (tx, rx) = channel();
        assert!(Worker::donate(1, &mut parked, &mut kv, &hub, &cancels,
                               &mut StaticController, &tx, &None));
        assert_eq!(hub.moves(), 0, "no transfer must be recorded");
        assert_eq!(parked.len(), 1, "the session must be re-parked locally");
        assert_eq!(kv.parked_count(), 1);
        // the re-parked session is intact: its snapshot still revives
        let snap = kv.revive(parked[0].handle).unwrap();
        assert_eq!(snap.out, vec![1, 2]);
        assert!(rx.try_recv().is_err(), "no record for a live session");
    }

    #[test]
    fn donate_and_adopt_hand_a_session_across_the_hub() {
        let hub = RebalanceHub::new(2);
        let mut kv_a = KvManager::new();
        let handle = kv_a.park(snapshot(5));
        let mut parked_a = VecDeque::new();
        parked_a.push_back(ParkedSession {
            id: 5,
            stream: true,
            queued_ms: 1.5,
            seq: 2,
            dec: Utf8StreamDecoder::new(),
            deadline: None,
            handle,
            ctl: None,
            trace_id: 0,
            tl: None,
        });
        let cancels = CancelSet::new();
        let (tx, _rx) = channel();
        assert!(Worker::donate(1, &mut parked_a, &mut kv_a, &hub, &cancels,
                               &mut StaticController, &tx, &None));
        assert!(parked_a.is_empty());
        assert_eq!(kv_a.parked_count(), 0, "the donor no longer owns the snapshot");
        assert_eq!(hub.moves(), 1);

        // the adopter picks it up with streaming state intact
        let mut kv_b = KvManager::new();
        let mut parked_b = VecDeque::new();
        for m in hub.take_transfers(1) {
            Worker::adopt(m, &mut parked_b, &mut kv_b, &None);
        }
        assert_eq!(parked_b.len(), 1);
        let p = &parked_b[0];
        assert_eq!((p.id, p.stream, p.seq), (5, true, 2));
        let snap = kv_b.revive(p.handle).unwrap();
        assert_eq!(snap.out, vec![1, 2], "the snapshot migrated byte-intact");
    }
}
