//! Engine workers: each worker thread owns its PJRT client, model runtime,
//! and a cache of decoder instances (the PJRT client is not Send — per-thread
//! ownership is mandatory, and it also mirrors lookahead parallelism's
//! full-model-per-device design).

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::engine::autoregressive::AutoRegressive;
use crate::engine::jacobi::Jacobi;
use crate::engine::lookahead::Lookahead;
use crate::engine::prompt_lookup::PromptLookup;
use crate::engine::spec_decode::SpecDecode;
use crate::engine::Decoder;
use crate::info;
use crate::runtime::{cpu_client, Manifest, ModelRuntime};
use crate::server::request::{Request, Response};
use crate::server::scheduler::Scheduler;
use crate::tokenizer::ByteTokenizer;

#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub artifacts_dir: String,
    pub model: String,
    /// default (W,N,G) when the request does not override it
    pub wng: (usize, usize, usize),
    pub draft_model: String,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            artifacts_dir: "artifacts".into(),
            model: "tiny".into(),
            wng: (5, 3, 5),
            draft_model: "draft".into(),
        }
    }
}

pub struct Worker {
    pub id: usize,
    cfg: WorkerConfig,
    manifest: Manifest,
    rt: ModelRuntime,
    engines: HashMap<String, Box<dyn Decoder>>,
    tok: ByteTokenizer,
}

impl Worker {
    pub fn start(id: usize, cfg: WorkerConfig) -> Result<Worker> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let client = cpu_client()?;
        let rt = ModelRuntime::load(&client, &manifest, &cfg.model)?;
        Ok(Worker { id, cfg, manifest, rt, engines: HashMap::new(), tok: ByteTokenizer::new() })
    }

    fn engine_key(&self, req: &Request) -> String {
        match (&req.method[..], req.wng) {
            ("lookahead", Some((w, n, g))) => format!("lookahead:{w},{n},{g}"),
            (m, _) => m.to_string(),
        }
    }

    fn make_engine(&self, req: &Request) -> Result<Box<dyn Decoder>> {
        let (w, n, g) = req.wng.unwrap_or(self.cfg.wng);
        Ok(match &req.method[..] {
            "lookahead" => Box::new(Lookahead::with_wng(w, n, g)),
            "autoregressive" | "greedy" | "ar" => Box::new(AutoRegressive::new()),
            "jacobi" => Box::new(Jacobi::new(8)),
            "prompt_lookup" => Box::new(PromptLookup::new(8, 1)),
            "spec_decode" => {
                let draft =
                    ModelRuntime::load(&self.rt.client, &self.manifest, &self.cfg.draft_model)?;
                Box::new(SpecDecode::new(draft, 4))
            }
            other => return Err(anyhow!("unknown decoding method '{other}'")),
        })
    }

    /// Token budget: keep the BOS + the most recent prompt bytes that fit.
    fn encode_prompt(&self, prompt: &str) -> Vec<u32> {
        let mut ids = self.tok.encode_with_bos(prompt);
        let cap = self.rt.prefill_len;
        if ids.len() > cap {
            let tail = ids.len() - (cap - 1);
            let mut v = vec![crate::tokenizer::BOS_ID];
            v.extend_from_slice(&ids[tail..]);
            ids = v;
        }
        ids
    }

    pub fn handle(&mut self, req: &Request, queued_ms: f64) -> Response {
        let key = self.engine_key(req);
        if !self.engines.contains_key(&key) {
            match self.make_engine(req) {
                Ok(e) => {
                    self.engines.insert(key.clone(), e);
                }
                Err(e) => return Response::err(req.id, e.to_string()),
            }
        }
        let ids = self.encode_prompt(&req.prompt);
        let engine = self.engines.get_mut(&key).unwrap();
        match engine.generate(&self.rt, &ids, &req.gen_params()) {
            Ok(out) => Response::ok(req.id, out.text, &out.stats, queued_ms),
            Err(e) => Response::err(req.id, e.to_string()),
        }
    }

    /// Worker main loop: drain the scheduler until it closes.
    pub fn run(mut self, sched: Arc<Scheduler>, replies: Sender<Response>) {
        info!("worker", "worker {} ready (model={})", self.id, self.cfg.model);
        while let Some(popped) = sched.pop() {
            let resp = self.handle(&popped.req, popped.queued_ms);
            if replies.send(resp).is_err() {
                break; // server gone
            }
        }
        info!("worker", "worker {} shutting down", self.id);
    }
}
