//! Lookahead token layout + attention-mask canon — the Rust mirror of
//! `python/compile/masks.py`. Cross-checked against
//! `artifacts/layout_golden.json` by `rust/tests/layout_golden.rs`; the two
//! implementations must agree bit-for-bit or the coordinator would feed the
//! AOT executables a layout they were not lowered for.
//!
//! See DESIGN.md §1 for the canonical formulation.

/// One step-input token's role.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Descriptor {
    /// 0 = lookahead branch, 1 = verification branch.
    pub branch: u8,
    /// lookahead: row r (0 = oldest); verify: candidate index i.
    pub row: u32,
    /// lookahead: column c; verify: in-candidate offset j.
    pub col: u32,
    /// relative position w.r.t. the current token (which sits at 0).
    pub relpos: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Wng {
    pub w: usize,
    pub n: usize,
    pub g: usize,
}

impl Wng {
    pub fn new(w: usize, n: usize, g: usize) -> Self {
        assert!(n >= 2, "n-gram size must be >= 2");
        assert!(w >= 1);
        Wng { w, n, g }
    }

    /// Step input size `(W+G) * (N-1)`.
    pub fn t_in(&self) -> usize {
        (self.w + self.g) * (self.n - 1)
    }

    /// Tokens in the lookahead block (rows x W, includes the current token).
    pub fn n_lookahead(&self) -> usize {
        self.w * (self.n - 1)
    }

    pub fn tag(&self) -> String {
        format!("w{}n{}g{}", self.w, self.n, self.g)
    }

    /// Index of lookahead slot (row r, col c).
    pub fn la_index(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.n - 1 && c < self.w);
        r * self.w + c
    }

    /// Index of verify slot (candidate i, offset j).
    pub fn verify_index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.g && j < self.n - 1);
        self.n_lookahead() + i * (self.n - 1) + j
    }

    pub fn descriptors(&self) -> Vec<Descriptor> {
        let mut out = Vec::with_capacity(self.t_in());
        for r in 0..self.n - 1 {
            for c in 0..self.w {
                out.push(Descriptor {
                    branch: 0,
                    row: r as u32,
                    col: c as u32,
                    relpos: (r + c) as u32,
                });
            }
        }
        for i in 0..self.g {
            for j in 0..self.n - 1 {
                out.push(Descriptor {
                    branch: 1,
                    row: i as u32,
                    col: j as u32,
                    relpos: (1 + j) as u32,
                });
            }
        }
        out
    }

    pub fn relative_positions(&self) -> Vec<i32> {
        self.descriptors().iter().map(|d| d.relpos as i32).collect()
    }

    /// Dense intra-step visibility mask, row-major `[t_in * t_in]`, 1=visible.
    pub fn intra_mask(&self) -> Vec<u8> {
        let ds = self.descriptors();
        let t = ds.len();
        let mut m = vec![0u8; t * t];
        for (qi, q) in ds.iter().enumerate() {
            for (ki, k) in ds.iter().enumerate() {
                m[qi * t + ki] = visible(q, k) as u8;
            }
        }
        m
    }
}

/// The scalar visibility rule (identical to `masks.visible` in Python).
pub fn visible(q: &Descriptor, k: &Descriptor) -> bool {
    match (q.branch, k.branch) {
        (0, 0) => (k.col == q.col && k.row <= q.row) || (k.row == 0 && k.col < q.col),
        (1, 1) => k.row == q.row && k.col <= q.col,
        (1, 0) => k.row == 0 && k.col == 0, // the current token
        _ => false,
    }
}

/// Causal mask for a k-token linear chain (AR / spec-verify), row-major.
pub fn linear_mask(k: usize) -> Vec<u8> {
    let mut m = vec![0u8; k * k];
    for q in 0..k {
        for c in 0..=q {
            m[q * k + c] = 1;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn t_in_formula() {
        assert_eq!(Wng::new(15, 5, 15).t_in(), 120);
        assert_eq!(Wng::new(5, 3, 5).t_in(), 20);
        assert_eq!(Wng::new(1, 2, 0).t_in(), 1);
    }

    #[test]
    fn paper_figure2b_example() {
        // W=5, N=4, G=2: red token 6 = (row 2, col 4) sees green 5 = (1,4),
        // all orange (row 0), and itself.
        let wng = Wng::new(5, 4, 2);
        let m = wng.intra_mask();
        let t = wng.t_in();
        let red6 = wng.la_index(2, 4);
        let vis: Vec<usize> = (0..t).filter(|&k| m[red6 * t + k] == 1).collect();
        let mut expected: Vec<usize> = (0..5).map(|c| wng.la_index(0, c)).collect();
        expected.push(wng.la_index(1, 4));
        expected.push(red6);
        expected.sort();
        assert_eq!(vis, expected);
    }

    #[test]
    fn current_token_is_index_zero() {
        let d = Wng::new(7, 5, 7).descriptors();
        assert_eq!(d[0], Descriptor { branch: 0, row: 0, col: 0, relpos: 0 });
    }

    #[test]
    fn prop_mask_invariants() {
        forall(
            60,
            21,
            |r: &mut Rng| (r.range(1, 10), r.range(2, 6), r.range(0, 10)),
            |&(w, n, g)| {
                let wng = Wng::new(w, n, g);
                let ds = wng.descriptors();
                let t = wng.t_in();
                let m = wng.intra_mask();
                for q in 0..t {
                    if m[q * t + q] != 1 {
                        return Err(format!("token {q} does not see itself"));
                    }
                    for k in 0..t {
                        if m[q * t + k] == 1 {
                            if ds[k].relpos > ds[q].relpos {
                                return Err(format!("{q} sees future {k}"));
                            }
                            if ds[q].branch == 0 && ds[k].branch == 1 {
                                return Err("lookahead sees verify".into());
                            }
                            if ds[q].branch == 1
                                && ds[k].branch == 1
                                && ds[q].row != ds[k].row
                            {
                                return Err("candidates not disjoint".into());
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_lookahead_pseudo_sequence_contiguous() {
        // Jacobi trajectory property: a lookahead token's visible set covers
        // exactly relative positions 0..=relpos.
        forall(
            40,
            22,
            |r: &mut Rng| (r.range(1, 10), r.range(2, 6), r.range(0, 6)),
            |&(w, n, g)| {
                let wng = Wng::new(w, n, g);
                let ds = wng.descriptors();
                let t = wng.t_in();
                let m = wng.intra_mask();
                for q in 0..wng.n_lookahead() {
                    let mut seen: Vec<u32> = (0..wng.n_lookahead())
                        .filter(|&k| m[q * t + k] == 1)
                        .map(|k| ds[k].relpos)
                        .collect();
                    seen.sort();
                    let want: Vec<u32> = (0..=ds[q].relpos).collect();
                    if seen != want {
                        return Err(format!("q={q} saw {seen:?} want {want:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn linear_mask_is_causal() {
        let m = linear_mask(4);
        #[rustfmt::skip]
        let want = vec![
            1,0,0,0,
            1,1,0,0,
            1,1,1,0,
            1,1,1,1,
        ];
        assert_eq!(m, want);
    }
}
