//! Byte-level tokenizer mirroring `python/compile/corpus.py` / `config.py`.
//!
//! Vocabulary: 256 raw bytes + PAD/BOS/EOS specials; model logits are padded
//! to a multiple of 8 (`VOCAB_PADDED`) — ids in the pad tail are never
//! sampled (the engines truncate logits at `VOCAB_SIZE`).

pub const VOCAB_BYTES: u32 = 256;
pub const PAD_ID: u32 = 256;
pub const BOS_ID: u32 = 257;
pub const EOS_ID: u32 = 258;
pub const VOCAB_SIZE: u32 = 259;
pub const VOCAB_PADDED: u32 = 264;

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    /// Encode UTF-8 text to token ids (raw bytes).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Encode with a leading BOS (prompt form used by the engines).
    pub fn encode_with_bos(&self, text: &str) -> Vec<u32> {
        let mut v = Vec::with_capacity(text.len() + 1);
        v.push(BOS_ID);
        v.extend(self.encode(text));
        v
    }

    /// Decode ids back to text; specials are dropped, non-UTF8 byte runs are
    /// replaced (lossy) — generation can emit arbitrary bytes.
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> =
            ids.iter().filter(|&&t| t < VOCAB_BYTES).map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, id: u32) -> bool {
        id >= VOCAB_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer::new();
        let s = "def add(a, b):\n    return a + b\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bos_prepended() {
        let t = ByteTokenizer::new();
        let ids = t.encode_with_bos("hi");
        assert_eq!(ids, vec![BOS_ID, b'h' as u32, b'i' as u32]);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[BOS_ID, b'x' as u32, EOS_ID, PAD_ID]), "x");
    }

    #[test]
    fn utf8_roundtrip() {
        let t = ByteTokenizer::new();
        let s = "héllo → 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn prop_roundtrip_byte_tokens() {
        // any ASCII byte sequence round-trips exactly
        forall(200, 17, gen::vec_of(0, 64, |r| r.below(128) as u32), |ids| {
            let t = ByteTokenizer::new();
            let text = t.decode(ids);
            let re = t.encode(&text);
            if &re == ids {
                Ok(())
            } else {
                Err(format!("{ids:?} != {re:?}"))
            }
        });
    }
}
