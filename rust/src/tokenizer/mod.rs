//! Byte-level tokenizer mirroring `python/compile/corpus.py` / `config.py`.
//!
//! Vocabulary: 256 raw bytes + PAD/BOS/EOS specials; model logits are padded
//! to a multiple of 8 (`VOCAB_PADDED`) — ids in the pad tail are never
//! sampled (the engines truncate logits at `VOCAB_SIZE`).

pub const VOCAB_BYTES: u32 = 256;
pub const PAD_ID: u32 = 256;
pub const BOS_ID: u32 = 257;
pub const EOS_ID: u32 = 258;
pub const VOCAB_SIZE: u32 = 259;
pub const VOCAB_PADDED: u32 = 264;

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    /// Encode UTF-8 text to token ids (raw bytes).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Encode with a leading BOS (prompt form used by the engines).
    pub fn encode_with_bos(&self, text: &str) -> Vec<u32> {
        let mut v = Vec::with_capacity(text.len() + 1);
        v.push(BOS_ID);
        v.extend(self.encode(text));
        v
    }

    /// Raw bytes of a token sequence; specials are dropped. This is THE
    /// token→byte mapping — the streaming path feeds these bytes through a
    /// [`Utf8StreamDecoder`] and must agree with [`ByteTokenizer::decode`]
    /// byte-for-byte, so both go through here.
    pub fn bytes(&self, ids: &[u32]) -> Vec<u8> {
        ids.iter().filter(|&&t| t < VOCAB_BYTES).map(|&t| t as u8).collect()
    }

    /// Decode ids back to text; specials are dropped, non-UTF8 byte runs are
    /// replaced (lossy) — generation can emit arbitrary bytes.
    pub fn decode(&self, ids: &[u32]) -> String {
        String::from_utf8_lossy(&self.bytes(ids)).into_owned()
    }

    pub fn is_special(&self, id: u32) -> bool {
        id >= VOCAB_BYTES
    }
}

/// Incremental lossy UTF-8 decoder for streaming deltas.
///
/// Token commits can split a multi-byte UTF-8 sequence across two decode
/// steps; naively lossy-decoding each step's bytes would emit U+FFFD where
/// the one-shot decode emits a real character. This decoder holds back an
/// incomplete trailing sequence (at most 3 bytes) and replaces genuinely
/// invalid sequences exactly like `String::from_utf8_lossy`, so the
/// concatenation of every `push()` return value plus `finish()` is
/// byte-identical to the one-shot lossy decode of the whole stream — the
/// invariant the streaming-equivalence suite checks.
#[derive(Debug, Clone, Default)]
pub struct Utf8StreamDecoder {
    pending: Vec<u8>,
}

impl Utf8StreamDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed bytes; returns the text completed by this chunk.
    pub fn push(&mut self, bytes: &[u8]) -> String {
        self.pending.extend_from_slice(bytes);
        let mut out = String::new();
        let mut i = 0;
        loop {
            match std::str::from_utf8(&self.pending[i..]) {
                Ok(s) => {
                    out.push_str(s);
                    i = self.pending.len();
                    break;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(
                        std::str::from_utf8(&self.pending[i..i + valid]).unwrap(),
                    );
                    match e.error_len() {
                        // invalid sequence: replace it, like from_utf8_lossy
                        Some(bad) => {
                            out.push('\u{FFFD}');
                            i += valid + bad;
                        }
                        // incomplete trailing sequence: hold it back
                        None => {
                            i += valid;
                            break;
                        }
                    }
                }
            }
        }
        self.pending.drain(..i);
        out
    }

    /// Flush the held-back tail (lossy) at end of stream.
    pub fn finish(&mut self) -> String {
        if self.pending.is_empty() {
            return String::new();
        }
        let out = String::from_utf8_lossy(&self.pending).into_owned();
        self.pending.clear();
        out
    }

    /// The held-back incomplete trailing sequence (at most 3 bytes) — what
    /// a cross-process migration must carry so the adopter's decoder
    /// continues mid-character without emitting U+FFFD.
    pub fn pending(&self) -> &[u8] {
        &self.pending
    }

    /// Rebuild a decoder around a held-back tail captured by
    /// [`Utf8StreamDecoder::pending`] on the other side of a migration.
    pub fn from_pending(pending: Vec<u8>) -> Self {
        Utf8StreamDecoder { pending }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer::new();
        let s = "def add(a, b):\n    return a + b\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn bos_prepended() {
        let t = ByteTokenizer::new();
        let ids = t.encode_with_bos("hi");
        assert_eq!(ids, vec![BOS_ID, b'h' as u32, b'i' as u32]);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[BOS_ID, b'x' as u32, EOS_ID, PAD_ID]), "x");
    }

    #[test]
    fn utf8_roundtrip() {
        let t = ByteTokenizer::new();
        let s = "héllo → 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn stream_decoder_holds_back_split_multibyte() {
        let s = "héllo → 世界";
        let bytes = s.as_bytes();
        let mut d = Utf8StreamDecoder::new();
        // feed one byte at a time: every multi-byte char crosses a boundary
        let mut out = String::new();
        for &b in bytes {
            out.push_str(&d.push(&[b]));
        }
        out.push_str(&d.finish());
        assert_eq!(out, s);
    }

    #[test]
    fn stream_decoder_replaces_invalid_like_lossy() {
        let bytes: &[u8] = &[0x68, 0xFF, 0x69, 0xE4, 0xB8]; // h <bad> i <incomplete>
        let mut d = Utf8StreamDecoder::new();
        let mut out = d.push(&bytes[..2]);
        out.push_str(&d.push(&bytes[2..]));
        out.push_str(&d.finish());
        assert_eq!(out, String::from_utf8_lossy(bytes));
    }

    #[test]
    fn prop_stream_decode_matches_one_shot_lossy() {
        use crate::util::rng::Rng;
        // any byte stream, any chunking: concat(push*) + finish == lossy
        forall(
            300,
            29,
            |r: &mut Rng| {
                let bytes: Vec<u32> =
                    (0..r.range(0, 48)).map(|_| r.below(256) as u32).collect();
                let cuts: Vec<u32> =
                    (0..r.range(0, 8)).map(|_| r.below(49) as u32).collect();
                (bytes, cuts)
            },
            |(bytes, cuts)| {
                let bytes: Vec<u8> = bytes.iter().map(|&b| b as u8).collect();
                let mut cuts: Vec<usize> =
                    cuts.iter().map(|&c| (c as usize).min(bytes.len())).collect();
                cuts.push(0);
                cuts.push(bytes.len());
                cuts.sort();
                let mut d = Utf8StreamDecoder::new();
                let mut out = String::new();
                for w in cuts.windows(2) {
                    out.push_str(&d.push(&bytes[w[0]..w[1]]));
                }
                out.push_str(&d.finish());
                let want = String::from_utf8_lossy(&bytes).into_owned();
                if out == want {
                    Ok(())
                } else {
                    Err(format!("{out:?} != {want:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_roundtrip_byte_tokens() {
        // any ASCII byte sequence round-trips exactly
        forall(200, 17, gen::vec_of(0, 64, |r| r.below(128) as u32), |ids| {
            let t = ByteTokenizer::new();
            let text = t.decode(ids);
            let re = t.encode(&text);
            if &re == ids {
                Ok(())
            } else {
                Err(format!("{ids:?} != {re:?}"))
            }
        });
    }
}
