//! Network-transparent session hand-off: the wire protocol that promotes the
//! in-process [`crate::server::scheduler::RebalanceHub`] transfer to a
//! cross-process TCP stream (DESIGN.md §4c).
//!
//! A transfer ships one `LAKV1` snapshot payload from a donor process to an
//! adopter process as checksummed chunks, with resumable range reads after a
//! dropped connection and duplicate suppression keyed by the whole-payload
//! FNV-1a hash. All control frames are single-line JSON; raw chunk bytes
//! follow their `chunk` frame on the same stream.
//!
//! Donor -> adopter handshake on a fresh connection:
//!
//! ```text
//! > {"kind":"offer","xfer":"<16-hex fnv64>","bytes":N,"meta":{...}}
//! < {"kind":"go","offset":K}        resume from K verified bytes
//! < {"kind":"dup"}                  payload already adopted -> skip to tunnel
//! < {"kind":"reject","why":"..."}   adopter refuses (bounce)
//! > {"kind":"chunk","off":o,"len":l,"sum":"<16-hex>"} + l raw bytes   (per chunk)
//! > {"kind":"end","sum":"<16-hex whole-payload fnv64>"}
//! < {"kind":"adopted"}              commit point: checksum verified AND injected
//! ```
//!
//! After `adopted` the same connection becomes the reply tunnel: the adopter
//! writes the session's `StreamChunk` lines followed by the final `Response`
//! line (`done: true`). A donor whose tunnel drops re-attaches with
//! `{"kind":"attach","xfer":"...","have":H}` and the adopter replays buffered
//! lines from index `H` (`{"kind":"ok"}`) or reports the session unknown
//! (`{"kind":"gone"}`).
//!
//! Liveness + load exchange is a one-shot connection:
//! `{"kind":"ping"}` -> `{"kind":"pong","load":{"live":n,"parked":n,"prefill_only":b}}`.
//!
//! Cancellation is a one-shot connection too: a client cancel on the donor
//! for an already-adopted session is forwarded as
//! `{"kind":"cancel","xfer":"..."}`; the adopter marks its local session
//! cancelled (`{"kind":"ok"}`, it stops within one decode step and its
//! cancelled final record flows back through the normal reply tunnel) or
//! reports the transfer unknown (`{"kind":"gone"}`).
//!
//! The commit point is the `adopted` ack, sent only after the whole-payload
//! checksum verifies and local injection succeeds. Before it, any failure is
//! retried with a resume offset and finally bounced (the donor re-parks the
//! session); after it, the transfer never bounces — tunnel failures are
//! resumed via `attach`, and exhausted attach retries surface as an error
//! `Response` so the client never hangs.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::kv::snapshot::{fnv64, wire_chunks};
use crate::metrics::Registry;
use crate::server::request::Reply;
use crate::util::json::Json;
use crate::util::sync::{nap, rank, RankedMutex};

/// Default chunk size for snapshot payload streaming.
pub const NET_CHUNK: usize = 4096;

/// Socket read timeout: every blocking read wakes at this cadence so threads
/// can observe their stop flag.
const READ_TICK: Duration = Duration::from_millis(250);

/// How long one handshake/frame wait may block before the peer is declared
/// dead (many `READ_TICK`s).
const FRAME_DEADLINE: Duration = Duration::from_secs(5);

fn hex(v: u64) -> String {
    format!("{v:016x}")
}

fn parse_hex(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

fn timeoutish(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

fn other(msg: impl Into<String>) -> io::Error {
    io::Error::other(msg.into())
}

/// Connect with a bounded timeout (first resolved address wins).
pub fn connect(addr: &str, timeout: Duration) -> io::Result<TcpStream> {
    let sa = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| other(format!("unresolvable peer address {addr}")))?;
    TcpStream::connect_timeout(&sa, timeout)
}

fn write_json(stream: &mut TcpStream, j: &Json) -> io::Result<()> {
    stream.write_all(j.dump().as_bytes())?;
    stream.write_all(b"\n")
}

/// Incremental line reader over a [`TcpStream`] with a short read timeout.
///
/// `std`'s `BufReader::read_line` loses partially-read bytes when the socket
/// times out mid-line; this reader keeps them buffered so a timeout is a
/// clean `Ok(None)` tick the caller can use to poll a stop flag.
pub struct NetLines {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl NetLines {
    pub fn new(stream: TcpStream) -> io::Result<Self> {
        stream.set_read_timeout(Some(READ_TICK))?;
        Ok(NetLines { stream, buf: Vec::new() })
    }

    pub fn get_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }

    /// Next full line (trailing `\n` stripped). `Ok(None)` is a timeout tick,
    /// not end-of-stream; a closed peer is `UnexpectedEof`.
    pub fn next(&mut self) -> io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.buf.drain(..=pos).collect();
                let s = String::from_utf8(line[..pos].to_vec())
                    .map_err(|_| other("non-utf8 control line"))?;
                return Ok(Some(s));
            }
            let mut tmp = [0u8; 4096];
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed connection",
                    ))
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) if timeoutish(&e) => return Ok(None),
                Err(e) => return Err(e),
            }
        }
    }

    /// Block up to `total` for one full line.
    pub fn next_deadline(&mut self, total: Duration) -> io::Result<String> {
        let t0 = Instant::now();
        loop {
            if let Some(l) = self.next()? {
                return Ok(l);
            }
            if t0.elapsed() >= total {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "deadline waiting for control line",
                ));
            }
        }
    }

    /// Exactly `n` raw payload bytes (a chunk body), never over-reading into
    /// the next control frame.
    pub fn read_exact_bytes(&mut self, n: usize, total: Duration) -> io::Result<Vec<u8>> {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(n);
        let take = n.min(self.buf.len());
        out.extend(self.buf.drain(..take));
        while out.len() < n {
            let want = (n - out.len()).min(4096);
            let mut tmp = [0u8; 4096];
            match self.stream.read(&mut tmp[..want]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "peer closed mid-chunk",
                    ))
                }
                Ok(k) => out.extend_from_slice(&tmp[..k]),
                Err(e) if timeoutish(&e) => {
                    if t0.elapsed() >= total {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "deadline waiting for chunk bytes",
                        ));
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(out)
    }
}

/// What a listener process does with a fully-verified snapshot payload.
///
/// The server side implements this by resuming the session on a local worker
/// (`NetGateway`); wire-level tests implement it with mocks. On success the
/// returned receiver yields the resumed session's replies — the listener
/// pumps them into the donor-facing tunnel.
pub trait Adopt: Send + Sync + 'static {
    /// Inject the payload; on success returns the ADOPTER-LOCAL request id
    /// (the handle a forwarded `cancel{xfer}` resolves against) plus the
    /// receiver yielding the resumed session's replies.
    fn adopt(
        &self,
        meta: &Json,
        payload: Vec<u8>,
    ) -> Result<(u64, Receiver<Reply>), String>;
    /// Mark an adopter-local request cancelled (forwarded donor cancel);
    /// the session stops within one decode step like a local cancel.
    fn cancel_local(&self, id: u64);
    /// Load snapshot advertised in heartbeat `pong`s:
    /// `{"live":n,"parked":n,"prefill_only":b}`.
    fn load_json(&self) -> Json;
}

/// Donor-side transfer knobs.
#[derive(Clone)]
pub struct TransferOpts {
    /// Connection attempts per transfer before bouncing.
    pub attempts: usize,
    /// Backoff between attempts.
    pub backoff: Duration,
    /// Payload chunk size.
    pub chunk: usize,
    /// Fault injection: planned cut offsets (absolute bytes into the
    /// payload), consumed one per attempt. A cut inside the payload drops
    /// the socket mid-chunk at that offset; a cut `>= payload.len()` sends
    /// everything and drops the socket before reading the `adopted` ack,
    /// which deterministically forces the duplicate-delivery path on retry.
    pub cuts: Arc<RankedMutex<Vec<usize>>>,
}

impl Default for TransferOpts {
    fn default() -> Self {
        TransferOpts {
            attempts: 3,
            backoff: Duration::from_millis(50),
            chunk: NET_CHUNK,
            cuts: Arc::new(RankedMutex::new(rank::LEAF, "net.cuts", Vec::new())),
        }
    }
}

/// Terminal state of one donor-side transfer.
pub enum SendOutcome {
    /// Adopter committed; the stream is now the reply tunnel.
    Adopted(NetLines),
    /// Rejected or retries exhausted before the commit point — the caller
    /// re-parks the session on the donor.
    Bounced(String),
}

pub struct SendReport {
    pub outcome: SendOutcome,
    /// Retry attempts that reached a fresh handshake (resumed transfers).
    pub resumes: u64,
}

enum SendErr {
    /// Adopter answered `reject` — terminal, no retry.
    Reject(String),
    /// Transport-level failure — retryable with a resume offset.
    Io(String),
}

/// Stream one snapshot payload to `addr`, retrying with resume offsets until
/// adopted, rejected, or attempts are exhausted. Never panics the caller's
/// session away: every non-`Adopted` path is a bounce.
pub fn send_session(
    addr: &str,
    meta: &Json,
    payload: &[u8],
    opts: &TransferOpts,
) -> SendReport {
    let xfer = fnv64(payload);
    let mut resumes = 0u64;
    let mut last = String::from("no attempts configured");
    for attempt in 0..opts.attempts.max(1) {
        if attempt > 0 {
            nap(opts.backoff);
        }
        let cut = {
            let mut cuts = opts.cuts.lock();
            if cuts.is_empty() { None } else { Some(cuts.remove(0)) }
        };
        let sent = send_once(
            addr, meta, payload, xfer, opts.chunk, cut, attempt, &mut resumes,
        );
        match sent {
            Ok(lines) => {
                return SendReport { outcome: SendOutcome::Adopted(lines), resumes }
            }
            Err(SendErr::Reject(why)) => {
                return SendReport { outcome: SendOutcome::Bounced(why), resumes }
            }
            Err(SendErr::Io(e)) => last = e,
        }
    }
    SendReport {
        outcome: SendOutcome::Bounced(format!("transfer attempts exhausted: {last}")),
        resumes,
    }
}

#[allow(clippy::too_many_arguments)]
fn send_once(
    addr: &str,
    meta: &Json,
    payload: &[u8],
    xfer: u64,
    chunk: usize,
    cut: Option<usize>,
    attempt: usize,
    resumes: &mut u64,
) -> Result<NetLines, SendErr> {
    let io_err = |e: io::Error| SendErr::Io(e.to_string());
    let stream = connect(addr, READ_TICK).map_err(io_err)?;
    let mut lines = NetLines::new(stream).map_err(io_err)?;
    let offer = Json::obj(vec![
        ("kind", Json::str("offer")),
        ("xfer", Json::str(hex(xfer))),
        ("bytes", Json::num(payload.len() as f64)),
        ("meta", meta.clone()),
    ]);
    write_json(lines.get_mut(), &offer).map_err(io_err)?;
    let resp = lines.next_deadline(FRAME_DEADLINE).map_err(io_err)?;
    let j = Json::parse(&resp).map_err(|e| SendErr::Io(format!("bad go frame: {e}")))?;
    let offset = match j.get("kind").and_then(Json::as_str) {
        Some("go") => {
            if attempt > 0 {
                *resumes += 1;
            }
            j.get("offset").and_then(Json::as_usize).unwrap_or(0)
        }
        Some("dup") => {
            // Payload already adopted on a previous attempt whose ack was
            // lost. The tunnel never starts before the ack, so the donor has
            // seen zero reply lines — the adopter replays from index 0.
            if attempt > 0 {
                *resumes += 1;
            }
            return Ok(lines);
        }
        Some("reject") => {
            let why = j
                .get("why")
                .and_then(Json::as_str)
                .unwrap_or("peer rejected offer")
                .to_string();
            return Err(SendErr::Reject(why));
        }
        _ => return Err(SendErr::Io(format!("unexpected handshake frame: {resp}"))),
    };
    if offset > payload.len() {
        return Err(SendErr::Io(format!(
            "peer requested resume offset {offset} past payload end {}",
            payload.len()
        )));
    }
    for frame in wire_chunks(&payload[offset..], chunk) {
        let off = frame.off + offset;
        let head = Json::obj(vec![
            ("kind", Json::str("chunk")),
            ("off", Json::num(off as f64)),
            ("len", Json::num(frame.len as f64)),
            ("sum", Json::str(hex(frame.sum))),
        ]);
        write_json(lines.get_mut(), &head).map_err(io_err)?;
        if let Some(c) = cut {
            if c < off + frame.len {
                // Injected fault: ship only the bytes before the cut point,
                // then drop the socket mid-chunk.
                let partial = c.saturating_sub(off).min(frame.len);
                let _ = lines.get_mut().write_all(&payload[off..off + partial]);
                return Err(SendErr::Io(format!("injected cut at offset {c}")));
            }
        }
        lines
            .get_mut()
            .write_all(&payload[off..off + frame.len])
            .map_err(io_err)?;
    }
    let end = Json::obj(vec![
        ("kind", Json::str("end")),
        ("sum", Json::str(hex(xfer))),
    ]);
    write_json(lines.get_mut(), &end).map_err(io_err)?;
    if cut.is_some_and(|c| c >= payload.len()) {
        // Injected fault: full payload delivered but the ack never read —
        // the retry must be detected as a duplicate by the adopter.
        return Err(SendErr::Io("injected cut before adopted ack".into()));
    }
    let resp = lines.next_deadline(FRAME_DEADLINE).map_err(io_err)?;
    let j =
        Json::parse(&resp).map_err(|e| SendErr::Io(format!("bad ack frame: {e}")))?;
    match j.get("kind").and_then(Json::as_str) {
        Some("adopted") => Ok(lines),
        Some("reject") => Err(SendErr::Reject(
            j.get("why")
                .and_then(Json::as_str)
                .unwrap_or("peer rejected payload")
                .to_string(),
        )),
        _ => Err(SendErr::Io(format!("unexpected ack frame: {resp}"))),
    }
}

/// Growable line buffer shared between the reply pump and tunnel writers.
///
/// The pump appends the adopted session's reply lines (stored with their
/// trailing newline); tunnel writers stream them to the donor from any start
/// index, so a re-`attach` after a dropped tunnel replays without loss.
pub struct RelayBuf {
    /// [`rank::LEAF`]: the pump appends and tunnel writers drain with no
    /// other lock held — net locks are all leaf-only.
    st: RankedMutex<(Vec<String>, bool)>,
    cv: Condvar,
}

pub enum RelayNext {
    Line(String),
    Done,
    Timeout,
}

impl Default for RelayBuf {
    fn default() -> Self {
        RelayBuf {
            st: RankedMutex::new(rank::LEAF, "net.relay_buf", (Vec::new(), false)),
            cv: Condvar::new(),
        }
    }
}

impl RelayBuf {
    pub fn push(&self, line: String) {
        self.st.lock().0.push(line);
        self.cv.notify_all();
    }

    pub fn finish(&self) {
        self.st.lock().1 = true;
        self.cv.notify_all();
    }

    /// Line at `idx`, `Done` once finished AND drained, or `Timeout` (a tick
    /// for the caller's stop flag).
    pub fn next(&self, idx: usize, timeout: Duration) -> RelayNext {
        let mut st = self.st.lock();
        loop {
            if idx < st.0.len() {
                return RelayNext::Line(st.0[idx].clone());
            }
            if st.1 {
                return RelayNext::Done;
            }
            let (guard, waited) = st.wait_timeout_on(&self.cv, timeout);
            st = guard;
            if waited.timed_out() && idx >= st.0.len() && !st.1 {
                return RelayNext::Timeout;
            }
        }
    }
}

/// Adopter-side per-payload transfer state, keyed by the whole-payload hash.
/// Entries persist for the process lifetime: `Adopted` doubles as the
/// duplicate-suppression record and the attach-replay source.
enum XferState {
    /// Verified prefix buffered across dropped connections; its length is
    /// the resume offset offered to the donor.
    Partial(Vec<u8>),
    /// A connection is mid-receive; concurrent duplicate offers bounce.
    InFlight,
    /// Committed: the adopter-local request id (forwarded-cancel target)
    /// plus the reply buffer tunnels replay from.
    Adopted(u64, Arc<RelayBuf>),
}

type TransferTable = Arc<RankedMutex<HashMap<u64, XferState>>>;

/// Accept loop for a peer listener: binds immediately (so callers surface
/// bind errors synchronously), then serves offer/attach/ping connections
/// until `stop`, joining every connection thread on the way out.
pub fn spawn_listener(
    addr: &str,
    gateway: Arc<dyn Adopt>,
    metrics: Arc<RankedMutex<Registry>>,
    stop: Arc<AtomicBool>,
) -> io::Result<JoinHandle<()>> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    Ok(thread::spawn(move || {
        let table: TransferTable =
            Arc::new(RankedMutex::new(rank::LEAF, "net.xfer_table", HashMap::new()));
        let mut conns: Vec<JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let (g, m, t, s) =
                        (gateway.clone(), metrics.clone(), table.clone(), stop.clone());
                    conns.push(thread::spawn(move || {
                        let _ = handle_peer_conn(stream, g, m, t, s);
                    }));
                }
                Err(_) => nap(Duration::from_millis(25)),
            }
        }
        for c in conns {
            let _ = c.join();
        }
    }))
}

fn handle_peer_conn(
    stream: TcpStream,
    gateway: Arc<dyn Adopt>,
    metrics: Arc<RankedMutex<Registry>>,
    table: TransferTable,
    stop: Arc<AtomicBool>,
) -> io::Result<()> {
    let mut lines = NetLines::new(stream)?;
    let first = lines.next_deadline(FRAME_DEADLINE)?;
    let j = Json::parse(&first).map_err(|e| other(format!("bad frame: {e}")))?;
    match j.get("kind").and_then(Json::as_str) {
        Some("ping") => {
            let pong = Json::obj(vec![
                ("kind", Json::str("pong")),
                ("load", gateway.load_json()),
            ]);
            write_json(lines.get_mut(), &pong)
        }
        Some("offer") => handle_offer(&j, lines, gateway, metrics, table, stop),
        Some("attach") => handle_attach(&j, lines, table, stop),
        Some("cancel") => handle_cancel(&j, lines, gateway, metrics, table),
        _ => {
            let reject = Json::obj(vec![
                ("kind", Json::str("reject")),
                ("why", Json::str(format!("unknown frame: {first}"))),
            ]);
            write_json(lines.get_mut(), &reject)
        }
    }
}

fn handle_offer(
    offer: &Json,
    mut lines: NetLines,
    gateway: Arc<dyn Adopt>,
    metrics: Arc<RankedMutex<Registry>>,
    table: TransferTable,
    stop: Arc<AtomicBool>,
) -> io::Result<()> {
    let reject = |lines: &mut NetLines, why: &str| {
        let r = Json::obj(vec![
            ("kind", Json::str("reject")),
            ("why", Json::str(why)),
        ]);
        write_json(lines.get_mut(), &r)
    };
    let xfer = offer
        .get("xfer")
        .and_then(Json::as_str)
        .and_then(parse_hex)
        .ok_or_else(|| other("offer without xfer hash"))?;
    let bytes = offer
        .get("bytes")
        .and_then(Json::as_usize)
        .ok_or_else(|| other("offer without byte count"))?;
    let meta = offer.get("meta").cloned().unwrap_or(Json::Null);
    // Claim the transfer slot: resume a partial, detect a duplicate, or
    // bounce a concurrent offer for the same payload.
    let mut buf = {
        let mut tbl = table.lock();
        match tbl.remove(&xfer) {
            Some(XferState::Adopted(local, relay)) => {
                tbl.insert(xfer, XferState::Adopted(local, relay.clone()));
                drop(tbl);
                metrics.lock().inc("net_dup_dropped", 1);
                let dup = Json::obj(vec![("kind", Json::str("dup"))]);
                write_json(lines.get_mut(), &dup)?;
                return tunnel(lines, &relay, 0, &stop);
            }
            Some(XferState::InFlight) => {
                tbl.insert(xfer, XferState::InFlight);
                drop(tbl);
                return reject(&mut lines, "transfer already in flight");
            }
            Some(XferState::Partial(buf)) => {
                tbl.insert(xfer, XferState::InFlight);
                buf
            }
            None => {
                tbl.insert(xfer, XferState::InFlight);
                Vec::new()
            }
        }
    };
    // On every early exit below the verified prefix goes back in the table
    // so the donor's next attempt resumes instead of restarting.
    let park_partial = |table: &TransferTable, buf: Vec<u8>| {
        table.lock().insert(xfer, XferState::Partial(buf));
    };
    let go = Json::obj(vec![
        ("kind", Json::str("go")),
        ("offset", Json::num(buf.len() as f64)),
    ]);
    if let Err(e) = write_json(lines.get_mut(), &go) {
        park_partial(&table, buf);
        return Err(e);
    }
    // Receive chunks until the end frame verifies the whole payload.
    loop {
        let line = match lines.next_deadline(FRAME_DEADLINE) {
            Ok(l) => l,
            Err(e) => {
                park_partial(&table, buf);
                return Err(e);
            }
        };
        let frame = match Json::parse(&line) {
            Ok(f) => f,
            Err(e) => {
                park_partial(&table, buf);
                return Err(other(format!("bad chunk frame: {e}")));
            }
        };
        match frame.get("kind").and_then(Json::as_str) {
            Some("chunk") => {
                let off =
                    frame.get("off").and_then(Json::as_usize).unwrap_or(usize::MAX);
                let len = frame.get("len").and_then(Json::as_usize).unwrap_or(0);
                let sum = frame.get("sum").and_then(Json::as_str).and_then(parse_hex);
                if off != buf.len() || buf.len() + len > bytes {
                    park_partial(&table, buf);
                    return reject(&mut lines, "chunk offset out of sequence");
                }
                let body = match lines.read_exact_bytes(len, FRAME_DEADLINE) {
                    Ok(b) => b,
                    Err(e) => {
                        // Mid-chunk cut: the unverified chunk is discarded;
                        // only fully-checksummed bytes count toward resume.
                        park_partial(&table, buf);
                        return Err(e);
                    }
                };
                if sum != Some(fnv64(&body)) {
                    park_partial(&table, buf);
                    return reject(&mut lines, "chunk checksum mismatch");
                }
                buf.extend_from_slice(&body);
            }
            Some("end") => {
                let sum = frame.get("sum").and_then(Json::as_str).and_then(parse_hex);
                if buf.len() != bytes || sum != Some(fnv64(&buf)) || sum != Some(xfer) {
                    park_partial(&table, buf);
                    return reject(&mut lines, "payload checksum mismatch");
                }
                break;
            }
            _ => {
                park_partial(&table, buf);
                return reject(&mut lines, "unexpected frame during transfer");
            }
        }
    }
    let donor_id = meta.get("id").and_then(Json::as_i64).unwrap_or(0) as u64;
    let (local_id, rx) = match gateway.adopt(&meta, buf) {
        Ok(got) => got,
        Err(why) => {
            // Injection failed on a verified payload: retrying the same bytes
            // cannot help, so drop the slot and bounce the donor.
            table.lock().remove(&xfer);
            return reject(&mut lines, &why);
        }
    };
    let relay = Arc::new(RelayBuf::default());
    table.lock().insert(xfer, XferState::Adopted(local_id, relay.clone()));
    let pump = spawn_pump(rx, relay.clone(), donor_id);
    let adopted = Json::obj(vec![("kind", Json::str("adopted"))]);
    let ack = write_json(lines.get_mut(), &adopted);
    let tun = ack.and_then(|()| tunnel(lines, &relay, 0, &stop));
    let _ = pump.join();
    tun
}

fn handle_attach(
    attach: &Json,
    mut lines: NetLines,
    table: TransferTable,
    stop: Arc<AtomicBool>,
) -> io::Result<()> {
    let xfer = attach.get("xfer").and_then(Json::as_str).and_then(parse_hex);
    let have = attach.get("have").and_then(Json::as_usize).unwrap_or(0);
    let relay = xfer.and_then(|x| {
        match table.lock().get(&x) {
            Some(XferState::Adopted(_, relay)) => Some(relay.clone()),
            _ => None,
        }
    });
    match relay {
        Some(relay) => {
            let ok = Json::obj(vec![("kind", Json::str("ok"))]);
            write_json(lines.get_mut(), &ok)?;
            tunnel(lines, &relay, have, &stop)
        }
        None => {
            let gone = Json::obj(vec![("kind", Json::str("gone"))]);
            write_json(lines.get_mut(), &gone)
        }
    }
}

/// Forwarded donor cancel: resolve the transfer to its adopter-local id and
/// mark it cancelled. The cancelled final record does NOT flow back on this
/// one-shot connection — it rides the ordinary reply tunnel so the donor's
/// relay sees exactly one terminal line per session.
fn handle_cancel(
    cancel: &Json,
    mut lines: NetLines,
    gateway: Arc<dyn Adopt>,
    metrics: Arc<RankedMutex<Registry>>,
    table: TransferTable,
) -> io::Result<()> {
    let local = cancel
        .get("xfer")
        .and_then(Json::as_str)
        .and_then(parse_hex)
        .and_then(|x| match table.lock().get(&x) {
            Some(XferState::Adopted(local, _)) => Some(*local),
            _ => None,
        });
    match local {
        Some(id) => {
            gateway.cancel_local(id);
            metrics.lock().inc("net_cancels", 1);
            write_json(lines.get_mut(), &Json::obj(vec![("kind", Json::str("ok"))]))
        }
        None => {
            let gone = Json::obj(vec![("kind", Json::str("gone"))]);
            write_json(lines.get_mut(), &gone)
        }
    }
}

/// Feed an adopted session's replies into its relay buffer, rewriting ids
/// back to the donor-side request id the client knows.
fn spawn_pump(
    rx: Receiver<Reply>,
    relay: Arc<RelayBuf>,
    donor_id: u64,
) -> JoinHandle<()> {
    thread::spawn(move || {
        while let Ok(reply) = rx.recv() {
            match reply {
                Reply::Chunk(mut c) => {
                    c.id = donor_id;
                    relay.push(format!("{}\n", c.to_json_line()));
                }
                Reply::Done(mut r) => {
                    r.id = donor_id;
                    relay.push(format!("{}\n", r.to_json_line()));
                    relay.finish();
                    return;
                }
            }
        }
        // Sender dropped without a final record (adopter shutting down);
        // close the relay so tunnels drain and exit.
        relay.finish();
    })
}

/// Stream relay lines to the donor from `idx` until done, the socket drops
/// (the donor will re-attach), or the process stops.
fn tunnel(
    mut lines: NetLines,
    relay: &RelayBuf,
    mut idx: usize,
    stop: &AtomicBool,
) -> io::Result<()> {
    loop {
        match relay.next(idx, READ_TICK) {
            RelayNext::Line(l) => {
                lines.get_mut().write_all(l.as_bytes())?;
                idx += 1;
            }
            RelayNext::Done => return Ok(()),
            RelayNext::Timeout => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
            }
        }
    }
}

/// One peer as seen from this process: address plus the last heartbeat's
/// liveness and load snapshot.
#[derive(Debug, Clone)]
pub struct PeerInfo {
    pub addr: String,
    pub alive: bool,
    pub prefill_only: bool,
    pub live: usize,
    pub parked: usize,
}

/// Heartbeat-maintained peer table; readers (the rebalance policy thread,
/// prefill-only workers) see a consistent snapshot.
pub struct Peers {
    /// [`rank::LEAF`]: heartbeat writes and policy reads hold nothing else.
    roster: RankedMutex<Vec<PeerInfo>>,
}

impl Default for Peers {
    fn default() -> Self {
        Peers { roster: RankedMutex::new(rank::LEAF, "net.peers", Vec::new()) }
    }
}

impl Peers {
    pub fn new(addrs: &[String]) -> Self {
        Peers {
            roster: RankedMutex::new(
                rank::LEAF,
                "net.peers",
                addrs
                    .iter()
                    .map(|a| PeerInfo {
                        addr: a.clone(),
                        alive: false,
                        prefill_only: false,
                        live: 0,
                        parked: 0,
                    })
                    .collect(),
            ),
        }
    }

    pub fn len(&self) -> usize {
        self.roster.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> Vec<PeerInfo> {
        self.roster.lock().clone()
    }

    pub fn addr(&self, i: usize) -> Option<String> {
        self.roster.lock().get(i).map(|p| p.addr.clone())
    }

    pub fn update(
        &self,
        i: usize,
        alive: bool,
        prefill_only: bool,
        live: usize,
        parked: usize,
    ) {
        if let Some(p) = self.roster.lock().get_mut(i) {
            p.alive = alive;
            p.prefill_only = prefill_only;
            p.live = live;
            p.parked = parked;
        }
    }
}

/// Donor-side reply-tunnel re-attachment after a dropped stream: the
/// adopter replays its buffered reply lines from index `have`. Errors when
/// the peer is unreachable or no longer knows the transfer (`gone`).
pub fn attach(addr: &str, xfer: u64, have: usize) -> io::Result<NetLines> {
    let stream = connect(addr, READ_TICK)?;
    let mut lines = NetLines::new(stream)?;
    let frame = Json::obj(vec![
        ("kind", Json::str("attach")),
        ("xfer", Json::str(hex(xfer))),
        ("have", Json::num(have as f64)),
    ]);
    write_json(lines.get_mut(), &frame)?;
    let resp = lines.next_deadline(FRAME_DEADLINE)?;
    let j = Json::parse(&resp).map_err(|e| other(format!("bad attach reply: {e}")))?;
    match j.get("kind").and_then(Json::as_str) {
        Some("ok") => Ok(lines),
        Some("gone") => Err(other("adopter no longer knows the transfer")),
        _ => Err(other(format!("unexpected attach reply: {resp}"))),
    }
}

/// Donor-side cancel forwarding: ask the adopter at `addr` to cancel the
/// session it adopted under transfer `xfer`. Ok(true) = the adopter marked
/// it (the cancelled record arrives via the reply tunnel); Ok(false) = the
/// adopter no longer knows the transfer.
pub fn cancel_session(addr: &str, xfer: u64) -> io::Result<bool> {
    let stream = connect(addr, READ_TICK)?;
    let mut lines = NetLines::new(stream)?;
    let frame = Json::obj(vec![
        ("kind", Json::str("cancel")),
        ("xfer", Json::str(hex(xfer))),
    ]);
    write_json(lines.get_mut(), &frame)?;
    let resp = lines.next_deadline(FRAME_DEADLINE)?;
    let j = Json::parse(&resp).map_err(|e| other(format!("bad cancel reply: {e}")))?;
    match j.get("kind").and_then(Json::as_str) {
        Some("ok") => Ok(true),
        Some("gone") => Ok(false),
        _ => Err(other(format!("unexpected cancel reply: {resp}"))),
    }
}

/// One-shot liveness + load probe: `ping` -> parsed `pong`.
pub fn ping(addr: &str) -> io::Result<Json> {
    let stream = connect(addr, READ_TICK)?;
    let mut lines = NetLines::new(stream)?;
    write_json(lines.get_mut(), &Json::obj(vec![("kind", Json::str("ping"))]))?;
    let resp = lines.next_deadline(Duration::from_millis(1500))?;
    let j = Json::parse(&resp).map_err(|e| other(format!("bad pong: {e}")))?;
    if j.get("kind").and_then(Json::as_str) != Some("pong") {
        return Err(other(format!("unexpected ping reply: {resp}")));
    }
    Ok(j)
}

/// Poll every peer at `interval`, refreshing the table and the
/// `net_heartbeats` / `net_peers_alive` metrics, until `stop`.
pub fn spawn_heartbeat(
    peers: Arc<Peers>,
    metrics: Arc<RankedMutex<Registry>>,
    interval: Duration,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    thread::spawn(move || {
        while !stop.load(Ordering::Relaxed) {
            let n = peers.len();
            let mut alive = 0u64;
            for i in 0..n {
                let addr = match peers.addr(i) {
                    Some(a) => a,
                    None => continue,
                };
                match ping(&addr) {
                    Ok(pong) => {
                        let load = |k: &str| {
                            pong.path(&format!("load.{k}"))
                                .and_then(Json::as_usize)
                                .unwrap_or(0)
                        };
                        let pf = pong
                            .path("load.prefill_only")
                            .and_then(Json::as_bool)
                            .unwrap_or(false);
                        peers.update(i, true, pf, load("live"), load("parked"));
                        alive += 1;
                    }
                    Err(_) => peers.update(i, false, false, 0, 0),
                }
                metrics.lock().inc("net_heartbeats", 1);
            }
            {
                let mut m = metrics.lock();
                m.set("net_peers_alive", alive);
            }
            let t0 = Instant::now();
            while t0.elapsed() < interval && !stop.load(Ordering::Relaxed) {
                nap(Duration::from_millis(10));
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relay_buf_replays_from_any_index_and_drains_before_done() {
        let relay = RelayBuf::default();
        relay.push("a\n".into());
        relay.push("b\n".into());
        relay.finish();
        // from index 0: both lines, then Done
        assert!(matches!(relay.next(0, Duration::from_millis(5)),
            RelayNext::Line(l) if l == "a\n"));
        assert!(matches!(relay.next(1, Duration::from_millis(5)),
            RelayNext::Line(l) if l == "b\n"));
        assert!(matches!(relay.next(2, Duration::from_millis(5)), RelayNext::Done));
        // attach-style replay from index 1 skips what the donor already has
        assert!(matches!(relay.next(1, Duration::from_millis(5)),
            RelayNext::Line(l) if l == "b\n"));
    }

    #[test]
    fn relay_buf_times_out_while_open() {
        let relay = RelayBuf::default();
        assert!(matches!(
            relay.next(0, Duration::from_millis(5)),
            RelayNext::Timeout
        ));
    }

    #[test]
    fn peer_table_updates_are_visible_in_snapshots() {
        let peers = Peers::new(&["127.0.0.1:1".into(), "127.0.0.1:2".into()]);
        assert_eq!(peers.len(), 2);
        assert!(!peers.snapshot()[0].alive);
        peers.update(0, true, true, 3, 1);
        let snap = peers.snapshot();
        assert!(snap[0].alive && snap[0].prefill_only);
        assert_eq!((snap[0].live, snap[0].parked), (3, 1));
        assert_eq!(peers.addr(1).as_deref(), Some("127.0.0.1:2"));
        assert!(peers.addr(2).is_none());
    }

    #[test]
    fn hex_round_trips() {
        for v in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(parse_hex(&hex(v)), Some(v));
        }
    }
}
