//! # Lookahead Decoding — Rust + JAX + Pallas reproduction
//!
//! Full-system reproduction of *Break the Sequential Dependency of LLM
//! Inference Using Lookahead Decoding* (Fu, Bailis, Stoica, Zhang — ICML
//! 2024) as a three-layer serving stack:
//!
//! - **L3 (this crate)**: the serving coordinator — lookahead engine
//!   (2D window + n-gram pool + disjoint-n-gram verification), baselines
//!   (autoregressive, Jacobi, speculative, prompt-lookup) behind the
//!   resumable [`engine::DecodeSession`] API, a request router/scheduler
//!   whose workers time-slice steps across concurrent sessions (streaming,
//!   cancellation, deadlines), lookahead parallelism, metrics, benches.
//! - **L2 (python/compile, build-time)**: LLaMA-style byte transformer
//!   AOT-lowered to HLO text, executed here via PJRT.
//! - **L1 (python/compile/kernels)**: Pallas flash-style attention kernel
//!   with the lookahead pattern (Fig. 2b) hardcoded.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for results.

// The crate is pure safe Rust end to end; real xla-rs PJRT bindings, when
// they land, live behind a feature-gated module boundary with its own
// documented exemption rather than weakening this to `deny`.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod analytic;
pub mod control;
pub mod engine;
pub mod kv;
pub mod layout;
pub mod lp;
pub mod metrics;
pub mod net;
pub mod ngram;
pub mod runtime;
pub mod server;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod workload;
pub mod bench;
