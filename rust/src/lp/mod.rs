//! Lookahead Parallelism (paper §3.4, Fig. 3) — simulated device pool.
//!
//! LP distributes the disjoint lookahead-branch columns and the disjoint
//! verification candidates across devices, each holding a FULL model copy;
//! only the accepted token ids are synchronized per step (near-zero
//! communication vs. TP's per-layer all-reduces).
//!
//! This testbed has one physical core (DESIGN.md §2), so true parallel
//! wall-clock is impossible. The simulation is still *measurement-driven*:
//! for each device count K we build the K-way shard of the (W,N,G) layout,
//! **execute the real shard-sized step** on the real runtime to measure its
//! compute time, and combine `max(shard times) + comm_model` into the
//! simulated per-step latency. Step compression S is unchanged by LP
//! (paper App. E verifies <0.1% difference), so projected throughput =
//! S / simulated_step_latency.

use anyhow::Result;

use crate::analytic::{comm_time, Parallelism};
use crate::layout::Wng;
use crate::metrics::Timer;
use crate::runtime::{Cache, ModelRuntime};

/// The shard of a (W,N,G) lookahead step assigned to one device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// lookahead columns [c0, c1) of the window assigned here
    pub col_range: (usize, usize),
    /// verification candidates [i0, i1) assigned here
    pub cand_range: (usize, usize),
    /// resulting per-device step-input size
    pub t_in: usize,
}

/// Split the layout across `devices`, balancing columns and candidates.
pub fn shard_layout(wng: Wng, devices: usize) -> Vec<Shard> {
    let d = devices.max(1);
    let mut shards = Vec::with_capacity(d);
    let cols = split_range(wng.w, d);
    let cands = split_range(wng.g, d);
    for i in 0..d {
        let (c0, c1) = cols[i];
        let (g0, g1) = cands[i];
        let t = (c1 - c0 + (g1 - g0)) * (wng.n - 1);
        shards.push(Shard { col_range: (c0, c1), cand_range: (g0, g1), t_in: t });
    }
    shards
}

fn split_range(total: usize, parts: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(parts);
    let base = total / parts;
    let rem = total % parts;
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[derive(Debug, Clone)]
pub struct LpReport {
    pub devices: usize,
    pub shards: Vec<Shard>,
    /// measured per-shard step wall (ms), via the real generic executable
    pub shard_ms: Vec<f64>,
    /// simulated step = max(shard) + comm (ms)
    pub step_ms: f64,
    pub comm_ms: f64,
    /// throughput projection = S * 1000 / step_ms (tokens/s)
    pub tokens_per_sec: f64,
}

/// Measure the LP-simulated step latency for `wng` on `devices` devices.
/// `s` is the measured step compression of the full config.
pub fn simulate(rt: &ModelRuntime, cache: &Cache, wng: Wng, devices: usize,
                s: f64, reps: usize) -> Result<LpReport> {
    let shards = shard_layout(wng, devices);
    let mut shard_ms = Vec::with_capacity(shards.len());
    for sh in &shards {
        if sh.t_in == 0 {
            shard_ms.push(0.0);
            continue;
        }
        // A shard executes a (w_shard, N, g_shard) lookahead step; its cost
        // is that of the same-sized generic decode (same T_in, same masks).
        let w_shard = (sh.col_range.1 - sh.col_range.0).max(1);
        let g_shard = sh.cand_range.1 - sh.cand_range.0;
        let swng = Wng::new(w_shard, wng.n, g_shard);
        let t = swng.t_in();
        let (exe, t_pad) = rt
            .mm
            .find_decode_gen(t)
            .ok_or_else(|| anyhow::anyhow!("no generic executable for shard t={t}"))?;
        let exe = exe.to_string();
        let mut relpos = swng.relative_positions();
        relpos.resize(t_pad, 0);
        let mask = ModelRuntime::pad_mask(&swng.intra_mask(), t, t_pad);
        let tokens: Vec<u32> = (0..t as u32).map(|i| 97 + i % 26).collect();
        // warmup (compile path) + timed reps
        rt.decode_generic(&exe, cache, &tokens, &relpos, &mask)?;
        let timer = Timer::start();
        for _ in 0..reps.max(1) {
            rt.decode_generic(&exe, cache, &tokens, &relpos, &mask)?;
        }
        shard_ms.push(timer.ms() / reps.max(1) as f64);
    }
    let compute_ms = shard_ms.iter().cloned().fold(0.0, f64::max);
    let comm_ms = comm_time(Parallelism::LP, devices, rt.mm.n_layers, rt.mm.d_model,
                            wng.t_in()) * 1e3;
    let step_ms = compute_ms + comm_ms;
    let tokens_per_sec = if step_ms > 0.0 { s * 1e3 / step_ms } else { 0.0 };
    Ok(LpReport { devices, shards, shard_ms, step_ms, comm_ms, tokens_per_sec })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn shards_partition_columns_and_candidates() {
        let wng = Wng::new(15, 5, 15);
        let shards = shard_layout(wng, 4);
        assert_eq!(shards.len(), 4);
        let mut col = 0;
        let mut cand = 0;
        for s in &shards {
            assert_eq!(s.col_range.0, col);
            assert_eq!(s.cand_range.0, cand);
            col = s.col_range.1;
            cand = s.cand_range.1;
        }
        assert_eq!(col, 15);
        assert_eq!(cand, 15);
    }

    #[test]
    fn shard_t_in_sums_to_total() {
        forall(
            60,
            5,
            |r: &mut Rng| (r.range(1, 31), r.range(2, 6), r.range(0, 31)),
            |&(w, n, g)| {
                for d in 1..9 {
                    let wng = Wng::new(w, n, g);
                    let total: usize =
                        shard_layout(wng, d).iter().map(|s| s.t_in).sum();
                    if total != wng.t_in() {
                        return Err(format!("d={d}: {total} != {}", wng.t_in()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_devices_smaller_max_shard() {
        let wng = Wng::new(15, 5, 15);
        let max_t =
            |d: usize| shard_layout(wng, d).iter().map(|s| s.t_in).max().unwrap();
        assert!(max_t(2) < max_t(1));
        assert!(max_t(4) < max_t(2));
        assert!(max_t(8) < max_t(4));
    }

    #[test]
    fn single_device_is_identity() {
        let wng = Wng::new(7, 5, 7);
        let shards = shard_layout(wng, 1);
        assert_eq!(shards[0].t_in, wng.t_in());
    }
}
