//! Ranked locks: the runtime half of the lock-order story (DESIGN.md §9).
//!
//! Every long-lived mutex in the serving stack is a [`RankedMutex`] carrying
//! a numeric rank from the declared hierarchy below. Debug builds maintain a
//! thread-local stack of held ranks and panic the moment any thread acquires
//! a lock whose rank is not strictly greater than everything it already
//! holds — so the whole integration suite (serving, net, kv, controller,
//! trace) continuously validates the same hierarchy the static checker
//! (`rust/src/analysis/`, `lookahead-lint`) proves over the source. Release
//! builds compile the tracker away: a `RankedMutex` is then exactly a
//! `std::sync::Mutex` plus two static words.
//!
//! Strict ordering (`>`), not `>=`: two locks of the same rank may never be
//! held together. That makes sharded families (trace shards, n-gram shards)
//! safe under one rank — shards are only ever locked one at a time — and it
//! encodes "leaf-only" for the [`rank::LEAF`] tier: while any leaf lock
//! (metrics registry, trace shard, net transfer state) is held, nothing else
//! may be acquired, including another leaf.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// The declared lock hierarchy. Acquisition order must strictly increase;
/// see DESIGN.md §9 for the per-edge rationale. Gaps are deliberate so a
/// future tier can slot in without renumbering.
pub mod rank {
    /// Process-wide test/bootstrap setup (sim artifact writer). Acquired
    /// before anything else; nothing is ever locked beneath it anyway.
    pub const SETUP: u8 = 1;
    /// Rebalance hub state (`RebalanceHub::{st,remote}`): the cross-worker
    /// coordinator, acquired before any worker-local structure.
    pub const HUB: u8 = 10;
    /// Scheduler admission queue (`Scheduler::state`).
    pub const SCHED: u8 = 20;
    /// Server-side request routing: the pending reply map, the remote-cancel
    /// forwarding table, and the relay join list.
    pub const PENDING: u8 = 30;
    /// Cancellation mark set. Ranked under PENDING because `cancel()` marks
    /// ids *while holding* the pending map — that ordering is what keeps a
    /// mark from outliving its request (see `ServerHandle::cancel`).
    pub const CANCEL: u8 = 40;
    /// KV prefix-reuse trie.
    pub const KV: u8 = 50;
    /// Shared n-gram cache registry. Held while a freshly created cache's
    /// shards are configured (`get_or_create_scoped`), hence below SHARD.
    pub const NGRAM_REGISTRY: u8 = 60;
    /// One n-gram pool shard. Shards are locked one at a time.
    pub const NGRAM_SHARD: u8 = 70;
    /// Leaf tier: metrics registry, trace shards, and every net-transport
    /// lock (transfer table, relay buffers, peer table, fault-injection
    /// cuts). Nothing may be acquired while a leaf is held.
    pub const LEAF: u8 = 80;
}

/// Bitmask of every rank any thread has ever acquired in this process
/// (debug builds only; bit = rank value, ranks stay < 64 by construction).
/// `exercised_ranks()` lets the test suite assert hierarchy coverage.
static EXERCISED: AtomicU64 = AtomicU64::new(0);

/// Distinct ranks acquired so far in this process (ascending). Always empty
/// in release builds — the tracker only runs under `debug_assertions`.
pub fn exercised_ranks() -> Vec<u8> {
    let bits = EXERCISED.load(Ordering::Relaxed);
    (0..64).filter(|b| bits & (1u64 << b) != 0).collect()
}

#[cfg(debug_assertions)]
mod tracker {
    use super::EXERCISED;
    use std::cell::RefCell;
    use std::sync::atomic::Ordering;

    struct Held {
        rank: u8,
        name: &'static str,
        token: u64,
    }

    thread_local! {
        static STACK: RefCell<Vec<Held>> = RefCell::new(Vec::new());
        static NEXT_TOKEN: RefCell<u64> = RefCell::new(0);
    }

    /// Rank check + push. Runs BEFORE blocking on the lock so a would-be
    /// deadlock still reports the ordering violation instead of hanging.
    pub fn acquire(rank: u8, name: &'static str) -> u64 {
        EXERCISED.fetch_or(1u64 << (rank % 64), Ordering::Relaxed);
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            if let Some(top) = s.iter().max_by_key(|h| h.rank) {
                if rank <= top.rank {
                    panic!(
                        "lock-rank violation: acquiring '{name}' (rank {rank}) \
                         while holding '{held}' (rank {held_rank}); the declared \
                         order is strictly increasing — see DESIGN.md §9",
                        held = top.name,
                        held_rank = top.rank,
                    );
                }
            }
            let token = NEXT_TOKEN.with(|t| {
                let mut t = t.borrow_mut();
                *t += 1;
                *t
            });
            s.push(Held { rank, name, token });
            token
        })
    }

    /// Pop by token, not by position: guards may drop out of LIFO order
    /// (e.g. `let a = ...lock(); let b = ...lock(); drop(a);`).
    pub fn release(token: u64) {
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            if let Some(i) = s.iter().rposition(|h| h.token == token) {
                s.remove(i);
            }
        });
    }
}

/// A `std::sync::Mutex` that carries its declared rank and name. `lock()`
/// returns the guard directly — poisoning (a panic while holding) is
/// re-raised here with the lock's name, which matches the `.lock().unwrap()`
/// behavior this type replaced.
pub struct RankedMutex<T> {
    rank: u8,
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> RankedMutex<T> {
    /// `const`, so statics work: `static L: RankedMutex<()> = ...`.
    pub const fn new(rank: u8, name: &'static str, value: T) -> Self {
        RankedMutex { rank, name, inner: Mutex::new(value) }
    }

    pub fn lock(&self) -> RankedGuard<'_, T> {
        #[cfg(debug_assertions)]
        let token = tracker::acquire(self.rank, self.name);
        let guard = match self.inner.lock() {
            Ok(g) => g,
            Err(_) => panic!("lock '{}' poisoned by a panicking holder", self.name),
        };
        RankedGuard {
            lock: self,
            inner: Some(guard),
            #[cfg(debug_assertions)]
            token,
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn rank(&self) -> u8 {
        self.rank
    }
}

impl<T: fmt::Debug> fmt::Debug for RankedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut d = f.debug_struct("RankedMutex");
        d.field("name", &self.name).field("rank", &self.rank);
        match self.inner.try_lock() {
            Ok(g) => d.field("data", &&*g).finish(),
            Err(_) => d.field("data", &"<locked>").finish(),
        }
    }
}

/// Guard for a [`RankedMutex`]. The `Option` is only `None` transiently
/// inside the condvar re-lock helpers and after `Drop` takes the guard out.
pub struct RankedGuard<'a, T> {
    lock: &'a RankedMutex<T>,
    inner: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    token: u64,
}

impl<'a, T> RankedGuard<'a, T> {
    /// `Condvar::wait` through the ranked guard. The rank entry stays on the
    /// thread's stack while blocked — a waiting thread acquires nothing, and
    /// on wake it holds exactly what it held before.
    pub fn wait_on(mut self, cv: &Condvar) -> RankedGuard<'a, T> {
        let g = self.inner.take().expect("guard already consumed");
        let g = match cv.wait(g) {
            Ok(g) => g,
            Err(_) => {
                panic!("lock '{}' poisoned during condvar wait", self.lock.name)
            }
        };
        self.inner = Some(g);
        self
    }

    /// `Condvar::wait_timeout` through the ranked guard.
    pub fn wait_timeout_on(
        mut self,
        cv: &Condvar,
        timeout: Duration,
    ) -> (RankedGuard<'a, T>, WaitTimeoutResult) {
        let g = self.inner.take().expect("guard already consumed");
        let (g, res) = match cv.wait_timeout(g, timeout) {
            Ok(ok) => ok,
            Err(_) => {
                panic!("lock '{}' poisoned during condvar wait", self.lock.name)
            }
        };
        self.inner = Some(g);
        (self, res)
    }
}

impl<T> std::ops::Deref for RankedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_deref().expect("guard already consumed")
    }
}

impl<T> std::ops::DerefMut for RankedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_deref_mut().expect("guard already consumed")
    }
}

impl<T> Drop for RankedGuard<'_, T> {
    fn drop(&mut self) {
        // release the std guard first, then retire the rank entry
        self.inner.take();
        #[cfg(debug_assertions)]
        tracker::release(self.token);
    }
}

/// The one blessed `thread::sleep` wrapper. `clippy.toml` disallows calling
/// `std::thread::sleep` anywhere else — naps, heartbeat pacing, retry
/// backoff, and test settling all route through here so sleep sites stay
/// enumerable (and a future async/testable-clock refactor has one seam).
#[allow(clippy::disallowed_methods)]
pub fn nap(d: Duration) {
    std::thread::sleep(d);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn guard_gives_access_and_releases() {
        let m = RankedMutex::new(rank::SCHED, "test.m", 1u32);
        {
            let mut g = m.lock();
            *g += 1;
        }
        assert_eq!(*m.lock(), 2);
    }

    #[test]
    fn ascending_ranks_nest_fine() {
        let a = RankedMutex::new(rank::HUB, "test.a", ());
        let b = RankedMutex::new(rank::KV, "test.b", ());
        let c = RankedMutex::new(rank::LEAF, "test.c", ());
        let _ga = a.lock();
        let _gb = b.lock();
        let _gc = c.lock();
    }

    #[test]
    fn out_of_lifo_drop_order_is_tracked() {
        let a = RankedMutex::new(rank::HUB, "test.a", ());
        let b = RankedMutex::new(rank::KV, "test.b", ());
        let ga = a.lock();
        let gb = b.lock();
        drop(ga);
        drop(gb);
        // after both drop, the stack is clean: LEAF then HUB again works
        let _gc = a.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn descending_acquisition_panics() {
        let hi = Arc::new(RankedMutex::new(rank::LEAF, "test.leaf", ()));
        let lo = Arc::new(RankedMutex::new(rank::HUB, "test.hub", ()));
        let err = std::thread::spawn(move || {
            let _g = hi.lock();
            let _bad = lo.lock(); // rank 10 while holding rank 80
        })
        .join()
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lock-rank violation"), "got: {msg}");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn equal_rank_acquisition_panics() {
        let a = Arc::new(RankedMutex::new(rank::LEAF, "test.leaf_a", ()));
        let b = Arc::new(RankedMutex::new(rank::LEAF, "test.leaf_b", ()));
        let err = std::thread::spawn(move || {
            let _g = a.lock();
            let _bad = b.lock(); // leaf-only: no second leaf while one held
        })
        .join()
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lock-rank violation"), "got: {msg}");
    }

    #[test]
    fn condvar_wait_timeout_rewraps_guard() {
        let m = RankedMutex::new(rank::SCHED, "test.cv", 0u32);
        let cv = Condvar::new();
        let g = m.lock();
        let (mut g, res) = g.wait_timeout_on(&cv, Duration::from_millis(1));
        assert!(res.timed_out());
        *g += 1;
        drop(g);
        assert_eq!(*m.lock(), 1);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn exercised_ranks_accumulate() {
        let m = RankedMutex::new(rank::NGRAM_SHARD, "test.shard", ());
        drop(m.lock());
        assert!(exercised_ranks().contains(&rank::NGRAM_SHARD));
    }
}
