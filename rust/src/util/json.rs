//! Minimal JSON parser + writer.
//!
//! Substrate module (DESIGN.md §4): the offline image has no `serde_json`,
//! so the manifest / workloads / golden-layout files are handled by this
//! hand-rolled implementation. Full JSON spec for parsing (objects, arrays,
//! strings with escapes, numbers, bools, null); writer emits what the bench
//! and report code needs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in dotted.split('.') {
            cur = cur.get(seg)?;
        }
        Some(cur)
    }

    pub fn str_vec(&self) -> Option<Vec<String>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
    }

    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr().map(|a| a.iter().filter_map(Json::as_usize).collect())
    }

    pub fn i32_vec(&self) -> Option<Vec<i32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_i64().map(|v| v as i32)).collect())
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    // -- writer --------------------------------------------------------------

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.i + 3..self.i + 7],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"n":-3,"o":{"b":false},"s":"q\"z"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"xs": [1,2,3], "ss": ["a","b"]}"#).unwrap();
        assert_eq!(v.get("xs").unwrap().usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.get("ss").unwrap().str_vec().unwrap(), vec!["a", "b"]);
        assert!(v.get("nope").is_none());
    }
}
