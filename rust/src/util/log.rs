//! Leveled stderr logger with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn set_from_env() {
    match std::env::var("LOOKAHEAD_LOG").as_deref() {
        Ok("debug") => set_level(Level::Debug),
        Ok("warn") => set_level(Level::Warn),
        Ok("error") => set_level(Level::Error),
        _ => {}
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: &str) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target,
                               &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
