//! Tiny CLI argument parser substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    program: String,
}

#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

impl Args {
    pub fn parse_env() -> Args {
        let mut it = std::env::args();
        let program = it.next().unwrap_or_default();
        Self::parse(program, it.collect())
    }

    pub fn parse(program: String, raw: Vec<String>) -> Args {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    flags.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { flags, positional, program }
    }

    pub fn program(&self) -> &str {
        &self.program
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, name: &str, default: bool) -> bool {
        match self.get(name) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// `--wng 15,5,15` -> (15, 5, 15)
    pub fn wng(&self, name: &str, default: (usize, usize, usize)) -> (usize, usize, usize) {
        match self.get(name) {
            Some(v) => {
                let parts: Vec<usize> =
                    v.split(',').filter_map(|x| x.trim().parse().ok()).collect();
                if parts.len() == 3 {
                    (parts[0], parts[1], parts[2])
                } else {
                    default
                }
            }
            None => default,
        }
    }
}

pub fn usage(program: &str, summary: &str, opts: &[Opt]) -> String {
    let mut s = format!("{summary}\n\nUSAGE: {program} [OPTIONS]\n\nOPTIONS:\n");
    for o in opts {
        let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse("prog".into(), v.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn parses_key_value_styles() {
        let a = args(&["--model", "tiny", "--steps=40", "pos1", "--verbose"]);
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.usize_or("steps", 0), 40);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.str_or("model", "tiny"), "tiny");
        assert_eq!(a.f64_or("temp", 0.5), 0.5);
        assert!(!a.bool_or("verbose", false));
    }

    #[test]
    fn wng_triplet() {
        let a = args(&["--wng", "10,5,10"]);
        assert_eq!(a.wng("wng", (1, 2, 3)), (10, 5, 10));
        assert_eq!(a.wng("other", (1, 2, 3)), (1, 2, 3));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args(&["--dry-run", "--model", "small"]);
        assert!(a.bool_or("dry-run", false));
        assert_eq!(a.get("model"), Some("small"));
    }
}
