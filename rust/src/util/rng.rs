//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! combination; passes the statistical sanity tests below.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// The raw xoshiro256** state, for suspend/resume snapshots.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot: the stream
    /// continues exactly where the captured generator left off.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w.max(0.0) as f64;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_roughly() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_500..11_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0f32, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
