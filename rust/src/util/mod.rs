//! Substrate utilities built in-repo (the offline image has only the `xla`
//! crate closure — see DESIGN.md §4): JSON, CLI parsing, PRNG, property
//! testing, logging, ranked locks.

pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod sync;
