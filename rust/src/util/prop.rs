//! Mini property-based testing harness (no `proptest` offline).
//!
//! `forall(cases, gen, check)` draws `cases` random inputs from `gen` and, on
//! failure, greedily shrinks via the value's `Shrink` implementation before
//! reporting the minimal counterexample. Used by the coordinator invariant
//! tests (ngram pool, window update, verification, scheduler).

use crate::util::rng::Rng;

pub trait Shrink: Sized {
    /// Candidate strictly-smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<u32> {
        (*self as usize).shrink().into_iter().map(|x| x as u32).collect()
    }
}

impl Shrink for i32 {
    fn shrink(&self) -> Vec<i32> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
        }
        out
    }
}

impl<T: Clone + Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(Vec::new());
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        // element-wise shrink of the first shrinkable element
        for (i, x) in self.iter().enumerate() {
            if let Some(sx) = x.shrink().into_iter().next() {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
                break;
            }
        }
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink, C: Clone + Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<(A, B, C)> {
        let mut out: Vec<(A, B, C)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

/// Run `check` on `cases` random inputs; panic with a shrunk counterexample.
pub fn forall<T, G, C>(cases: usize, seed: u64, mut gen: G, mut check: C)
where
    T: Clone + std::fmt::Debug + Shrink,
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            // Greedy shrink: first failing candidate wins, up to a depth cap.
            let mut best = input.clone();
            let mut best_msg = msg;
            'outer: for _ in 0..200 {
                for cand in best.shrink() {
                    if let Err(m) = check(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  \
                 input (shrunk): {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Convenience generators.
pub mod gen {
    use crate::util::rng::Rng;

    pub fn usize_in(lo: usize, hi: usize) -> impl FnMut(&mut Rng) -> usize {
        move |r| r.range(lo, hi)
    }

    pub fn vec_of<T>(
        len_lo: usize,
        len_hi: usize,
        mut item: impl FnMut(&mut Rng) -> T,
    ) -> impl FnMut(&mut Rng) -> Vec<T> {
        move |r| {
            let n = r.range(len_lo, len_hi);
            (0..n).map(|_| item(r)).collect()
        }
    }

    pub fn tokens(len_lo: usize, len_hi: usize) -> impl FnMut(&mut Rng) -> Vec<u32> {
        vec_of(len_lo, len_hi, |r| r.below(256) as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(200, 1, gen::usize_in(0, 1000), |&x| {
            if x < 1000 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_shrinks() {
        forall(500, 2, gen::tokens(0, 50), |v| {
            if v.len() < 10 {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        });
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let v = vec![5u32, 6, 7, 8];
        for s in v.shrink() {
            assert!(s.len() < v.len() || s.iter().sum::<u32>() < v.iter().sum());
        }
    }
}
