//! Perf probe: per-step phase breakdown for the decode hot path
//! (EXPERIMENTS.md §Perf). Times decode vs commit per executable.
use lookahead::metrics::Timer;
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let client = cpu_client()?;
    let rt = ModelRuntime::load(&client, &manifest, "tiny")?;
    let prompt: Vec<u32> =
        "def warm(a, b):\n    return a".bytes().map(|b| b as u32).collect();
    let (_, cache) = rt.prefill(&prompt)?;
    let reps = 50;

    for exe in ["decode_lin_1", "decode_la_w5n3g5", "decode_la_w15n5g15",
                "decode_la_w15n5g15_pallas"] {
        let t_in = rt.mm.executables[exe].kind.t_in().unwrap();
        let tokens: Vec<u32> = (0..t_in as u32).map(|i| 97 + i % 26).collect();
        let step = rt.decode(exe, &cache, &tokens)?; // warmup (compiles)
        let t = Timer::start();
        for _ in 0..reps {
            rt.decode(exe, &cache, &tokens)?;
        }
        let decode_ms = t.ms() / reps as f64;

        // rolling commit on a fresh cache handle, length kept stable
        let (_, mut roll) = rt.prefill(&prompt)?;
        let t = Timer::start();
        for _ in 0..reps {
            roll = rt.commit(roll, &step.new_kv, t_in, &[0], 1)?;
            roll.len -= 1;
        }
        let commit_ms = t.ms() / reps as f64;
        println!("{exe:32} t_in={t_in:<4} decode={decode_ms:7.2}ms \
                  commit={commit_ms:6.2}ms");
    }
    Ok(())
}
