//! `lookahead-lint` — repo-aware static analysis CLI (DESIGN.md §9).
//!
//! Walks the tree (default `rust/`), runs the lock-order checker and the
//! invariant lints from [`lookahead::analysis`], prints findings as
//! `file:line: [lint] message`, and exits non-zero when anything fires —
//! the CI `lint` lane runs exactly this. `--json <path>` writes the
//! findings artifact; `--baseline <path>` points at the shrink-only
//! hot-unwrap budget (default `rust/lint_baseline.json`).

use lookahead::analysis::{
    self, baseline_budget, findings_json, hot_unwrap_counts, parse_baseline,
};
use std::collections::BTreeMap;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = "rust".to_string();
    let mut json_out: Option<String> = None;
    let mut baseline_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" | "--json" | "--baseline" => {
                let Some(v) = args.next() else {
                    eprintln!("lookahead-lint: {a} needs a value");
                    return ExitCode::FAILURE;
                };
                match a.as_str() {
                    "--root" => root = v,
                    "--json" => json_out = Some(v),
                    _ => baseline_path = Some(v),
                }
            }
            "--help" | "-h" => {
                println!(
                    "usage: lookahead-lint [--root DIR] [--json OUT] \
                     [--baseline FILE]"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("lookahead-lint: unknown argument `{other}`");
                return ExitCode::FAILURE;
            }
        }
    }
    let explicit_baseline = baseline_path.is_some();
    let bpath =
        baseline_path.unwrap_or_else(|| format!("{root}/lint_baseline.json"));
    let baseline: BTreeMap<String, usize> = match std::fs::read_to_string(&bpath) {
        Ok(text) => match parse_baseline(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("lookahead-lint: bad baseline {bpath}: {e}");
                return ExitCode::FAILURE;
            }
        },
        Err(e) if explicit_baseline => {
            eprintln!("lookahead-lint: cannot read baseline {bpath}: {e}");
            return ExitCode::FAILURE;
        }
        Err(_) => BTreeMap::new(),
    };
    let files = match analysis::load_tree(Path::new(&root)) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("lookahead-lint: cannot walk {root}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let findings = analysis::run(&files, &baseline);
    for f in &findings {
        println!("{f}");
    }
    // shrink-only baseline hygiene: flag budgets the tree no longer needs
    for (path, count) in hot_unwrap_counts(&files) {
        let budget = baseline_budget(&baseline, &path);
        if count < budget {
            println!(
                "note: {path} has {count} hot-path unwrap sites, baseline \
                 allows {budget} — tighten {bpath}"
            );
        }
    }
    if let Some(out) = json_out {
        let doc = findings_json(&findings).dump();
        if let Err(e) = std::fs::write(&out, doc) {
            eprintln!("lookahead-lint: cannot write {out}: {e}");
            return ExitCode::FAILURE;
        }
    }
    println!(
        "lookahead-lint: {} finding(s) over {} file(s)",
        findings.len(),
        files.len()
    );
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
