//! `serve_bench` — open-loop serving benchmark on sim artifacts.
//!
//! Drives the real TCP server (default) or the in-process handle with a
//! seeded Poisson arrival schedule from `bench::load`, then writes the
//! `lookahead-serve-bench/v1` BENCH record (p50/p99 TTFT, per-token
//! latency, goodput, batch occupancy, prefix/n-gram hit rates) and
//! self-validates it. `--validate FILE` checks an existing record instead
//! (the CI smoke lane's second pass).
//!
//! Determinism contract: the same `--seed` replays the identical arrival
//! schedule and request set (`schedule.fingerprint` in the output pins it);
//! latencies are real wall clock and vary run to run.

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use lookahead::bench::load::{self, bench_json, drive_inprocess, drive_tcp, LoadRun,
                             LoadSpec, Schedule};
use lookahead::runtime::sim::{ensure_sim_artifacts, ensure_slow_sim_artifacts};
use lookahead::server::{serve_tcp, Policy, ServerConfig, ServerHandle};
use lookahead::util::cli::{usage, Args, Opt};
use lookahead::util::json::Json;

fn main() -> Result<()> {
    lookahead::util::log::set_from_env();
    let args = Args::parse_env();
    if args.bool_or("help", false) {
        print_usage(&args);
        return Ok(());
    }
    if let Some(f) = args.get("validate") {
        let text =
            std::fs::read_to_string(f).with_context(|| format!("reading {f}"))?;
        load::validate_bench_json(&text).with_context(|| format!("{f}"))?;
        println!("{f}: schema-valid ({})", schema_line(&text));
        return Ok(());
    }
    if let Some(f) = args.get("validate-trace") {
        let text =
            std::fs::read_to_string(f).with_context(|| format!("reading {f}"))?;
        lookahead::trace::validate_trace_json(&text).with_context(|| format!("{f}"))?;
        println!("{f}: valid Chrome trace-event JSON");
        return Ok(());
    }

    let artifacts = resolve_artifacts(&args)?;
    let spec = build_spec(&args)?;
    let sched = Schedule::generate(&spec);
    let cfg = build_server_config(&args, &artifacts, None);
    let addr = args.str_or("addr", "127.0.0.1:7979");
    let inprocess = args.bool_or("inprocess", false);

    eprintln!(
        "serve_bench: {} requests, rate {}/s, seed {}, fingerprint {:016x}, {}",
        spec.requests,
        spec.rate_per_s,
        spec.seed,
        sched.fingerprint(),
        if inprocess { "in-process".to_string() } else { format!("tcp {addr}") },
    );

    let (run, trace_json) = if inprocess {
        run_one_inprocess(cfg.clone(), &sched)?
    } else if args.bool_or("external", false) {
        // drive a server someone else started (multi-node CI lane: the
        // topology under test spans processes this harness cannot spawn)
        wait_for_bind(&addr)?;
        let run = drive_tcp(&addr, &sched)?;
        let tj = cfg.trace.then(|| scrape_trace(&addr)).transpose()?;
        (run, tj)
    } else {
        run_one_tcp(&addr, cfg.clone(), &sched)?
    };
    let mut record = bench_json(args.u64_or("pr", 6), &spec, &sched, &run);
    attach_server_section(&mut record, &cfg);
    if let Some(tj) = &trace_json {
        if !matches!(tj, Json::Null) {
            // per-phase span summary rides the BENCH record (additive
            // section — the required schema paths are untouched)
            if let Json::Obj(m) = &mut record {
                m.insert("trace".to_string(), lookahead::trace::trace_section(tj));
            }
        }
        if let Some(f) = args.get("trace-out") {
            std::fs::write(f, tj.dump()).with_context(|| format!("writing {f}"))?;
            eprintln!("trace dump written to {f}");
        }
    }

    // --sweep-time-slice 2,4,8: replay the same schedule against servers
    // that differ only in time_slice — the comparative numbers future
    // tuning PRs anchor to (BatchedRound group keys / chunking / time_slice
    // are the known untuned knobs).
    if let Some(list) = args.get("sweep-time-slice") {
        let mut sweeps = Vec::new();
        for (i, ts) in list.split(',').enumerate() {
            let ts: usize = ts
                .trim()
                .parse()
                .map_err(|_| anyhow!("bad --sweep-time-slice entry '{ts}'"))?;
            let swept = build_server_config(&args, &artifacts, Some(ts));
            let srun = if inprocess {
                run_one_inprocess(swept, &sched)?.0
            } else {
                run_one_tcp(&bump_port(&addr, 1 + i as u16)?, swept, &sched)?.0
            };
            let sj = bench_json(args.u64_or("pr", 6), &spec, &sched, &srun);
            sweeps.push(Json::obj(vec![
                ("time_slice", Json::num(ts as f64)),
                ("goodput_tok_per_s", num_at(&sj, "goodput_tok_per_s")),
                ("ttft_ms_p50", num_at(&sj, "ttft_ms.p50")),
                ("ttft_ms_p99", num_at(&sj, "ttft_ms.p99")),
                ("per_token_ms_mean", num_at(&sj, "per_token_ms.mean")),
                ("batch_occupancy_mean", num_at(&sj, "batch_occupancy.mean")),
            ]));
            eprintln!("sweep time_slice={ts}: done");
        }
        if let Json::Obj(m) = &mut record {
            m.insert("sweeps".to_string(), Json::Arr(sweeps));
        }
    }

    let out = args.str_or("out", format!("BENCH_{}.json", args.u64_or("pr", 6)).as_str());
    let text = record.dump();
    load::validate_bench_json(&text).context("self-validation of the new record")?;
    std::fs::write(&out, &text).with_context(|| format!("writing {out}"))?;
    println!("wrote {out}");
    print_headline(&record);

    // --baseline FILE: compare against a prior record and gate on tail
    // latency — the regression tripwire CI runs between stacked PRs.
    if let Some(f) = args.get("baseline") {
        let base = std::fs::read_to_string(f).with_context(|| format!("reading {f}"))?;
        let base = Json::parse(&base).map_err(|e| anyhow!("bad baseline json: {e}"))?;
        compare_to_baseline(&record, &base, f)?;
    }
    Ok(())
}

/// Print a delta summary vs a prior BENCH record and fail (nonzero exit)
/// when p99 TTFT regressed by more than 20% (see
/// [`load::p99_ttft_regression`] — a near-zero baseline is no gate at all,
/// so the check carries an absolute floor instead of dividing by ~0).
/// Throughput numbers are wall clock and machine-dependent, so everything
/// except the tail-latency gate is informational.
fn compare_to_baseline(new: &Json, base: &Json, base_path: &str) -> Result<()> {
    const ROWS: [(&str, &str); 6] = [
        ("ttft p50 ms", "ttft_ms.p50"),
        ("ttft p99 ms", "ttft_ms.p99"),
        ("per-token mean ms", "per_token_ms.mean"),
        ("goodput tok/s", "goodput_tok_per_s"),
        ("batch occupancy", "batch_occupancy.mean"),
        ("throughput tok/s", "throughput_tok_per_s"),
    ];
    let at = |j: &Json, p: &str| j.path(p).and_then(Json::as_f64).unwrap_or(0.0);
    println!("baseline {base_path}:");
    for (label, path) in ROWS {
        let (b, n) = (at(base, path), at(new, path));
        // sub-millisecond baselines produce garbage percentages (a 0.0001
        // -> 5.0 ms move is +4999900%): print them as absolute-only
        if b.abs() > 1e-3 {
            let pct = 100.0 * (n - b) / b;
            println!("  {label:<18} {b:>9.2} -> {n:>9.2}  ({pct:+.1}%)");
        } else {
            println!("  {label:<18} {b:>9.2} -> {n:>9.2}  (n/a)");
        }
    }
    let (b99, n99) = (at(base, "ttft_ms.p99"), at(new, "ttft_ms.p99"));
    if let Some(msg) = load::p99_ttft_regression(n99, b99) {
        bail!("{msg}");
    }
    println!("baseline gate: p99 TTFT within +20% budget");
    Ok(())
}

fn print_usage(args: &Args) {
    let opts = [
        Opt { name: "artifacts", default: Some("sim-slow"),
              help: "sim | sim-slow | artifact directory" },
        Opt { name: "seed", default: Some("7"), help: "schedule seed" },
        Opt { name: "requests", default: Some("32"), help: "offered requests" },
        Opt { name: "rate", default: Some("50"), help: "Poisson arrivals per second" },
        Opt { name: "mix", default: Some("templated:1,tenant:1,prefix:1"),
              help: "workload mix class:weight list" },
        Opt { name: "cancel-frac", default: Some("0"),
              help: "fraction cancelled mid-flight" },
        Opt { name: "deadline-frac", default: Some("0"),
              help: "fraction carrying a serving deadline" },
        Opt { name: "deadline-ms", default: Some("40"), help: "deadline budget" },
        Opt { name: "max-tokens", default: Some("8,24"),
              help: "per-request budget range lo,hi" },
        Opt { name: "methods", default: Some("lookahead"),
              help: "decoding methods, comma-separated" },
        Opt { name: "workers", default: Some("2"), help: "serving workers" },
        Opt { name: "policy", default: Some("fifo"), help: "fifo | sjf" },
        Opt { name: "time-slice", default: Some("4"),
              help: "decode steps per session per round" },
        Opt { name: "max-live", default: Some("4"),
              help: "interleaved sessions per worker" },
        Opt { name: "kv-budget", default: Some("0"),
              help: "device KV budget per worker (0 = unlimited)" },
        Opt { name: "batch-decode", default: Some("true"),
              help: "continuous batching on/off" },
        Opt { name: "controller", default: Some("static"),
              help: "static | adaptive engine-selection controller" },
        Opt { name: "baseline", default: None,
              help: "prior BENCH_*.json to diff against; exits nonzero \
                     when p99 TTFT regresses by more than 20%" },
        Opt { name: "addr", default: Some("127.0.0.1:7979"),
              help: "TCP bind address (sweeps use successive ports)" },
        Opt { name: "inprocess", default: Some("false"),
              help: "drive ServerHandle directly instead of TCP" },
        Opt { name: "external", default: Some("false"),
              help: "drive an already-running server at --addr instead of \
                     spawning one (multi-node lanes)" },
        Opt { name: "pr", default: Some("6"), help: "trajectory index for BENCH_<pr>" },
        Opt { name: "out", default: Some("BENCH_<pr>.json"), help: "output path" },
        Opt { name: "sweep-time-slice", default: None,
              help: "extra comparative runs, e.g. 2,4,8" },
        Opt { name: "validate", default: None,
              help: "validate an existing BENCH_*.json and exit" },
        Opt { name: "trace", default: Some("false"),
              help: "record span-level timelines; a per-phase summary \
                     rides the BENCH record under \"trace\"" },
        Opt { name: "trace-sample", default: Some("1"),
              help: "trace every Nth admitted session (1 = all)" },
        Opt { name: "trace-buf", default: Some("65536"),
              help: "bounded span-ring capacity per lane" },
        Opt { name: "trace-out", default: None,
              help: "write the scraped Chrome trace-event JSON here" },
        Opt { name: "validate-trace", default: None,
              help: "validate an existing Chrome trace dump and exit" },
    ];
    println!("{}", usage(args.program(),
        "serve_bench — open-loop serving benchmark (seeded Poisson load).",
        &opts));
}

fn resolve_artifacts(args: &Args) -> Result<String> {
    Ok(match args.str_or("artifacts", "sim-slow").as_str() {
        // slow-sim decodes take ~5ms per launch, so queueing/batching is
        // actually visible in the latency numbers; fast sim is near-instant
        "sim" => ensure_sim_artifacts()?.to_string_lossy().into_owned(),
        "sim-slow" => ensure_slow_sim_artifacts()?.to_string_lossy().into_owned(),
        dir => dir.to_string(),
    })
}

fn build_spec(args: &Args) -> Result<LoadSpec> {
    let (lo, hi) = parse_range(&args.str_or("max-tokens", "8,24"))?;
    let methods: Vec<String> = args
        .str_or("methods", "lookahead")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if methods.is_empty() {
        bail!("--methods must name at least one method");
    }
    Ok(LoadSpec::new(args.u64_or("seed", 7))
        .requests(args.usize_or("requests", 32))
        .rate_per_s(args.f64_or("rate", 50.0))
        .mix(LoadSpec::parse_mix(
            &args.str_or("mix", "templated:1,tenant:1,prefix:1"),
        )?)
        .cancel_frac(args.f64_or("cancel-frac", 0.0))
        .deadline_frac(args.f64_or("deadline-frac", 0.0))
        .deadline_ms(args.u64_or("deadline-ms", 40))
        .max_tokens(lo, hi)
        .methods(methods))
}

fn parse_range(s: &str) -> Result<(usize, usize)> {
    let parts: Vec<usize> =
        s.split(',').filter_map(|x| x.trim().parse().ok()).collect();
    match parts.as_slice() {
        [one] => Ok((*one, *one)),
        [lo, hi] if lo <= hi => Ok((*lo, *hi)),
        _ => bail!("bad range '{s}' (want lo,hi)"),
    }
}

fn build_server_config(args: &Args, artifacts: &str,
                       time_slice_override: Option<usize>) -> ServerConfig {
    ServerConfig::builder()
        .workers(args.usize_or("workers", 2))
        .policy(Policy::parse(&args.str_or("policy", "fifo")))
        .batch_decode(args.bool_or("batch-decode", true))
        .artifacts_dir(artifacts)
        .time_slice(time_slice_override
            .unwrap_or_else(|| args.usize_or("time-slice", 4)))
        .max_live(args.usize_or("max-live", 4))
        .kv_budget(args.usize_or("kv-budget", 0))
        .controller(args.str_or("controller", "static"))
        .trace(args.bool_or("trace", false))
        .trace_sample(args.u64_or("trace-sample", 1))
        .trace_buf(args.usize_or("trace-buf", lookahead::trace::DEFAULT_TRACE_BUF))
        .build()
}

fn run_one_inprocess(cfg: ServerConfig, sched: &Schedule)
                     -> Result<(LoadRun, Option<Json>)> {
    let trace_on = cfg.trace;
    let h = ServerHandle::start(cfg)?;
    let run = drive_inprocess(&h, sched);
    let tj = trace_on.then(|| h.trace_json());
    h.shutdown();
    Ok((run, tj))
}

/// One TCP run: serve in a thread for exactly the schedule's connection
/// count (+1 for the bind probe, +1 for the trace scrape), drive, join.
fn run_one_tcp(addr: &str, cfg: ServerConfig, sched: &Schedule)
               -> Result<(LoadRun, Option<Json>)> {
    let trace_on = cfg.trace;
    let conns = sched.tcp_conns() + 1 + usize::from(trace_on);
    let addr_owned = addr.to_string();
    let server =
        std::thread::spawn(move || serve_tcp(&addr_owned, cfg, Some(conns)));
    wait_for_bind(addr)?;
    let run = drive_tcp(addr, sched)?;
    // scrape the span buffer BEFORE the server exits — this connection is
    // counted in `conns` above
    let tj = if trace_on { Some(scrape_trace(addr)?) } else { None };
    server
        .join()
        .map_err(|_| anyhow!("server thread panicked"))?
        .context("serve_tcp")?;
    Ok((run, tj))
}

/// One `{"trace": true}` control round-trip: returns the bare Chrome
/// trace-event object (or `Json::Null` when the server traces nothing).
fn scrape_trace(addr: &str) -> Result<Json> {
    let line = lookahead::server::client_request(addr, r#"{"trace": true}"#)?;
    let j = Json::parse(&line).map_err(|e| anyhow!("bad trace reply: {e}"))?;
    Ok(j.get("trace").cloned().unwrap_or(Json::Null))
}

/// Poll until the listener accepts — exactly one successful probe
/// connection (accounted for in `run_one_tcp`'s max_conns).
fn wait_for_bind(addr: &str) -> Result<()> {
    for _ in 0..250 {
        if TcpStream::connect(addr).is_ok() {
            return Ok(());
        }
        lookahead::util::sync::nap(Duration::from_millis(20));
    }
    bail!("server at {addr} never came up");
}

fn bump_port(addr: &str, by: u16) -> Result<String> {
    let (host, port) =
        addr.rsplit_once(':').ok_or_else(|| anyhow!("bad addr '{addr}'"))?;
    let port: u16 = port.parse().map_err(|_| anyhow!("bad port in '{addr}'"))?;
    Ok(format!("{host}:{}", port + by))
}

fn attach_server_section(record: &mut Json, cfg: &ServerConfig) {
    let server = Json::obj(vec![
        ("workers", Json::num(cfg.workers as f64)),
        ("policy", Json::str(format!("{:?}", cfg.policy))),
        ("batch_decode",
         Json::Bool(cfg.batch_decode && cfg.worker.batch_decode)),
        ("time_slice", Json::num(cfg.worker.time_slice as f64)),
        ("max_live", Json::num(cfg.worker.max_live as f64)),
        ("kv_budget", Json::num(cfg.worker.kv_budget as f64)),
        ("prefix_cache", Json::Bool(cfg.worker.prefix_cache)),
        ("share_ngrams", Json::Bool(cfg.share_ngrams)),
        ("controller", Json::str(cfg.worker.controller.clone())),
    ]);
    if let Json::Obj(m) = record {
        m.insert("server".to_string(), server);
    }
}

fn num_at(j: &Json, path: &str) -> Json {
    Json::num(j.path(path).and_then(Json::as_f64).unwrap_or(0.0))
}

fn schema_line(text: &str) -> String {
    Json::parse(text)
        .ok()
        .and_then(|j| j.get("schema").and_then(|s| s.as_str().map(str::to_string)))
        .unwrap_or_else(|| "?".to_string())
}

fn print_headline(j: &Json) {
    let f = |p: &str| j.path(p).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "ttft p50/p99 {:.1}/{:.1} ms | per-token {:.2} ms | goodput {:.0} tok/s \
         | occupancy {:.2} | prefix hit {:.0}% | ngram warm {:.0}%",
        f("ttft_ms.p50"),
        f("ttft_ms.p99"),
        f("per_token_ms.mean"),
        f("goodput_tok_per_s"),
        f("batch_occupancy.mean"),
        100.0 * f("prefix_cache.hit_rate"),
        100.0 * f("ngram.warm_frac"),
    );
}
