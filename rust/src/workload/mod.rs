//! Evaluation workload suites: deterministic prompt sets generated at
//! artifact-build time by `python/compile/corpus.py` (substitutes for
//! MT-Bench / HumanEval / GSM8K / MBPP / ClassEval / XSum — DESIGN.md §2)
//! and loaded from `artifacts/workloads.json`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Suite name -> prompts. Names: chat, code, class-code, math, summarize.
#[derive(Debug, Clone)]
pub struct Workloads {
    pub suites: BTreeMap<String, Vec<String>>,
}

pub const SUITE_NAMES: [&str; 5] = ["chat", "class-code", "code", "math", "summarize"];

/// Which paper dataset each suite substitutes (for bench table headers).
pub fn paper_dataset(suite: &str) -> &'static str {
    match suite {
        "chat" => "MT-Bench",
        "code" => "HumanEval",
        "class-code" => "ClassEval",
        "math" => "GSM8K",
        "summarize" => "XSum/CNN-DM",
        _ => "?",
    }
}

impl Workloads {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Workloads> {
        let path = artifacts_dir.as_ref().join("workloads.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let suites_j = j
            .get("suites")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("workloads.json: missing suites object"))?;
        let mut suites = BTreeMap::new();
        for (name, arr) in suites_j {
            let prompts = arr
                .str_vec()
                .ok_or_else(|| anyhow!("suite {name}: not a string array"))?;
            suites.insert(name.clone(), prompts);
        }
        Ok(Workloads { suites })
    }

    pub fn suite(&self, name: &str) -> Result<&[String]> {
        self.suites
            .get(name)
            .map(Vec::as_slice)
            .ok_or_else(|| anyhow!("no workload suite '{name}' (have {:?})",
                                   self.suites.keys().collect::<Vec<_>>()))
    }

    /// First `n` prompts of a suite (benches subsample for time budget).
    pub fn take(&self, name: &str, n: usize) -> Result<Vec<String>> {
        Ok(self.suite(name)?.iter().take(n).cloned().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_workloads_json_shape() {
        let dir = std::env::temp_dir().join(format!("la-wl-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("workloads.json"),
            r#"{"suites": {"chat": ["hello", "hi"], "code": ["def f():"]}}"#,
        )
        .unwrap();
        let w = Workloads::load(&dir).unwrap();
        assert_eq!(w.suite("chat").unwrap().len(), 2);
        assert_eq!(w.take("code", 5).unwrap(), vec!["def f():".to_string()]);
        assert!(w.suite("nope").is_err());
    }

    #[test]
    fn dataset_mapping() {
        assert_eq!(paper_dataset("chat"), "MT-Bench");
        assert_eq!(paper_dataset("code"), "HumanEval");
    }
}
