//! Evaluation workload suites: deterministic prompt sets generated at
//! artifact-build time by `python/compile/corpus.py` (substitutes for
//! MT-Bench / HumanEval / GSM8K / MBPP / ClassEval / XSum — DESIGN.md §2)
//! and loaded from `artifacts/workloads.json`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Suite name -> prompts. Names: chat, code, class-code, math, summarize.
#[derive(Debug, Clone)]
pub struct Workloads {
    pub suites: BTreeMap<String, Vec<String>>,
}

pub const SUITE_NAMES: [&str; 5] = ["chat", "class-code", "code", "math", "summarize"];

/// Which paper dataset each suite substitutes (for bench table headers).
pub fn paper_dataset(suite: &str) -> &'static str {
    match suite {
        "chat" => "MT-Bench",
        "code" => "HumanEval",
        "class-code" => "ClassEval",
        "math" => "GSM8K",
        "summarize" => "XSum/CNN-DM",
        _ => "?",
    }
}

impl Workloads {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Workloads> {
        let path = artifacts_dir.as_ref().join("workloads.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let suites_j = j
            .get("suites")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("workloads.json: missing suites object"))?;
        let mut suites = BTreeMap::new();
        for (name, arr) in suites_j {
            let prompts = arr
                .str_vec()
                .ok_or_else(|| anyhow!("suite {name}: not a string array"))?;
            suites.insert(name.clone(), prompts);
        }
        Ok(Workloads { suites })
    }

    pub fn suite(&self, name: &str) -> Result<&[String]> {
        self.suites
            .get(name)
            .map(Vec::as_slice)
            .ok_or_else(|| anyhow!("no workload suite '{name}' (have {:?})",
                                   self.suites.keys().collect::<Vec<_>>()))
    }

    /// First `n` prompts of a suite (benches subsample for time budget).
    pub fn take(&self, name: &str, n: usize) -> Result<Vec<String>> {
        Ok(self.suite(name)?.iter().take(n).cloned().collect())
    }
}

/// Serving-bench workload classes: synthetic prompt generators for the
/// open-loop load harness. Unlike [`Workloads`] (which needs
/// `artifacts/workloads.json` from `make artifacts`), these are generated in
/// process from a seeded [`Rng`], so the sim-artifact bench lane needs no
/// corpus files. Every prompt stays under ~60 chars — the sim runtime's
/// prefill capacity is 64 byte-tokens including BOS, and longer prompts are
/// rejected at prefill.
///
/// Each class stresses a different serving-side cache:
/// - `Templated`: few templates, varied slots — warms the shared n-gram
///   cache across requests (repeated phrasing speculates well).
/// - `MultiTenant`: same, but requests rotate through tenants `t0..t3`, so
///   per-tenant n-gram namespaces warm independently.
/// - `LongSharedPrefix`: one fixed >=32-char prompt prefix with short varied
///   tails — exercises the KV prefix-reuse trie (`min_prefix` is 32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixClass {
    Templated,
    MultiTenant,
    LongSharedPrefix,
}

/// The fixed prefix every `LongSharedPrefix` prompt starts with (39 chars,
/// above the prefix-cache `min_prefix` of 32).
pub const SHARED_PREFIX: &str = "shared context block alpha beta gamma: ";

impl MixClass {
    pub const ALL: [MixClass; 3] =
        [MixClass::Templated, MixClass::MultiTenant, MixClass::LongSharedPrefix];

    pub fn name(&self) -> &'static str {
        match self {
            MixClass::Templated => "templated",
            MixClass::MultiTenant => "tenant",
            MixClass::LongSharedPrefix => "prefix",
        }
    }

    pub fn parse(s: &str) -> Result<MixClass> {
        match s {
            "templated" => Ok(MixClass::Templated),
            "tenant" | "multi-tenant" => Ok(MixClass::MultiTenant),
            "prefix" | "long-shared-prefix" => Ok(MixClass::LongSharedPrefix),
            _ => bail!("unknown mix class '{s}' (templated|tenant|prefix)"),
        }
    }

    /// One synthetic request body: `(prompt, tenant)`. Deterministic in the
    /// rng stream; ASCII-only, <= 60 chars.
    pub fn synth(&self, rng: &mut Rng) -> (String, Option<String>) {
        const TOPICS: [&str; 4] = ["bread", "ledger", "garden", "rocket"];
        const VERBS: [&str; 4] = ["explain", "compare", "list", "check"];
        match self {
            MixClass::Templated => {
                let p = format!(
                    "{} step {} of the {} plan",
                    rng.choose(&VERBS),
                    rng.below(90) + 10,
                    rng.choose(&TOPICS)
                );
                (p, None)
            }
            MixClass::MultiTenant => {
                let tenant = format!("t{}", rng.below(4));
                let p = format!(
                    "{} item {} for {}",
                    rng.choose(&VERBS),
                    rng.below(90) + 10,
                    tenant
                );
                (p, Some(tenant))
            }
            MixClass::LongSharedPrefix => {
                let p = format!("{}case {:02}", SHARED_PREFIX, rng.below(100));
                (p, None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_workloads_json_shape() {
        let dir = std::env::temp_dir().join(format!("la-wl-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("workloads.json"),
            r#"{"suites": {"chat": ["hello", "hi"], "code": ["def f():"]}}"#,
        )
        .unwrap();
        let w = Workloads::load(&dir).unwrap();
        assert_eq!(w.suite("chat").unwrap().len(), 2);
        assert_eq!(w.take("code", 5).unwrap(), vec!["def f():".to_string()]);
        assert!(w.suite("nope").is_err());
    }

    #[test]
    fn dataset_mapping() {
        assert_eq!(paper_dataset("chat"), "MT-Bench");
        assert_eq!(paper_dataset("code"), "HumanEval");
    }

    #[test]
    fn mix_class_names_roundtrip() {
        for c in MixClass::ALL {
            assert_eq!(MixClass::parse(c.name()).unwrap(), c);
        }
        assert_eq!(MixClass::parse("multi-tenant").unwrap(), MixClass::MultiTenant);
        assert!(MixClass::parse("nope").is_err());
    }

    #[test]
    fn synth_prompts_fit_sim_prefill() {
        // sim prefill capacity is 64 byte-tokens incl. BOS
        let mut rng = Rng::new(42);
        for c in MixClass::ALL {
            for _ in 0..200 {
                let (p, tenant) = c.synth(&mut rng);
                assert!(p.len() <= 60, "{c:?} prompt too long: {p:?}");
                assert!(p.is_ascii());
                match c {
                    MixClass::MultiTenant => assert!(tenant.is_some()),
                    _ => assert!(tenant.is_none()),
                }
            }
        }
    }

    #[test]
    fn synth_is_deterministic() {
        let run = |seed| {
            let mut rng = Rng::new(seed);
            (0..50)
                .map(|i| MixClass::ALL[i % 3].synth(&mut rng))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn shared_prefix_meets_min_prefix() {
        assert!(SHARED_PREFIX.len() >= 32, "prefix-cache min_prefix is 32");
        let mut rng = Rng::new(1);
        let (p, _) = MixClass::LongSharedPrefix.synth(&mut rng);
        assert!(p.starts_with(SHARED_PREFIX));
    }
}
