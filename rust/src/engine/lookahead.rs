//! LOOKAHEAD DECODING (Algorithm 2) — the paper's contribution.
//!
//! Per step, one fused model call evaluates:
//!   - the **lookahead branch**: a fixed 2D window (W columns x N-1
//!     trajectory rows) advancing a modified Jacobi iteration; its outputs
//!     yield W new n-grams per step for the pool;
//!   - the **verification branch**: up to G pool candidates starting with
//!     the current token, verified as disjoint n-grams (Algorithm 3 greedy /
//!     Algorithm 4 sampling) — accepted tokens commit their KVs in place.
//!
//! The engine prefers a *specialized* executable (lookahead mask hardcoded at
//! lowering time — the Pallas/FlashAttention path) and falls back to the
//! *generic* mask-as-input executable for arbitrary (W,N,G) sweeps.
//!
//! Each step commits a variable-length run of verified tokens, which the
//! [`crate::engine::DecodeSession`] API exposes directly: `begin()` sets up
//! the window + pool, every `step()` is one fused forward.

use anyhow::{anyhow, Result};

use crate::engine::session::{EngineStep, EngineSuspend, RawStep, Session, SessionCore,
                             StepPlan};
use crate::engine::{capacity_left, verify, vocab_live, Decoder, DecodeSession,
                    FinishReason, GenParams};
use crate::kv::EngineState;
use crate::layout::Wng;
use crate::metrics::Timer;
use crate::ngram::{PoolHandle, PoolSpec};
use crate::runtime::{Cache, ModelRuntime, StepOut};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LookaheadConfig {
    pub wng: Wng,
    /// preferred attention implementation of the specialized artifact
    /// ("jnp" or "pallas"); ignored on the generic path.
    pub attn: String,
    /// seed the pool with prompt n-grams (Tab. 3 "prompt as ref").
    pub prompt_as_ref: bool,
    /// per-key LRU capacity of the n-gram pool.
    pub pool_per_key: usize,
    /// global pool capacity.
    pub pool_total: usize,
    /// force the generic executable even if a specialized one exists.
    pub force_generic: bool,
}

impl LookaheadConfig {
    pub fn new(w: usize, n: usize, g: usize) -> Self {
        LookaheadConfig {
            wng: Wng::new(w, n, g),
            attn: "jnp".into(),
            prompt_as_ref: true,
            pool_per_key: (2 * g).max(8),
            pool_total: 16_384,
            force_generic: false,
        }
    }
}

enum Exe {
    Specialized(String),
    Generic { name: String, t_pad: usize, relpos: Vec<i32>, mask: Vec<u8> },
}

pub struct Lookahead {
    pub cfg: LookaheadConfig,
}

impl Lookahead {
    pub fn new(cfg: LookaheadConfig) -> Self {
        Lookahead { cfg }
    }

    pub fn with_wng(w: usize, n: usize, g: usize) -> Self {
        Self::new(LookaheadConfig::new(w, n, g))
    }

    fn resolve_exe(&self, rt: &ModelRuntime) -> Result<Exe> {
        let Wng { w, n, g } = self.cfg.wng;
        if !self.cfg.force_generic {
            if let Some((name, _)) = rt.mm.find_decode_la(w, n, g, &self.cfg.attn) {
                return Ok(Exe::Specialized(name.to_string()));
            }
        }
        let t = self.cfg.wng.t_in();
        let (name, t_pad) = rt.mm.find_decode_gen(t).ok_or_else(|| {
            anyhow!("no specialized decode_la for {:?} and no generic executable \
                     with t_pad >= {t}", self.cfg.wng)
        })?;
        let mut relpos: Vec<i32> = self.cfg.wng.relative_positions();
        relpos.resize(t_pad, 0);
        let mask = ModelRuntime::pad_mask(&self.cfg.wng.intra_mask(), t, t_pad);
        Ok(Exe::Generic { name: name.to_string(), t_pad, relpos, mask })
    }
}

struct LookaheadState<'rt> {
    rt: &'rt ModelRuntime,
    wng: Wng,
    /// config bits a suspend must carry so resume re-derives the same
    /// executable resolution.
    attn: String,
    force_generic: bool,
    exe: Exe,
    commit_t: usize,
    rng: Rng,
    /// 2D window: rows[r][c] = trajectory guess at relative position r+c.
    rows: Vec<Vec<u32>>,
    tokens: Vec<u32>,
    /// verification-branch candidates drawn by `plan_step`, consumed by
    /// `finish_step` (the two halves of one Algorithm-2 step).
    cands: Vec<Vec<u32>>,
    cur: u32,
    cache: Option<Cache>,
    vocab: usize,
    pool: PoolHandle,
}

impl LookaheadState<'_> {
    fn run_step(&self, cache: &Cache, tokens: &[u32]) -> Result<StepOut> {
        match &self.exe {
            Exe::Specialized(name) => self.rt.decode(name, cache, tokens),
            Exe::Generic { name, relpos, mask, .. } => {
                self.rt.decode_generic(name, cache, tokens, relpos, mask)
            }
        }
    }
}

impl EngineStep for LookaheadState<'_> {
    // raw_step ≡ plan → decode → finish: the per-session and fused-batch
    // paths execute the identical operation sequence (BatchStep contract).
    fn raw_step(&mut self, core: &mut SessionCore) -> Result<RawStep> {
        match self.plan_step(core)? {
            StepPlan::Stop(reason) => Ok(RawStep::Stop(reason)),
            StepPlan::Run => {
                let step = self.run_step(self.cache.as_ref().unwrap(), &self.tokens)?;
                self.finish_step(core, step)
            }
        }
    }

    fn pool_mut(&mut self) -> &mut PoolHandle {
        &mut self.pool
    }

    fn suspendable(&self) -> bool {
        self.rt.supports_cache_io()
    }

    fn suspend_engine(&mut self) -> Result<EngineSuspend> {
        // between steps `cands` is always drained (taken by finish_step)
        // and `tokens` is fully rewritten by the next plan, so the window
        // rows + rng stream + current token are the whole step state
        debug_assert!(self.cands.is_empty());
        let kv = {
            let cache = self.cache.as_ref().ok_or_else(|| anyhow!("session lost its cache"))?;
            self.rt.cache_to_host(cache)?
        };
        self.cache = None; // free the device buffer
        Ok(EngineSuspend {
            model: self.rt.mm.name.clone(),
            state: EngineState::Lookahead {
                w: self.wng.w,
                n: self.wng.n,
                g: self.wng.g,
                attn: self.attn.clone(),
                force_generic: self.force_generic,
                rows: self.rows.clone(),
                cur: self.cur,
                rng: self.rng.state(),
            },
            kv,
            draft_kv: None,
            pool: std::mem::replace(&mut self.pool, PoolHandle::none()),
        })
    }

    fn batchable(&self) -> bool {
        true
    }

    fn plan_step(&mut self, _core: &mut SessionCore) -> Result<StepPlan> {
        let Wng { w, n, g } = self.wng;
        let cache_len = self.cache.as_ref().unwrap().len;
        if !capacity_left(self.rt, cache_len, n) {
            return Ok(StepPlan::Stop(FinishReason::CacheFull));
        }
        self.rows[0][0] = self.cur;

        // -- assemble the step input ------------------------------------
        for r in 0..n - 1 {
            self.tokens[r * w..(r + 1) * w].copy_from_slice(&self.rows[r]);
        }
        self.cands = self.pool.lookup(self.cur, g);
        for i in 0..g {
            for j in 0..n - 1 {
                self.tokens[self.wng.verify_index(i, j)] = match self.cands.get(i) {
                    Some(c) => c[j],
                    None => self.cur, // padding candidate, ignored by verify
                };
            }
        }
        Ok(StepPlan::Run)
    }

    fn finish_step(&mut self, core: &mut SessionCore, step: StepOut) -> Result<RawStep> {
        let Wng { w, n, .. } = self.wng;
        let cands = std::mem::take(&mut self.cands);

        // -- verification branch -----------------------------------------
        let wng = self.wng;
        let vocab = self.vocab;
        let dist = |c: usize, depth: usize| -> Vec<f32> {
            let row = if depth == 0 {
                step.logits.row(0)
            } else {
                step.logits.row(wng.verify_index(c, depth - 1))
            };
            core.params.sampling.dist(&row[..vocab])
        };
        let outcome = if core.params.sampling.is_greedy() {
            verify::greedy_verify(&cands, n - 1, dist)
        } else {
            verify::sample_verify(&cands, n - 1, dist, &mut self.rng)
        };

        let a = outcome.tokens.len();
        debug_assert!((1..=n).contains(&a));

        // -- commit: KVs of [cur, matched tokens...] ---------------------
        let mut src: Vec<i32> = Vec::with_capacity(a);
        src.push(0);
        if let Some(wi) = outcome.winner {
            for d in 0..outcome.matched_depths.min(a - 1) {
                src.push(self.wng.verify_index(wi, d) as i32);
            }
        }
        debug_assert_eq!(src.len(), a);
        let cache = self.cache.take().unwrap();
        self.cache = Some(self.rt.commit(cache, &step.new_kv, self.commit_t, &src, a)?);

        // -- harvest W n-grams + the new trajectory row ------------------
        let mut new_row = Vec::with_capacity(w);
        let mut gram = Vec::with_capacity(n);
        for c in 0..w {
            // pool generation is always greedy (Algorithm 4 requires
            // one-hot proposal distributions)
            let tok = step.logits.argmax(self.wng.la_index(n - 2, c), self.vocab);
            new_row.push(tok);
            gram.clear();
            for r in 0..n - 1 {
                gram.push(self.rows[r][c]);
            }
            gram.push(tok);
            self.pool.insert(&gram);
        }

        // -- window update: rows move up one step in time, columns shift
        //    left by (a-1) positions; vacated tail refilled randomly ------
        let shift = a - 1;
        for r in 0..n - 2 {
            self.rows[r] = self.rows[r + 1].clone();
        }
        self.rows[n - 2] = new_row;
        if shift > 0 {
            for row in self.rows.iter_mut() {
                row.rotate_left(shift.min(w));
                let start = w - shift.min(w);
                for slot in row[start..].iter_mut() {
                    *slot = self.rng.below(256) as u32;
                }
            }
        }

        self.cur = *outcome.tokens.last().unwrap();
        Ok(RawStep::Tokens(outcome.tokens))
    }

    fn window(&self) -> &[u32] {
        &self.tokens
    }

    fn batch_exe(&self) -> &str {
        match &self.exe {
            Exe::Specialized(name) => name,
            Exe::Generic { name, .. } => name,
        }
    }

    fn group_key(&self) -> String {
        // executable name alone does not pin the layout: one decode_gen
        // artifact serves many (W,N,G) configs with different masks
        format!("lookahead:{}:{}", self.batch_exe(), self.wng.tag())
    }

    fn batch_cache(&self) -> Option<&Cache> {
        self.cache.as_ref()
    }

    fn batch_mask(&self) -> Option<(&[i32], &[u8])> {
        match &self.exe {
            Exe::Specialized(_) => None,
            Exe::Generic { relpos, mask, .. } => Some((relpos, mask)),
        }
    }
}

impl Decoder for Lookahead {
    fn name(&self) -> String {
        format!("lookahead[{}{}]", self.cfg.wng.tag(),
                if self.cfg.prompt_as_ref { "+pref" } else { "" })
    }

    fn pool_spec(&self) -> Option<PoolSpec> {
        Some(
            PoolSpec::new(self.cfg.wng.n, self.cfg.pool_per_key, self.cfg.pool_total)
                .with_kind("lookahead"),
        )
    }

    fn begin<'rt>(&self, rt: &'rt ModelRuntime, prompt: &[u32], params: &GenParams,
                  mut pool: PoolHandle) -> Result<Box<dyn DecodeSession + 'rt>> {
        let mut core = SessionCore::new(prompt.len(), params.clone());
        let Wng { w, n, .. } = self.cfg.wng;
        let t_in = self.cfg.wng.t_in();

        let vocab = vocab_live(rt);
        let exe = self.resolve_exe(rt)?;
        // commit executables are keyed by the executable's token count,
        // which is t_pad on the generic path
        let commit_t = match &exe {
            Exe::Specialized(_) => t_in,
            Exe::Generic { t_pad, .. } => *t_pad,
        };
        let mut rng = Rng::new(params.seed ^ 0x1007AE4D);

        // degrade to a private pool if the caller bound a handle with the
        // wrong n-gram length (or none at all)
        pool.ensure(self.pool_spec().unwrap());
        if self.cfg.prompt_as_ref {
            pool.seed_from(prompt);
        }

        let pf = Timer::start();
        // prefix-reuse-aware prefill (engines ignore the prompt logits)
        let cache = rt.prefill_reuse(prompt)?;
        core.stats.prefill_wall = pf.elapsed();

        let cur = *prompt.last().unwrap();

        // Random initialization per Algorithm 2 line 4.
        let rows: Vec<Vec<u32>> =
            (0..n - 1).map(|_| (0..w).map(|_| rng.below(256) as u32).collect()).collect();

        Ok(Session::boxed(core, LookaheadState {
            rt,
            wng: self.cfg.wng,
            attn: self.cfg.attn.clone(),
            force_generic: self.cfg.force_generic,
            exe,
            commit_t,
            rng,
            rows,
            tokens: vec![0u32; t_in],
            cands: Vec::new(),
            cur,
            cache: Some(cache),
            vocab,
            pool,
        }))
    }
}

/// Reopen a suspended lookahead session from its snapshot parts
/// (`kv::SessionSnapshot::resume` dispatches here). The executable
/// resolution, commit width, and padded token buffer are re-derived from
/// the (W,N,G) config exactly as `begin` derives them; the window rows,
/// RNG stream, and current token continue from the snapshot.
#[allow(clippy::too_many_arguments)]
pub(crate) fn resume_session<'rt>(rt: &'rt ModelRuntime, core: SessionCore,
                                  cache: Cache, (w, n, g): (usize, usize, usize),
                                  attn: String, force_generic: bool,
                                  rows: Vec<Vec<u32>>, cur: u32, rng: Rng,
                                  pool: PoolHandle)
                                  -> Result<Box<dyn DecodeSession + 'rt>> {
    // validate BEFORE Wng::new: snapshots are cross-process input, and the
    // layout constructors assert on degenerate configs instead of erroring
    if w == 0 || n < 2 || g == 0 {
        return Err(anyhow!("lookahead snapshot has invalid config w={w} n={n} g={g}"));
    }
    if rows.len() + 1 != n || rows.iter().any(|r| r.len() != w) {
        return Err(anyhow!("lookahead snapshot window is {}x{:?}, want {}x{w}",
                           rows.len(), rows.first().map(Vec::len), n - 1));
    }
    let mut cfg = LookaheadConfig::new(w, n, g);
    cfg.attn = attn.clone();
    cfg.force_generic = force_generic;
    let eng = Lookahead::new(cfg);
    let exe = eng.resolve_exe(rt)?;
    let t_in = eng.cfg.wng.t_in();
    let commit_t = match &exe {
        Exe::Specialized(_) => t_in,
        Exe::Generic { t_pad, .. } => *t_pad,
    };
    Ok(Session::boxed(core, LookaheadState {
        rt,
        wng: eng.cfg.wng,
        attn,
        force_generic,
        exe,
        commit_t,
        rng,
        rows,
        tokens: vec![0u32; t_in],
        cands: Vec::new(),
        cur,
        cache: Some(cache),
        vocab: vocab_live(rt),
        pool,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults() {
        let c = LookaheadConfig::new(15, 5, 15);
        assert_eq!(c.wng.t_in(), 120);
        assert!(c.prompt_as_ref);
        assert_eq!(c.pool_per_key, 30);
    }

    #[test]
    fn name_reflects_config() {
        let e = Lookahead::with_wng(5, 3, 5);
        assert_eq!(e.name(), "lookahead[w5n3g5+pref]");
    }
}
