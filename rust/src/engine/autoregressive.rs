//! Autoregressive baseline: one token per step via `decode_lin_1`.
//! This is the reference implementation every speedup is measured against
//! and the byte-exactness oracle for the greedy engines.

use anyhow::Result;

use crate::engine::{capacity_left, finish, vocab_live, Decoder, GenOutput, GenParams};
use crate::metrics::{DecodeStats, Timer};
use crate::ngram::PoolHandle;
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

#[derive(Debug, Default, Clone)]
pub struct AutoRegressive;

impl AutoRegressive {
    pub fn new() -> Self {
        AutoRegressive
    }
}

impl Decoder for AutoRegressive {
    fn name(&self) -> String {
        "autoregressive".into()
    }

    fn generate_with_pool(&mut self, rt: &ModelRuntime, prompt: &[u32],
                          params: &GenParams, _pool: &mut PoolHandle)
                          -> Result<GenOutput> {
        let timer = Timer::start();
        let mut stats = DecodeStats { prompt_tokens: prompt.len(), ..Default::default() };
        let mut rng = Rng::new(params.seed);
        let vocab = vocab_live(rt);

        let pf = Timer::start();
        let (_, mut cache) = rt.prefill(prompt)?;
        stats.prefill_wall = pf.elapsed();

        let mut cur = *prompt.last().unwrap();
        let mut out = Vec::with_capacity(params.max_new_tokens);

        while out.len() < params.max_new_tokens && capacity_left(rt, cache.len, 1) {
            let step = rt.decode("decode_lin_1", &cache, &[cur])?;
            let next = if params.sampling.is_greedy() {
                step.logits.argmax(0, vocab)
            } else {
                params.sampling.sample(&step.logits.row(0)[..vocab], &mut rng)
            };
            cache = rt.commit(cache, &step.new_kv, 1, &[0], 1)?;
            stats.record_accept(1);
            out.push(next);
            cur = next;
            if params.stop_at_eos && next == crate::tokenizer::EOS_ID {
                break;
            }
        }
        Ok(finish(out, params, stats, timer.elapsed()))
    }
}
