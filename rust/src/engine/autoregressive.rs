//! Autoregressive baseline: one token per step via `decode_lin_1`.
//! This is the reference implementation every speedup is measured against
//! and the byte-exactness oracle for the greedy engines.

use anyhow::{anyhow, Result};

use crate::engine::session::{EngineStep, EngineSuspend, RawStep, Session, SessionCore,
                             StepPlan};
use crate::engine::{capacity_left, vocab_live, Decoder, DecodeSession, FinishReason,
                    GenParams};
use crate::kv::EngineState;
use crate::metrics::Timer;
use crate::ngram::PoolHandle;
use crate::runtime::{Cache, ModelRuntime, StepOut};
use crate::util::rng::Rng;

#[derive(Debug, Default, Clone)]
pub struct AutoRegressive;

impl AutoRegressive {
    pub fn new() -> Self {
        AutoRegressive
    }
}

struct ArState<'rt> {
    rt: &'rt ModelRuntime,
    cache: Option<Cache>,
    cur: u32,
    rng: Rng,
    vocab: usize,
    pool: PoolHandle,
}

impl EngineStep for ArState<'_> {
    // raw_step ≡ plan → decode → finish: the per-session and fused-batch
    // paths execute the identical operation sequence (BatchStep contract).
    fn raw_step(&mut self, core: &mut SessionCore) -> Result<RawStep> {
        match self.plan_step(core)? {
            StepPlan::Stop(reason) => Ok(RawStep::Stop(reason)),
            StepPlan::Run => {
                let step = self.rt.decode("decode_lin_1", self.cache.as_ref().unwrap(),
                                          &[self.cur])?;
                self.finish_step(core, step)
            }
        }
    }

    fn pool_mut(&mut self) -> &mut PoolHandle {
        &mut self.pool
    }

    fn suspendable(&self) -> bool {
        self.rt.supports_cache_io()
    }

    fn suspend_engine(&mut self) -> Result<EngineSuspend> {
        let kv = {
            let cache = self.cache.as_ref().ok_or_else(|| anyhow!("session lost its cache"))?;
            self.rt.cache_to_host(cache)?
        };
        self.cache = None; // free the device buffer
        Ok(EngineSuspend {
            model: self.rt.mm.name.clone(),
            state: EngineState::Autoregressive { cur: self.cur, rng: self.rng.state() },
            kv,
            draft_kv: None,
            pool: std::mem::replace(&mut self.pool, PoolHandle::none()),
        })
    }

    fn batchable(&self) -> bool {
        true
    }

    fn plan_step(&mut self, _core: &mut SessionCore) -> Result<StepPlan> {
        let cache_len = self.cache.as_ref().unwrap().len;
        if !capacity_left(self.rt, cache_len, 1) {
            return Ok(StepPlan::Stop(FinishReason::CacheFull));
        }
        Ok(StepPlan::Run)
    }

    fn finish_step(&mut self, core: &mut SessionCore, step: StepOut) -> Result<RawStep> {
        let next = if core.params.sampling.is_greedy() {
            step.logits.argmax(0, self.vocab)
        } else {
            core.params.sampling.sample(&step.logits.row(0)[..self.vocab],
                                        &mut self.rng)
        };
        let cache = self.cache.take().unwrap();
        self.cache = Some(self.rt.commit(cache, &step.new_kv, 1, &[0], 1)?);
        self.cur = next;
        Ok(RawStep::Tokens(vec![next]))
    }

    fn window(&self) -> &[u32] {
        std::slice::from_ref(&self.cur)
    }

    fn batch_exe(&self) -> &str {
        "decode_lin_1"
    }

    fn group_key(&self) -> String {
        "autoregressive:decode_lin_1".into()
    }

    fn batch_cache(&self) -> Option<&Cache> {
        self.cache.as_ref()
    }
}

impl Decoder for AutoRegressive {
    fn name(&self) -> String {
        "autoregressive".into()
    }

    fn begin<'rt>(&self, rt: &'rt ModelRuntime, prompt: &[u32], params: &GenParams,
                  pool: PoolHandle) -> Result<Box<dyn DecodeSession + 'rt>> {
        let mut core = SessionCore::new(prompt.len(), params.clone());
        let rng = Rng::new(params.seed);
        let vocab = vocab_live(rt);

        let pf = Timer::start();
        // prefix-reuse-aware prefill (engines ignore the prompt logits)
        let cache = rt.prefill_reuse(prompt)?;
        core.stats.prefill_wall = pf.elapsed();

        let cur = *prompt.last().unwrap();
        Ok(Session::boxed(core, ArState { rt, cache: Some(cache), cur, rng, vocab, pool }))
    }
}

/// Reopen a suspended autoregressive session from its snapshot parts
/// (`kv::SessionSnapshot::resume` dispatches here).
pub(crate) fn resume_session<'rt>(rt: &'rt ModelRuntime, core: SessionCore,
                                  cache: Cache, cur: u32, rng: Rng, pool: PoolHandle)
                                  -> Box<dyn DecodeSession + 'rt> {
    let vocab = vocab_live(rt);
    Session::boxed(core, ArState { rt, cache: Some(cache), cur, rng, vocab, pool })
}
