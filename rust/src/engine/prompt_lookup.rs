//! Prompt-lookup decoding baseline (Saxena 2023; paper Tab. 3 row ②):
//! speculate by copying the continuation of the most recent match of the
//! current suffix inside [prompt + generated so far], then verify with one
//! `decode_lin_k` target call. No draft model, no lookahead branch.
//!
//! Serving extension: when the local history has no match, the engine falls
//! back to its [`PoolHandle`] — which, under the serving front, wraps the
//! cross-request `SharedNgramCache` — and it feeds accepted continuations
//! back into that pool. Verification keeps the output byte-exact either way.

use anyhow::{anyhow, bail, Result};

use crate::engine::session::{EngineStep, EngineSuspend, RawStep, Session, SessionCore};
use crate::engine::{capacity_left, vocab_live, Decoder, DecodeSession, FinishReason,
                    GenParams};
use crate::kv::EngineState;
use crate::metrics::Timer;
use crate::ngram::{PoolHandle, PoolSpec};
use crate::runtime::{Cache, ModelRuntime};

pub struct PromptLookup {
    /// total chain length (1 current + k-1 speculated); needs decode_lin_k.
    pub k: usize,
    /// match length: how many trailing tokens must match (transformers'
    /// prompt_lookup uses several; the paper notes Lookahead checks 1).
    pub match_len: usize,
}

impl PromptLookup {
    pub fn new(k: usize, match_len: usize) -> Self {
        PromptLookup { k, match_len: match_len.max(1) }
    }
}

/// Find the continuation after the most recent previous occurrence of the
/// `match_len`-token suffix of `history` (excluding the final position).
pub fn lookup_continuation(history: &[u32], match_len: usize, want: usize) -> Vec<u32> {
    if history.len() < match_len + 1 {
        return Vec::new();
    }
    let suffix = &history[history.len() - match_len..];
    // scan right-to-left for the most recent match
    for start in (0..history.len() - match_len).rev() {
        if &history[start..start + match_len] == suffix {
            let cont_start = start + match_len;
            let cont_end = (cont_start + want).min(history.len());
            if cont_end > cont_start {
                return history[cont_start..cont_end].to_vec();
            }
        }
    }
    Vec::new()
}

struct PromptLookupState<'rt> {
    rt: &'rt ModelRuntime,
    k: usize,
    match_len: usize,
    exe: String,
    /// prompt + every accepted token (untrimmed — the speculation source).
    history: Vec<u32>,
    tokens: Vec<u32>,
    cache: Option<Cache>,
    vocab: usize,
    pool: PoolHandle,
}

impl EngineStep for PromptLookupState<'_> {
    fn raw_step(&mut self, core: &mut SessionCore) -> Result<RawStep> {
        let k = self.k;
        let cache_len = self.cache.as_ref().unwrap().len;
        if !capacity_left(self.rt, cache_len, k) {
            return Ok(RawStep::Stop(FinishReason::CacheFull));
        }
        let cur = *self.history.last().unwrap();
        let mut spec = lookup_continuation(&self.history, self.match_len, k - 1);
        if spec.is_empty() {
            // local-history miss: fall back to the (possibly warm,
            // cross-request) pool — the handle counts the hit/miss
            spec = self.pool.lookup(cur, 1).into_iter().next().unwrap_or_default();
        } else {
            core.stats.pool_hits += 1;
        }
        // pad the chain with repeats of the last speculated/current token
        while spec.len() < k - 1 {
            spec.push(*spec.last().unwrap_or(&cur));
        }

        self.tokens[0] = cur;
        self.tokens[1..].copy_from_slice(&spec);
        let step = self.rt.decode(&self.exe, self.cache.as_ref().unwrap(),
                                  &self.tokens)?;

        let mut accepted: Vec<u32> = Vec::new();
        for i in 0..k {
            let target = step.logits.argmax(i, self.vocab);
            accepted.push(target);
            if i < k - 1 && spec[i] != target {
                break;
            }
        }
        let a = accepted.len().min(self.rt.commit_slots);
        accepted.truncate(a);
        let src: Vec<i32> = (0..a as i32).collect();
        let cache = self.cache.take().unwrap();
        self.cache = Some(self.rt.commit(cache, &step.new_kv, k, &src, a)?);

        self.history.extend_from_slice(&accepted);
        // feed the pool every n-gram window the accepted tokens created
        let fed = self.history.len().saturating_sub(a + k - 1);
        let window = self.history[fed..].to_vec();
        self.pool.seed_from(&window);

        Ok(RawStep::Tokens(accepted))
    }

    fn pool_mut(&mut self) -> &mut PoolHandle {
        &mut self.pool
    }

    fn suspendable(&self) -> bool {
        self.rt.supports_cache_io()
    }

    fn suspend_engine(&mut self) -> Result<EngineSuspend> {
        // `tokens` is fully rewritten by every step; the speculation window
        // is derived from `history`, and the pool handle travels with the
        // snapshot — so k, match_len, and history are the whole state
        let kv = {
            let cache = self.cache.as_ref().ok_or_else(|| anyhow!("session lost its cache"))?;
            self.rt.cache_to_host(cache)?
        };
        self.cache = None; // free the device buffer
        Ok(EngineSuspend {
            model: self.rt.mm.name.clone(),
            state: EngineState::PromptLookup {
                k: self.k,
                match_len: self.match_len,
                history: self.history.clone(),
            },
            kv,
            draft_kv: None,
            pool: std::mem::replace(&mut self.pool, PoolHandle::none()),
        })
    }
}

impl Decoder for PromptLookup {
    fn name(&self) -> String {
        format!("prompt_lookup[k{},m{}]", self.k, self.match_len)
    }

    fn pool_spec(&self) -> Option<PoolSpec> {
        // pool n-grams are [key + (k-1)-token suffix]: one verification chain
        Some(PoolSpec::new(self.k, 8, 16_384).with_kind("prompt_lookup"))
    }

    fn begin<'rt>(&self, rt: &'rt ModelRuntime, prompt: &[u32], params: &GenParams,
                  mut pool: PoolHandle) -> Result<Box<dyn DecodeSession + 'rt>> {
        if !params.sampling.is_greedy() {
            bail!("prompt_lookup baseline implements greedy verification only");
        }
        let mut core = SessionCore::new(prompt.len(), params.clone());
        let k = self.k;
        let exe = format!("decode_lin_{k}");
        if !rt.mm.executables.contains_key(&exe) {
            bail!("model lacks {exe}");
        }
        let vocab = vocab_live(rt);

        // bind (or degrade to) a pool of the right n-gram length; under the
        // serving front this is the cross-request shared cache
        pool.ensure(self.pool_spec().unwrap());
        pool.seed_from(prompt);

        let pf = Timer::start();
        // prefix-reuse-aware prefill (engines ignore the prompt logits)
        let cache = rt.prefill_reuse(prompt)?;
        core.stats.prefill_wall = pf.elapsed();

        Ok(Session::boxed(core, PromptLookupState {
            rt,
            k,
            match_len: self.match_len,
            exe,
            history: prompt.to_vec(),
            tokens: vec![0u32; k],
            cache: Some(cache),
            vocab,
            pool,
        }))
    }
}

/// Reopen a suspended prompt-lookup session from its snapshot parts
/// (`kv::SessionSnapshot::resume` dispatches here). The pool is NOT
/// re-seeded from the history: the handle restored with the snapshot
/// already holds the session's exact pool state, and re-seeding would
/// shuffle its LRU order (changing candidate sets, hence stats).
pub(crate) fn resume_session<'rt>(rt: &'rt ModelRuntime, core: SessionCore,
                                  cache: Cache, k: usize, match_len: usize,
                                  history: Vec<u32>, pool: PoolHandle)
                                  -> Result<Box<dyn DecodeSession + 'rt>> {
    // snapshots are cross-process input: validate before indexing
    if k < 2 || match_len == 0 {
        return Err(anyhow!(
            "prompt_lookup snapshot has invalid config k={k} match_len={match_len}"));
    }
    if history.is_empty() {
        return Err(anyhow!("prompt_lookup snapshot has an empty history"));
    }
    let exe = format!("decode_lin_{k}");
    if !rt.mm.executables.contains_key(&exe) {
        return Err(anyhow!("model lacks {exe}"));
    }
    Ok(Session::boxed(core, PromptLookupState {
        rt,
        k,
        match_len,
        exe,
        history,
        tokens: vec![0u32; k],
        cache: Some(cache),
        vocab: vocab_live(rt),
        pool,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_most_recent_continuation() {
        // history: a b c X a b -> suffix [a,b] matched at 0, continuation [c,X]
        let h = vec![1, 2, 3, 9, 1, 2];
        assert_eq!(lookup_continuation(&h, 2, 2), vec![3, 9]);
    }

    #[test]
    fn prefers_recent_match() {
        let h = vec![1, 2, 7, 5, 1, 2, 8, 6, 1, 2];
        assert_eq!(lookup_continuation(&h, 2, 1), vec![8]);
    }

    #[test]
    fn no_match_returns_empty() {
        assert_eq!(lookup_continuation(&[1, 2, 3], 2, 2), Vec::<u32>::new());
        assert_eq!(lookup_continuation(&[1], 2, 2), Vec::<u32>::new());
    }

    #[test]
    fn continuation_clipped_at_end() {
        let h = vec![1, 2, 3, 1, 2];
        assert_eq!(lookup_continuation(&h, 2, 5), vec![3, 1, 2]);
    }
}
