//! Prompt-lookup decoding baseline (Saxena 2023; paper Tab. 3 row ②):
//! speculate by copying the continuation of the most recent match of the
//! current suffix inside [prompt + generated so far], then verify with one
//! `decode_lin_k` target call. No draft model, no lookahead branch.
//!
//! Serving extension: when the local history has no match, the engine falls
//! back to its [`PoolHandle`] — which, under the serving front, wraps the
//! cross-request `SharedNgramCache` — and it feeds accepted continuations
//! back into that pool. Verification keeps the output byte-exact either way.

use anyhow::{bail, Result};

use crate::engine::{capacity_left, finish, vocab_live, Decoder, GenOutput, GenParams};
use crate::metrics::{DecodeStats, Timer};
use crate::ngram::{PoolHandle, PoolSpec};
use crate::runtime::ModelRuntime;
use crate::tokenizer::EOS_ID;

pub struct PromptLookup {
    /// total chain length (1 current + k-1 speculated); needs decode_lin_k.
    pub k: usize,
    /// match length: how many trailing tokens must match (transformers'
    /// prompt_lookup uses several; the paper notes Lookahead checks 1).
    pub match_len: usize,
}

impl PromptLookup {
    pub fn new(k: usize, match_len: usize) -> Self {
        PromptLookup { k, match_len: match_len.max(1) }
    }
}

/// Find the continuation after the most recent previous occurrence of the
/// `match_len`-token suffix of `history` (excluding the final position).
pub fn lookup_continuation(history: &[u32], match_len: usize, want: usize) -> Vec<u32> {
    if history.len() < match_len + 1 {
        return Vec::new();
    }
    let suffix = &history[history.len() - match_len..];
    // scan right-to-left for the most recent match
    for start in (0..history.len() - match_len).rev() {
        if &history[start..start + match_len] == suffix {
            let cont_start = start + match_len;
            let cont_end = (cont_start + want).min(history.len());
            if cont_end > cont_start {
                return history[cont_start..cont_end].to_vec();
            }
        }
    }
    Vec::new()
}

impl Decoder for PromptLookup {
    fn name(&self) -> String {
        format!("prompt_lookup[k{},m{}]", self.k, self.match_len)
    }

    fn pool_spec(&self) -> Option<PoolSpec> {
        // pool n-grams are [key + (k-1)-token suffix]: one verification chain
        Some(PoolSpec::new(self.k, 8, 16_384).with_kind("prompt_lookup"))
    }

    fn generate_with_pool(&mut self, rt: &ModelRuntime, prompt: &[u32],
                          params: &GenParams, pool: &mut PoolHandle)
                          -> Result<GenOutput> {
        if !params.sampling.is_greedy() {
            bail!("prompt_lookup baseline implements greedy verification only");
        }
        let timer = Timer::start();
        let k = self.k;
        let exe = format!("decode_lin_{k}");
        if !rt.mm.executables.contains_key(&exe) {
            bail!("model lacks {exe}");
        }
        let vocab = vocab_live(rt);
        let mut stats = DecodeStats { prompt_tokens: prompt.len(), ..Default::default() };

        // bind (or degrade to) a pool of the right n-gram length; under the
        // serving front this is the cross-request shared cache
        pool.ensure(self.pool_spec().unwrap());
        pool.seed_from(prompt);

        let pf = Timer::start();
        let (_, mut cache) = rt.prefill(prompt)?;
        stats.prefill_wall = pf.elapsed();

        let mut history: Vec<u32> = prompt.to_vec();
        let mut out: Vec<u32> = Vec::new();
        let mut tokens = vec![0u32; k];

        while out.len() < params.max_new_tokens && capacity_left(rt, cache.len, k) {
            let cur = *history.last().unwrap();
            let mut spec = lookup_continuation(&history, self.match_len, k - 1);
            if spec.is_empty() {
                // local-history miss: fall back to the (possibly warm,
                // cross-request) pool — the handle counts the hit/miss
                spec = pool.lookup(cur, 1).into_iter().next().unwrap_or_default();
            } else {
                stats.pool_hits += 1;
            }
            // pad the chain with repeats of the last speculated/current token
            while spec.len() < k - 1 {
                spec.push(*spec.last().unwrap_or(&cur));
            }

            tokens[0] = cur;
            tokens[1..].copy_from_slice(&spec);
            let step = rt.decode(&exe, &cache, &tokens)?;

            let mut accepted: Vec<u32> = Vec::new();
            for i in 0..k {
                let target = step.logits.argmax(i, vocab);
                accepted.push(target);
                if i < k - 1 && spec[i] != target {
                    break;
                }
            }
            let a = accepted.len().min(rt.commit_slots);
            accepted.truncate(a);
            let src: Vec<i32> = (0..a as i32).collect();
            cache = rt.commit(cache, &step.new_kv, k, &src, a)?;
            stats.record_accept(a);

            let hit_eos = params.stop_at_eos && accepted.contains(&EOS_ID);
            out.extend_from_slice(&accepted);
            history.extend_from_slice(&accepted);
            // feed the pool every n-gram window the accepted tokens created
            let fed = history.len().saturating_sub(a + k - 1);
            pool.seed_from(&history[fed..]);
            if hit_eos {
                break;
            }
        }
        pool.fill_stats(&mut stats);
        Ok(finish(out, params, stats, timer.elapsed()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_most_recent_continuation() {
        // history: a b c X a b -> suffix [a,b] matched at 0, continuation [c,X]
        let h = vec![1, 2, 3, 9, 1, 2];
        assert_eq!(lookup_continuation(&h, 2, 2), vec![3, 9]);
    }

    #[test]
    fn prefers_recent_match() {
        let h = vec![1, 2, 7, 5, 1, 2, 8, 6, 1, 2];
        assert_eq!(lookup_continuation(&h, 2, 1), vec![8]);
    }

    #[test]
    fn no_match_returns_empty() {
        assert_eq!(lookup_continuation(&[1, 2, 3], 2, 2), Vec::<u32>::new());
        assert_eq!(lookup_continuation(&[1], 2, 2), Vec::<u32>::new());
    }

    #[test]
    fn continuation_clipped_at_end() {
        let h = vec![1, 2, 3, 1, 2];
        assert_eq!(lookup_continuation(&h, 2, 5), vec![3, 1, 2]);
    }
}
