//! Speculative decoding baseline (Leviathan et al. / Chen et al., paper §2):
//! a separately-trained draft model proposes gamma tokens autoregressively,
//! the target model verifies them in one `decode_lin_{gamma+1}` call.
//! Greedy verification here (the guess-and-verify comparison point for
//! Fig. 5 / the scaling-law analysis of §4.1).

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::engine::session::{EngineStep, EngineSuspend, RawStep, Session, SessionCore,
                             StepPlan};
use crate::engine::{capacity_left, vocab_live, Decoder, DecodeSession, FinishReason,
                    GenParams};
use crate::kv::EngineState;
use crate::metrics::Timer;
use crate::ngram::PoolHandle;
use crate::runtime::{Cache, ModelRuntime, StepOut};

pub struct SpecDecode {
    /// Shared with every open session (sessions must not borrow the engine,
    /// so the draft runtime lives behind an `Rc`).
    pub draft: Rc<ModelRuntime>,
    pub gamma: usize,
}

impl SpecDecode {
    /// `gamma + 1` must have a matching `decode_lin_{gamma+1}` target
    /// executable (the shipped artifacts provide gamma = 4).
    pub fn new(draft: ModelRuntime, gamma: usize) -> Self {
        Self::with_shared(Rc::new(draft), gamma)
    }

    /// Build on an already-shared draft runtime (the worker keeps one draft
    /// runtime per model name and hands it to both fresh engines and
    /// snapshot resumes).
    pub fn with_shared(draft: Rc<ModelRuntime>, gamma: usize) -> Self {
        SpecDecode { draft, gamma }
    }
}

struct SpecState<'rt> {
    rt: &'rt ModelRuntime,
    draft: Rc<ModelRuntime>,
    gamma: usize,
    verify_exe: String,
    tokens: Vec<u32>,
    cur: u32,
    cache: Option<Cache>,
    dcache: Option<Cache>,
    vocab: usize,
    dvocab: usize,
    /// last plan's draft proposals, consumed by `finish_step` (the draft
    /// loop is side-effectful, so it runs exactly once, in the plan half).
    draft_toks: Vec<u32>,
    pool: PoolHandle,
}

impl EngineStep for SpecState<'_> {
    // raw_step ≡ plan → decode → finish: the per-session and fused-batch
    // paths execute the identical operation sequence (BatchStep contract).
    // Only the TARGET verify call fuses across sessions; each session's
    // draft proposals stay per-session inside its plan.
    fn raw_step(&mut self, core: &mut SessionCore) -> Result<RawStep> {
        match self.plan_step(core)? {
            StepPlan::Stop(r) => Ok(RawStep::Stop(r)),
            StepPlan::Run => {
                let step = self.rt.decode(&self.verify_exe,
                                          self.cache.as_ref().unwrap(),
                                          &self.tokens)?;
                self.finish_step(core, step)
            }
        }
    }

    fn plan_step(&mut self, _core: &mut SessionCore) -> Result<StepPlan> {
        let k = self.gamma + 1;
        let cache_len = self.cache.as_ref().unwrap().len;
        // capacity check BEFORE the draft loop: a Stop plan must leave the
        // draft cache untouched (plan may run again next round)
        if !capacity_left(self.rt, cache_len, k) {
            return Ok(StepPlan::Stop(FinishReason::CacheFull));
        }

        // -- draft proposes gamma tokens autoregressively ----------------
        let mut draft_toks = Vec::with_capacity(self.gamma);
        let mut dcur = self.cur;
        for _ in 0..self.gamma {
            let ds = self.draft.decode("decode_lin_1", self.dcache.as_ref().unwrap(),
                                       &[dcur])?;
            let t = ds.logits.argmax(0, self.dvocab);
            let dcache = self.dcache.take().unwrap();
            self.dcache = Some(self.draft.commit(dcache, &ds.new_kv, 1, &[0], 1)?);
            draft_toks.push(t);
            dcur = t;
        }

        // -- assemble the verify window [cur, d1..d_gamma] ----------------
        self.tokens[0] = self.cur;
        self.tokens[1..].copy_from_slice(&draft_toks);
        self.draft_toks = draft_toks;
        Ok(StepPlan::Run)
    }

    fn finish_step(&mut self, _core: &mut SessionCore, step: StepOut)
                   -> Result<RawStep> {
        let k = self.gamma + 1;
        let draft_toks = std::mem::take(&mut self.draft_toks);
        let mut accepted: Vec<u32> = Vec::new();
        for i in 0..k {
            let target = step.logits.argmax(i, self.vocab);
            accepted.push(target);
            if i < self.gamma && draft_toks[i] != target {
                break; // draft diverged; `target` is the corrected token
            }
            // matched (or bonus position i == gamma): continue
        }
        let a = accepted.len();
        let src: Vec<i32> = (0..a as i32).collect();
        let cache = self.cache.take().unwrap();
        self.cache = Some(self.rt.commit(cache, &step.new_kv, k, &src, a)?);

        // -- draft cache sync ---------------------------------------------
        // Draft committed rows for [cur, d1..d_{gamma-1}] during proposal.
        // Accepted prefix matches those rows; roll draft length back to
        // the target's and, when everything was accepted, ingest the last
        // draft token whose KV the draft never computed.
        if a == k {
            let ds = self.draft.decode("decode_lin_1", self.dcache.as_ref().unwrap(),
                                       &[draft_toks[self.gamma - 1]])?;
            let dcache = self.dcache.take().unwrap();
            self.dcache = Some(self.draft.commit(dcache, &ds.new_kv, 1, &[0], 1)?);
        }
        self.dcache.as_mut().unwrap().len = self.cache.as_ref().unwrap().len;

        self.cur = *accepted.last().unwrap();
        Ok(RawStep::Tokens(accepted))
    }

    fn pool_mut(&mut self) -> &mut PoolHandle {
        &mut self.pool
    }

    fn batchable(&self) -> bool {
        true
    }

    fn window(&self) -> &[u32] {
        &self.tokens
    }

    fn batch_exe(&self) -> &str {
        &self.verify_exe
    }

    fn group_key(&self) -> String {
        // the fused call is the target verify (linear chain, no mask); the
        // draft name rides along so mixed-draft groups never share a key
        format!("spec_decode:{}:{}", self.verify_exe, self.draft.mm.name)
    }

    fn batch_cache(&self) -> Option<&Cache> {
        self.cache.as_ref()
    }

    fn suspendable(&self) -> bool {
        // BOTH caches must be serializable: the draft's sequentially-built
        // cache is as much session state as the target's
        self.rt.supports_cache_io() && self.draft.supports_cache_io()
    }

    fn suspend_engine(&mut self) -> Result<EngineSuspend> {
        // capture both caches before freeing either, so a failed draft
        // capture leaves the (poisoned) session internally consistent
        let kv = {
            let cache = self.cache.as_ref().ok_or_else(|| anyhow!("session lost its cache"))?;
            self.rt.cache_to_host(cache)?
        };
        let dkv = {
            let dcache = self
                .dcache
                .as_ref()
                .ok_or_else(|| anyhow!("session lost its draft cache"))?;
            // second cache_io pass, through the DRAFT runtime: its cache
            // shape and element type are the draft model's, not the target's
            self.draft.cache_to_host(dcache)?
        };
        self.cache = None; // free the device buffers
        self.dcache = None;
        Ok(EngineSuspend {
            model: self.rt.mm.name.clone(),
            state: EngineState::SpecDecode {
                gamma: self.gamma,
                cur: self.cur,
                draft: self.draft.mm.name.clone(),
            },
            kv,
            draft_kv: Some(dkv),
            pool: std::mem::replace(&mut self.pool, PoolHandle::none()),
        })
    }
}

impl Decoder for SpecDecode {
    fn name(&self) -> String {
        format!("spec_decode[draft={},g{}]", self.draft.mm.name, self.gamma)
    }

    fn begin<'rt>(&self, rt: &'rt ModelRuntime, prompt: &[u32], params: &GenParams,
                  pool: PoolHandle) -> Result<Box<dyn DecodeSession + 'rt>> {
        if !params.sampling.is_greedy() {
            bail!("spec_decode baseline implements greedy verification only");
        }
        let mut core = SessionCore::new(prompt.len(), params.clone());
        let k = self.gamma + 1;
        let verify_exe = format!("decode_lin_{k}");
        if !rt.mm.executables.contains_key(&verify_exe) {
            bail!("target model lacks {verify_exe}");
        }
        let vocab = vocab_live(rt);
        let dvocab = vocab_live(&self.draft);

        let pf = Timer::start();
        // prefix-reuse-aware prefill (engines ignore the prompt logits);
        // the draft runtime has no prefix cache attached, so its call
        // falls through to a plain prefill
        let cache = rt.prefill_reuse(prompt)?;
        let dcache = self.draft.prefill_reuse(prompt)?;
        core.stats.prefill_wall = pf.elapsed();

        let cur = *prompt.last().unwrap();
        Ok(Session::boxed(core, SpecState {
            rt,
            draft: self.draft.clone(),
            gamma: self.gamma,
            verify_exe,
            tokens: vec![0u32; k],
            cur,
            cache: Some(cache),
            dcache: Some(dcache),
            vocab,
            dvocab,
            draft_toks: Vec::new(),
            pool,
        }))
    }
}

/// Reopen a suspended spec-decode session from its snapshot parts
/// (`kv::SessionSnapshot::resume_with` dispatches here, providing a draft
/// runtime for the snapshot's draft model — the second half of the
/// two-model state the `draft_kv` snapshot section captures).
pub(crate) fn resume_session<'rt>(rt: &'rt ModelRuntime, draft: Rc<ModelRuntime>,
                                  core: SessionCore, cache: Cache, dcache: Cache,
                                  gamma: usize, cur: u32, pool: PoolHandle)
                                  -> Result<Box<dyn DecodeSession + 'rt>> {
    // snapshots are cross-process input: validate before indexing
    if gamma == 0 {
        return Err(anyhow!("spec_decode snapshot has invalid gamma=0"));
    }
    let k = gamma + 1;
    let verify_exe = format!("decode_lin_{k}");
    if !rt.mm.executables.contains_key(&verify_exe) {
        return Err(anyhow!("target model lacks {verify_exe}"));
    }
    let dvocab = vocab_live(&draft);
    Ok(Session::boxed(core, SpecState {
        rt,
        draft,
        gamma,
        verify_exe,
        tokens: vec![0u32; k],
        cur,
        cache: Some(cache),
        dcache: Some(dcache),
        vocab: vocab_live(rt),
        dvocab,
        draft_toks: Vec::new(),
        pool,
    }))
}
