//! Speculative decoding baseline (Leviathan et al. / Chen et al., paper §2):
//! a separately-trained draft model proposes gamma tokens autoregressively,
//! the target model verifies them in one `decode_lin_{gamma+1}` call.
//! Greedy verification here (the guess-and-verify comparison point for
//! Fig. 5 / the scaling-law analysis of §4.1).

use anyhow::{bail, Result};

use crate::engine::{capacity_left, finish, vocab_live, Decoder, GenOutput, GenParams};
use crate::metrics::{DecodeStats, Timer};
use crate::ngram::PoolHandle;
use crate::runtime::ModelRuntime;
use crate::tokenizer::EOS_ID;

pub struct SpecDecode {
    pub draft: ModelRuntime,
    pub gamma: usize,
}

impl SpecDecode {
    /// `gamma + 1` must have a matching `decode_lin_{gamma+1}` target
    /// executable (the shipped artifacts provide gamma = 4).
    pub fn new(draft: ModelRuntime, gamma: usize) -> Self {
        SpecDecode { draft, gamma }
    }
}

impl Decoder for SpecDecode {
    fn name(&self) -> String {
        format!("spec_decode[draft={},g{}]", self.draft.mm.name, self.gamma)
    }

    fn generate_with_pool(&mut self, rt: &ModelRuntime, prompt: &[u32],
                          params: &GenParams, _pool: &mut PoolHandle)
                          -> Result<GenOutput> {
        if !params.sampling.is_greedy() {
            bail!("spec_decode baseline implements greedy verification only");
        }
        let timer = Timer::start();
        let k = self.gamma + 1;
        let verify_exe = format!("decode_lin_{k}");
        if !rt.mm.executables.contains_key(&verify_exe) {
            bail!("target model lacks {verify_exe}");
        }
        let vocab = vocab_live(rt);
        let dvocab = vocab_live(&self.draft);
        let mut stats = DecodeStats { prompt_tokens: prompt.len(), ..Default::default() };

        let pf = Timer::start();
        let (_, mut cache) = rt.prefill(prompt)?;
        let (_, mut dcache) = self.draft.prefill(prompt)?;
        stats.prefill_wall = pf.elapsed();

        let mut cur = *prompt.last().unwrap();
        let mut out: Vec<u32> = Vec::new();
        let mut tokens = vec![0u32; k];

        while out.len() < params.max_new_tokens && capacity_left(rt, cache.len, k) {
            // -- draft proposes gamma tokens autoregressively ----------------
            let mut draft_toks = Vec::with_capacity(self.gamma);
            let mut dcur = cur;
            for _ in 0..self.gamma {
                let ds = self.draft.decode("decode_lin_1", &dcache, &[dcur])?;
                let t = ds.logits.argmax(0, dvocab);
                dcache = self.draft.commit(dcache, &ds.new_kv, 1, &[0], 1)?;
                draft_toks.push(t);
                dcur = t;
            }

            // -- target verifies [cur, d1..d_gamma] in parallel ---------------
            tokens[0] = cur;
            tokens[1..].copy_from_slice(&draft_toks);
            let step = rt.decode(&verify_exe, &cache, &tokens)?;

            let mut accepted: Vec<u32> = Vec::new();
            for i in 0..k {
                let target = step.logits.argmax(i, vocab);
                accepted.push(target);
                if i < self.gamma && draft_toks[i] != target {
                    break; // draft diverged; `target` is the corrected token
                }
                // matched (or bonus position i == gamma): continue
            }
            let a = accepted.len();
            let src: Vec<i32> = (0..a as i32).collect();
            cache = rt.commit(cache, &step.new_kv, k, &src, a)?;
            stats.record_accept(a);

            // -- draft cache sync ---------------------------------------------
            // Draft committed rows for [cur, d1..d_{gamma-1}] during proposal.
            // Accepted prefix matches those rows; roll draft length back to
            // the target's and, when everything was accepted, ingest the last
            // draft token whose KV the draft never computed.
            if a == k {
                let ds = self.draft.decode("decode_lin_1", &dcache, &[draft_toks[self.gamma - 1]])?;
                dcache = self.draft.commit(dcache, &ds.new_kv, 1, &[0], 1)?;
            }
            dcache.len = cache.len;

            let hit_eos = params.stop_at_eos && accepted.contains(&EOS_ID);
            out.extend_from_slice(&accepted);
            cur = *out.last().unwrap();
            if hit_eos {
                break;
            }
        }
        Ok(finish(out, params, stats, timer.elapsed()))
    }
}
