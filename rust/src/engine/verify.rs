//! Disjoint-n-gram verification — Algorithms 3 (greedy) and 4 (sampling)
//! of the paper, decoupled from the runtime: callers provide the candidate
//! token lists and a distribution oracle `dist(candidate, depth)`:
//!
//!   depth 0   : the current token's output distribution (identical across
//!               candidates — they share the prefix),
//!   depth d>0 : candidate c's distribution after its d-th token.
//!
//! Output: the accepted tokens (1..=N per step — >=1 guaranteed, so a
//! lookahead step can never fall behind autoregressive decoding), plus the
//! *source rows* needed by the KV commit: which input slots hold the KVs of
//! the tokens that became committed.

use crate::util::rng::Rng;

/// `winner`: a candidate index whose inputs matched the whole accepted
/// prefix (None when the step fell back to plain decoding at depth 0).
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    pub tokens: Vec<u32>,
    /// For each accepted token except the last: the candidate-slot depth in
    /// the winner (the commit translates these to input rows). Length =
    /// tokens.len() - 1.
    pub matched_depths: usize,
    pub winner: Option<usize>,
}

/// Greedy verification (Algorithm 3) over disjoint candidates.
///
/// `cands[i]` is candidate i's token list (length N-1). `dist` must return a
/// probability vector over the live vocab (for greedy it is one-hot — only
/// argmax matters; we take a full vector for uniformity with Algorithm 4).
pub fn greedy_verify(
    cands: &[Vec<u32>],
    max_depth: usize,
    mut dist: impl FnMut(usize, usize) -> Vec<f32>,
) -> VerifyOutcome {
    let mut out = Vec::new();
    let mut alive: Vec<usize> = (0..cands.len()).collect();
    let mut matched = 0usize;

    for depth in 0..max_depth {
        // All alive candidates share the accepted prefix, so any alive
        // candidate's distribution at this depth is THE distribution.
        let rep = alive.first().copied().unwrap_or(0);
        let p = dist(rep, depth);
        let target = crate::engine::sampling::argmax(&p) as u32;

        // Does some alive candidate speculate exactly `target` here?
        let next_alive: Vec<usize> = alive
            .iter()
            .copied()
            .filter(|&c| cands[c].get(depth) == Some(&target))
            .collect();

        out.push(target);
        if next_alive.is_empty() || depth + 1 >= max_depth {
            // Fallback (guaranteed one-step movement) or candidates
            // exhausted: the token is still *correct* (it came from the
            // model's own distribution) but has no input slot -> it becomes
            // the new current token and the step ends. On full acceptance
            // (depth+1 == max_depth) we additionally take the bonus token
            // below.
            if !next_alive.is_empty() {
                // full acceptance: bonus token from the winner's last dist
                let w = next_alive[0];
                matched = depth + 1;
                let bonus = dist(w, depth + 1);
                out.push(crate::engine::sampling::argmax(&bonus) as u32);
                return VerifyOutcome { tokens: out, matched_depths: matched, winner: Some(w) };
            }
            let winner = if matched > 0 { alive.first().copied() } else { None };
            return VerifyOutcome { tokens: out, matched_depths: matched, winner };
        }
        alive = next_alive;
        matched = depth + 1;
    }
    unreachable!("loop always returns")
}

/// Sampling verification (Algorithm 4): speculations were generated greedily
/// (one-hot proposal distribution), so rejection updates zero out the
/// rejected token and renormalize — output distribution is preserved
/// (paper Appendix B).
pub fn sample_verify(
    cands: &[Vec<u32>],
    max_depth: usize,
    mut dist: impl FnMut(usize, usize) -> Vec<f32>,
    rng: &mut Rng,
) -> VerifyOutcome {
    let mut out = Vec::new();
    let mut alive: Vec<usize> = (0..cands.len()).collect();
    let mut matched = 0usize;

    for depth in 0..max_depth {
        let rep = alive.first().copied().unwrap_or(0);
        let mut p = dist(rep, depth);

        // Walk candidates in order; rejection zeroes the token's mass.
        let mut accepted_tok: Option<u32> = None;
        for pos in 0..alive.len() {
            let c = alive[pos];
            let Some(&s) = cands[c].get(depth) else { continue };
            let ps = p.get(s as usize).copied().unwrap_or(0.0);
            let r = rng.f32();
            if ps > 0.0 && r <= ps {
                accepted_tok = Some(s);
                break;
            }
            // rejected: remove s from the distribution and renormalize
            if (s as usize) < p.len() {
                p[s as usize] = 0.0;
                crate::engine::sampling::normalize(&mut p);
            }
        }

        match accepted_tok {
            Some(s) => {
                out.push(s);
                let next_alive: Vec<usize> = alive
                    .iter()
                    .copied()
                    .filter(|&c| cands[c].get(depth) == Some(&s))
                    .collect();
                matched = depth + 1;
                if depth + 1 >= max_depth {
                    // full acceptance: bonus token sampled from the winner's
                    // final distribution
                    let w = next_alive[0];
                    let bonus = dist(w, depth + 1);
                    out.push(crate::engine::sampling::sample_from(&bonus, rng));
                    return VerifyOutcome {
                        tokens: out,
                        matched_depths: matched,
                        winner: Some(w),
                    };
                }
                alive = next_alive;
            }
            None => {
                // all candidates rejected at this depth: sample from the
                // residual distribution (guaranteed one-step movement)
                let tok = if p.iter().any(|&x| x > 0.0) {
                    crate::engine::sampling::sample_from(&p, rng)
                } else {
                    // every candidate token absorbed the whole mass and got
                    // rejected — numerically impossible for r<=p, but guard.
                    crate::engine::sampling::argmax(&dist(rep, depth)) as u32
                };
                out.push(tok);
                let winner = if matched > 0 { alive.first().copied() } else { None };
                return VerifyOutcome { tokens: out, matched_depths: matched, winner };
            }
        }
    }
    unreachable!("loop always returns")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn onehot(v: usize, n: usize) -> Vec<f32> {
        let mut p = vec![0.0; n];
        p[v] = 1.0;
        p
    }

    // Model that deterministically continues 1,2,3,4,... after any prefix.
    fn seq_dist(_c: usize, depth: usize) -> Vec<f32> {
        onehot(depth + 1, 16)
    }

    #[test]
    fn greedy_accepts_full_match_with_bonus() {
        let cands = vec![vec![1, 2, 3]];
        let o = greedy_verify(&cands, 3, seq_dist);
        assert_eq!(o.tokens, vec![1, 2, 3, 4]); // 3 matched + bonus
        assert_eq!(o.matched_depths, 3);
        assert_eq!(o.winner, Some(0));
    }

    #[test]
    fn greedy_partial_match_stops_with_fallback() {
        let cands = vec![vec![1, 9, 9]];
        let o = greedy_verify(&cands, 3, seq_dist);
        // depth0: target 1 matches; depth1: target 2, cand has 9 -> fallback
        assert_eq!(o.tokens, vec![1, 2]);
        assert_eq!(o.matched_depths, 1);
        assert_eq!(o.winner, Some(0));
    }

    #[test]
    fn greedy_no_candidates_is_plain_step() {
        let o = greedy_verify(&[], 3, seq_dist);
        assert_eq!(o.tokens, vec![1]);
        assert_eq!(o.matched_depths, 0);
        assert_eq!(o.winner, None);
    }

    #[test]
    fn greedy_picks_matching_candidate_among_many() {
        let cands = vec![vec![7, 7], vec![1, 2], vec![1, 9]];
        let o = greedy_verify(&cands, 2, seq_dist);
        assert_eq!(o.tokens, vec![1, 2, 3]);
        assert_eq!(o.winner, Some(1)); // the fully-matching one
    }

    #[test]
    fn greedy_never_fewer_than_one_token() {
        let cands = vec![vec![9], vec![8]];
        let o = greedy_verify(&cands, 1, |_, d| onehot(d + 1, 16));
        assert!(!o.tokens.is_empty());
    }

    #[test]
    fn sample_greedy_model_behaves_like_greedy() {
        // With one-hot model dists, sampling verification must accept the
        // same tokens as greedy verification.
        let cands = vec![vec![1, 2, 9]];
        let mut rng = Rng::new(3);
        let o = sample_verify(&cands, 3, seq_dist, &mut rng);
        assert_eq!(o.tokens, vec![1, 2, 3]);
        assert_eq!(o.matched_depths, 2);
    }

    #[test]
    fn sample_preserves_distribution_no_candidates() {
        // Statistical check of Theorem A's base case: with a non-trivial P
        // and speculations that never match, accepted tokens ~ P.
        let p_true = vec![0.5f32, 0.3, 0.2];
        let cands = vec![vec![2u32]]; // speculation with prob 0.2
        let mut rng = Rng::new(77);
        let mut counts = [0usize; 3];
        let n = 40_000;
        for _ in 0..n {
            let o = sample_verify(&cands, 1, |_, d| {
                if d == 0 {
                    p_true.clone()
                } else {
                    vec![1.0, 0.0, 0.0]
                }
            }, &mut rng);
            counts[o.tokens[0] as usize] += 1;
        }
        for i in 0..3 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - p_true[i] as f64).abs() < 0.015,
                "token {i}: {emp} vs {}",
                p_true[i]
            );
        }
    }

    #[test]
    fn sample_multi_candidate_distribution_preserved() {
        // Two speculations covering tokens {0, 1}; the output must still
        // follow P exactly (Appendix B, G=2 case).
        let p_true = vec![0.25f32, 0.35, 0.4];
        let cands = vec![vec![0u32], vec![1u32]];
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            let o = sample_verify(&cands, 1, |_, d| {
                if d == 0 {
                    p_true.clone()
                } else {
                    vec![1.0, 0.0, 0.0]
                }
            }, &mut rng);
            counts[o.tokens[0] as usize] += 1;
        }
        for i in 0..3 {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - p_true[i] as f64).abs() < 0.015,
                "token {i}: {emp} vs {}",
                p_true[i]
            );
        }
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// Random model: deterministic dist from (depth, candidate salt).
    fn model_dist(vocab: usize, salt: u64) -> impl Fn(usize, usize) -> Vec<f32> {
        move |c, depth| {
            let mut r = Rng::new(salt ^ ((depth as u64) << 3) ^ (c as u64));
            let mut p: Vec<f32> = (0..vocab).map(|_| r.f32().max(1e-3)).collect();
            crate::engine::sampling::normalize(&mut p);
            p
        }
    }

    #[test]
    fn prop_greedy_verify_invariants() {
        forall(
            300,
            91,
            |r: &mut Rng| {
                let n = r.range(2, 6);
                let g = r.range(0, 6);
                let cands: Vec<Vec<u32>> = (0..g)
                    .map(|_| (0..n - 1).map(|_| r.below(8) as u32).collect())
                    .collect();
                (cands, n)
            },
            |(cands, n)| {
                let max_depth = n - 1;
                let o = greedy_verify(cands, max_depth, model_dist(8, 7));
                // 1..=N tokens per step, never zero (guaranteed movement)
                if o.tokens.is_empty() || o.tokens.len() > *n {
                    return Err(format!("accepted {} of max {n}", o.tokens.len()));
                }
                // matched prefix must be a real candidate prefix
                if let Some(w) = o.winner {
                    let m = o.matched_depths;
                    if m > 0 && cands[w][..m.min(cands[w].len())]
                        != o.tokens[..m.min(o.tokens.len())]
                    {
                        return Err(format!("winner {w} does not match prefix"));
                    }
                } else if o.matched_depths != 0 {
                    return Err("matched without winner".into());
                }
                // tokens.len() == matched + 1 (fallback or bonus token)
                if o.tokens.len() != o.matched_depths + 1 {
                    return Err(format!(
                        "len {} != matched {} + 1", o.tokens.len(), o.matched_depths));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_sample_verify_invariants() {
        forall(
            300,
            92,
            |r: &mut Rng| {
                let n = r.range(2, 6);
                let g = r.range(0, 6);
                let cands: Vec<Vec<u32>> = (0..g)
                    .map(|_| (0..n - 1).map(|_| r.below(8) as u32).collect())
                    .collect();
                let seed = r.next_u64() as usize;
                (cands, n, seed)
            },
            |(cands, n, seed)| {
                let mut rng = Rng::new(*seed as u64);
                let o = sample_verify(cands, n - 1, model_dist(8, 13), &mut rng);
                if o.tokens.is_empty() || o.tokens.len() > *n {
                    return Err(format!("accepted {} of max {n}", o.tokens.len()));
                }
                if o.tokens.len() != o.matched_depths + 1 {
                    return Err("len != matched + 1".into());
                }
                if let Some(w) = o.winner {
                    if o.matched_depths > 0
                        && cands[w][..o.matched_depths] != o.tokens[..o.matched_depths]
                    {
                        return Err("winner prefix mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_greedy_equals_sampling_with_onehot_model() {
        // With one-hot model distributions, Algorithm 4 must accept exactly
        // what Algorithm 3 accepts (the greedy degenerate case).
        forall(
            200,
            93,
            |r: &mut Rng| {
                let n = r.range(2, 6);
                let g = r.range(0, 5);
                let cands: Vec<Vec<u32>> = (0..g)
                    .map(|_| (0..n - 1).map(|_| r.below(4) as u32).collect())
                    .collect();
                (cands, n)
            },
            |(cands, n)| {
                let onehot = |c: usize, depth: usize| {
                    let d = model_dist(4, 3)(c, depth);
                    let mut o = vec![0.0f32; 4];
                    o[crate::engine::sampling::argmax(&d)] = 1.0;
                    o
                };
                let a = greedy_verify(cands, n - 1, onehot);
                let mut rng = Rng::new(5);
                let b = sample_verify(cands, n - 1, onehot, &mut rng);
                if a.tokens != b.tokens {
                    return Err(format!("{:?} != {:?}", a.tokens, b.tokens));
                }
                Ok(())
            },
        );
    }
}
