//! Sampling: greedy / temperature / top-k / top-p over logits rows, plus the
//! probability-distribution transform shared with the verification branch
//! (Algorithm 4 must verify against *exactly* the distribution tokens are
//! sampled from, so both paths go through `SamplingParams::dist`).

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy (argmax).
    pub temperature: f64,
    /// 0 = disabled.
    pub top_k: usize,
    /// 1.0 = disabled.
    pub top_p: f64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self::default()
    }

    pub fn temp(t: f64) -> Self {
        SamplingParams { temperature: t, ..Self::default() }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// The model's sampling distribution for one logits row (live vocab
    /// only): softmax(logits / T) with top-k / top-p filtering renormalized.
    pub fn dist(&self, logits: &[f32]) -> Vec<f32> {
        let n = logits.len();
        if self.is_greedy() {
            // degenerate one-hot on the argmax
            let mut out = vec![0.0f32; n];
            out[argmax(logits)] = 1.0;
            return out;
        }
        let t = self.temperature as f32;
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f32> = logits.iter().map(|&l| ((l - m) / t).exp()).collect();

        if self.top_k > 0 && self.top_k < n {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            for &i in &idx[self.top_k..] {
                probs[i] = 0.0;
            }
        }
        if self.top_p < 1.0 {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
            let total: f32 = probs.iter().sum();
            let mut acc = 0.0f32;
            let mut cut = n;
            for (rank, &i) in idx.iter().enumerate() {
                acc += probs[i] / total;
                if acc >= self.top_p as f32 {
                    cut = rank + 1;
                    break;
                }
            }
            for &i in &idx[cut..] {
                probs[i] = 0.0;
            }
        }
        normalize(&mut probs);
        probs
    }

    /// Draw a token id from a logits row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        if self.is_greedy() {
            return argmax(logits) as u32;
        }
        let d = self.dist(logits);
        rng.weighted(&d) as u32
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

pub fn normalize(probs: &mut [f32]) {
    let s: f32 = probs.iter().sum();
    if s > 0.0 {
        for p in probs.iter_mut() {
            *p /= s;
        }
    }
}

/// Sample from an explicit probability vector.
pub fn sample_from(probs: &[f32], rng: &mut Rng) -> u32 {
    rng.weighted(probs) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let p = SamplingParams::greedy();
        let mut r = Rng::new(1);
        assert_eq!(p.sample(&[0.1, 3.0, 1.0], &mut r), 1);
        let d = p.dist(&[0.1, 3.0, 1.0]);
        assert_eq!(d, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn temperature_softens() {
        let hot = SamplingParams::temp(2.0).dist(&[1.0, 2.0]);
        let cold = SamplingParams::temp(0.25).dist(&[1.0, 2.0]);
        assert!(cold[1] > hot[1]); // low T concentrates
        assert!((hot.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn top_k_zeroes_tail() {
        let mut p = SamplingParams::temp(1.0);
        p.top_k = 2;
        let d = p.dist(&[5.0, 4.0, 1.0, 0.0]);
        assert!(d[2] == 0.0 && d[3] == 0.0);
        assert!(d[0] > 0.0 && d[1] > 0.0);
    }

    #[test]
    fn top_p_keeps_nucleus() {
        let mut p = SamplingParams::temp(1.0);
        p.top_p = 0.5;
        let d = p.dist(&[10.0, 0.0, 0.0, 0.0]); // ~all mass on 0
        assert!(d[0] > 0.99);
        assert_eq!(d[1], 0.0);
    }

    #[test]
    fn sampling_matches_dist_statistically() {
        let p = SamplingParams::temp(1.0);
        let logits = [1.0f32, 2.0, 0.5];
        let d = p.dist(&logits);
        let mut r = Rng::new(42);
        let mut counts = [0usize; 3];
        let n = 30_000;
        for _ in 0..n {
            counts[p.sample(&logits, &mut r) as usize] += 1;
        }
        for i in 0..3 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - d[i] as f64).abs() < 0.02, "{i}: {emp} vs {}", d[i]);
        }
    }
}
