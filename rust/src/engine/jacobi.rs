//! Jacobi decoding (paper §2, Santilli et al. 2023): fixed-point iteration
//! over a window of future-token guesses, *without* the n-gram pool or the
//! verification branch. Demonstrates the limitation Lookahead fixes: tokens
//! land at wrong positions and get clobbered, so S stays near 1.

use anyhow::{anyhow, Result};

use crate::engine::session::{EngineStep, EngineSuspend, RawStep, Session, SessionCore,
                             StepPlan};
use crate::engine::{capacity_left, vocab_live, Decoder, DecodeSession, FinishReason,
                    GenParams};
use crate::kv::EngineState;
use crate::metrics::Timer;
use crate::ngram::PoolHandle;
use crate::runtime::{Cache, ModelRuntime, StepOut};
use crate::util::rng::Rng;

pub struct Jacobi {
    /// Window size = the linear-chain executable length (decode_lin_k).
    pub window: usize,
}

impl Jacobi {
    pub fn new(window: usize) -> Self {
        Jacobi { window }
    }
}

struct JacobiState<'rt> {
    rt: &'rt ModelRuntime,
    k: usize,
    exe: String,
    rng: Rng,
    /// guesses y_1..y_{k-1} for the next positions.
    guesses: Vec<u32>,
    tokens: Vec<u32>,
    cur: u32,
    cache: Option<Cache>,
    vocab: usize,
    pool: PoolHandle,
}

impl EngineStep for JacobiState<'_> {
    // raw_step ≡ plan → decode → finish: the per-session and fused-batch
    // paths execute the identical operation sequence (BatchStep contract).
    fn raw_step(&mut self, core: &mut SessionCore) -> Result<RawStep> {
        match self.plan_step(core)? {
            StepPlan::Stop(r) => Ok(RawStep::Stop(r)),
            StepPlan::Run => {
                let step = self.rt.decode(&self.exe, self.cache.as_ref().unwrap(),
                                          &self.tokens)?;
                self.finish_step(core, step)
            }
        }
    }

    fn plan_step(&mut self, _core: &mut SessionCore) -> Result<StepPlan> {
        let cache_len = self.cache.as_ref().unwrap().len;
        if !capacity_left(self.rt, cache_len, self.k) {
            return Ok(StepPlan::Stop(FinishReason::CacheFull));
        }
        self.tokens[0] = self.cur;
        self.tokens[1..].copy_from_slice(&self.guesses);
        Ok(StepPlan::Run)
    }

    fn finish_step(&mut self, _core: &mut SessionCore, step: StepOut)
                   -> Result<RawStep> {
        let k = self.k;
        // Jacobi update: output i is the new value for position i+1.
        let new_vals: Vec<u32> =
            (0..k).map(|i| step.logits.argmax(i, self.vocab)).collect();

        // Fixed-point acceptance: y_{i+1} is final iff the input guess at
        // position i+1 equals the model output given positions <= i
        // (all of which are final).
        let mut accepted: Vec<u32> = vec![new_vals[0]];
        for i in 0..k - 1 {
            if self.guesses[i] == new_vals[i] {
                // the guess was already the model's output -> position
                // i+2's output new_vals[i+1] is also computed from a
                // fully-final prefix
                accepted.push(new_vals[i + 1]);
            } else {
                break;
            }
        }
        let a = accepted.len().min(self.rt.commit_slots);
        accepted.truncate(a);

        // Commit rows: cur (idx 0) + the matched guesses (idx 1..a-1).
        let src: Vec<i32> = (0..a as i32).collect();
        let cache = self.cache.take().unwrap();
        self.cache = Some(self.rt.commit(cache, &step.new_kv, k, &src, a)?);

        self.cur = *accepted.last().unwrap();

        // Next window: shift the trajectory by a, refill tail from the
        // model's own new values (better than random re-init).
        let mut next: Vec<u32> = Vec::with_capacity(k - 1);
        next.extend(new_vals.iter().copied().skip(a).take(k - 1));
        while next.len() < k - 1 {
            next.push(self.rng.below(256) as u32);
        }
        self.guesses = next;

        Ok(RawStep::Tokens(accepted))
    }

    fn pool_mut(&mut self) -> &mut PoolHandle {
        &mut self.pool
    }

    fn batchable(&self) -> bool {
        true
    }

    fn window(&self) -> &[u32] {
        &self.tokens
    }

    fn batch_exe(&self) -> &str {
        &self.exe
    }

    fn group_key(&self) -> String {
        // linear-chain executable, no mask: the exe name pins the shape
        format!("jacobi:{}", self.exe)
    }

    fn batch_cache(&self) -> Option<&Cache> {
        self.cache.as_ref()
    }

    fn suspendable(&self) -> bool {
        self.rt.supports_cache_io()
    }

    fn suspend_engine(&mut self) -> Result<EngineSuspend> {
        // `tokens` is fully rewritten by every step (cur + guesses), so the
        // trajectory guesses + rng stream + current token are the whole
        // inter-step state
        let kv = {
            let cache = self.cache.as_ref().ok_or_else(|| anyhow!("session lost its cache"))?;
            self.rt.cache_to_host(cache)?
        };
        self.cache = None; // free the device buffer
        Ok(EngineSuspend {
            model: self.rt.mm.name.clone(),
            state: EngineState::Jacobi {
                k: self.k,
                guesses: self.guesses.clone(),
                cur: self.cur,
                rng: self.rng.state(),
            },
            kv,
            draft_kv: None,
            pool: std::mem::replace(&mut self.pool, PoolHandle::none()),
        })
    }
}

impl Decoder for Jacobi {
    fn name(&self) -> String {
        format!("jacobi[k{}]", self.window)
    }

    fn begin<'rt>(&self, rt: &'rt ModelRuntime, prompt: &[u32], params: &GenParams,
                  pool: PoolHandle) -> Result<Box<dyn DecodeSession + 'rt>> {
        let mut core = SessionCore::new(prompt.len(), params.clone());
        let k = self.window;
        rt.mm.decode_lin_exe(k).map_err(|e| anyhow!("{e}"))?;
        let exe = format!("decode_lin_{k}");
        let vocab = vocab_live(rt);
        let mut rng = Rng::new(params.seed ^ 0x1AC0B1);

        let pf = Timer::start();
        // prefix-reuse-aware prefill (engines ignore the prompt logits)
        let cache = rt.prefill_reuse(prompt)?;
        core.stats.prefill_wall = pf.elapsed();

        let cur = *prompt.last().unwrap();
        // random init, matching the historical generate() path
        let guesses: Vec<u32> = (0..k - 1).map(|_| rng.below(256) as u32).collect();

        Ok(Session::boxed(core, JacobiState {
            rt,
            k,
            exe,
            rng,
            guesses,
            tokens: vec![0u32; k],
            cur,
            cache: Some(cache),
            vocab,
            pool,
        }))
    }
}

/// Reopen a suspended Jacobi session from its snapshot parts
/// (`kv::SessionSnapshot::resume` dispatches here). The chain executable is
/// re-derived from `k` exactly as `begin` derives it; the trajectory
/// guesses, RNG stream, and current token continue from the snapshot.
pub(crate) fn resume_session<'rt>(rt: &'rt ModelRuntime, core: SessionCore,
                                  cache: Cache, k: usize, guesses: Vec<u32>, cur: u32,
                                  rng: Rng, pool: PoolHandle)
                                  -> Result<Box<dyn DecodeSession + 'rt>> {
    // snapshots are cross-process input: validate before indexing
    if k < 2 {
        return Err(anyhow!("jacobi snapshot has invalid window k={k}"));
    }
    if guesses.len() != k - 1 {
        return Err(anyhow!("jacobi snapshot has {} guesses, want {}",
                           guesses.len(), k - 1));
    }
    rt.mm.decode_lin_exe(k).map_err(|e| anyhow!("{e}"))?;
    Ok(Session::boxed(core, JacobiState {
        rt,
        k,
        exe: format!("decode_lin_{k}"),
        rng,
        guesses,
        tokens: vec![0u32; k],
        cur,
        cache: Some(cache),
        vocab: vocab_live(rt),
        pool,
    }))
}
