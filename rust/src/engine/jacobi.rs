//! Jacobi decoding (paper §2, Santilli et al. 2023): fixed-point iteration
//! over a window of future-token guesses, *without* the n-gram pool or the
//! verification branch. Demonstrates the limitation Lookahead fixes: tokens
//! land at wrong positions and get clobbered, so S stays near 1.

use anyhow::{anyhow, Result};

use crate::engine::{capacity_left, finish, vocab_live, Decoder, GenOutput, GenParams};
use crate::metrics::{DecodeStats, Timer};
use crate::ngram::PoolHandle;
use crate::runtime::ModelRuntime;
use crate::tokenizer::EOS_ID;
use crate::util::rng::Rng;

pub struct Jacobi {
    /// Window size = the linear-chain executable length (decode_lin_k).
    pub window: usize,
}

impl Jacobi {
    pub fn new(window: usize) -> Self {
        Jacobi { window }
    }
}

impl Decoder for Jacobi {
    fn name(&self) -> String {
        format!("jacobi[k{}]", self.window)
    }

    fn generate_with_pool(&mut self, rt: &ModelRuntime, prompt: &[u32],
                          params: &GenParams, _pool: &mut PoolHandle)
                          -> Result<GenOutput> {
        let timer = Timer::start();
        let k = self.window;
        rt.mm.decode_lin_exe(k).map_err(|e| anyhow!("{e}"))?;
        let exe = format!("decode_lin_{k}");
        let vocab = vocab_live(rt);
        let mut rng = Rng::new(params.seed ^ 0x1AC0B1);
        let mut stats = DecodeStats { prompt_tokens: prompt.len(), ..Default::default() };

        let pf = Timer::start();
        let (_, mut cache) = rt.prefill(prompt)?;
        stats.prefill_wall = pf.elapsed();

        let mut cur = *prompt.last().unwrap();
        // guesses y_1..y_{k-1} for the next positions (random init)
        let mut guesses: Vec<u32> =
            (0..k - 1).map(|_| rng.below(256) as u32).collect();
        let mut out: Vec<u32> = Vec::new();
        let mut tokens = vec![0u32; k];

        while out.len() < params.max_new_tokens && capacity_left(rt, cache.len, k) {
            tokens[0] = cur;
            tokens[1..].copy_from_slice(&guesses);
            let step = rt.decode(&exe, &cache, &tokens)?;

            // Jacobi update: output i is the new value for position i+1.
            let new_vals: Vec<u32> =
                (0..k).map(|i| step.logits.argmax(i, vocab)).collect();

            // Fixed-point acceptance: y_{i+1} is final iff the input guess at
            // position i+1 equals the model output given positions <= i
            // (all of which are final).
            let mut accepted: Vec<u32> = vec![new_vals[0]];
            for i in 0..k - 1 {
                if guesses[i] == new_vals[i] {
                    // the guess was already the model's output -> position
                    // i+2's output new_vals[i+1] is also computed from a
                    // fully-final prefix
                    accepted.push(new_vals[i + 1]);
                } else {
                    break;
                }
            }
            let a = accepted.len().min(rt.commit_slots);
            accepted.truncate(a);

            // Commit rows: cur (idx 0) + the matched guesses (idx 1..a-1).
            let src: Vec<i32> = (0..a as i32).collect();
            cache = rt.commit(cache, &step.new_kv, k, &src, a)?;
            stats.record_accept(a);

            let hit_eos = params.stop_at_eos && accepted.contains(&EOS_ID);
            out.extend_from_slice(&accepted);
            cur = *out.last().unwrap();

            // Next window: shift the trajectory by a, refill tail from the
            // model's own new values (better than random re-init).
            let mut next: Vec<u32> = Vec::with_capacity(k - 1);
            next.extend(new_vals.iter().copied().skip(a).take(k - 1));
            while next.len() < k - 1 {
                next.push(rng.below(256) as u32);
            }
            guesses = next;

            if hit_eos {
                break;
            }
        }
        Ok(finish(out, params, stats, timer.elapsed()))
    }
}
