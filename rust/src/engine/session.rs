//! Resumable decoding sessions — the step-structured view of Algorithm 2.
//!
//! Lookahead decoding commits a variable-length run of verified tokens per
//! step, but the original `Decoder::generate_with_pool` hid that behind a
//! blocking, all-at-once call. [`DecodeSession`] exposes the step structure:
//! `Decoder::begin` opens a session that owns its KV cache, n-gram pool
//! handle, and per-step stats; each [`DecodeSession::step`] advances one
//! fused model call and reports the tokens it committed. The serving layer
//! builds streaming, cancellation, and time-sliced multi-request
//! interleaving on top; the one-shot `generate()`/`generate_with_pool()`
//! are now thin loops over `step()` (byte-exact with the old behavior).
//!
//! Internals: engines implement the private [`EngineStep`] trait (one raw
//! Algorithm-2 step, no budget/EOS bookkeeping); the generic [`Session`]
//! wrapper folds raw commits through [`SessionCore::commit_step`], which
//! applies the same budget/EOS trimming contract as `engine::finish` —
//! incrementally, so streamed deltas concatenate to exactly the one-shot
//! output.

use anyhow::Result;

use crate::engine::{finish, GenOutput, GenParams};
use crate::metrics::{DecodeStats, Timer};
use crate::ngram::PoolHandle;
use crate::tokenizer::EOS_ID;

/// Why a session stopped producing tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// EOS was committed (and trimmed from the output).
    Eos,
    /// `max_new_tokens` reached.
    Budget,
    /// The KV cache cannot hold another step.
    CacheFull,
    /// The caller cancelled the session.
    Cancelled,
    /// The request's serving deadline expired.
    Deadline,
    /// A step returned an error; the session is poisoned.
    Failed,
}

impl FinishReason {
    /// Stable wire-format tag (the `finish` field of the final record).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Budget => "budget",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Deadline => "deadline",
            FinishReason::Failed => "failed",
        }
    }
}

/// Result of one [`DecodeSession::step`] call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepOutcome {
    /// The step committed these tokens to the output (already trimmed to the
    /// generation budget and cut at EOS — concatenating every `Committed`
    /// payload reproduces the one-shot output byte-exactly). May be empty
    /// when the step's tokens were entirely trimmed (e.g. EOS first).
    Committed { tokens: Vec<u32> },
    /// The session is finished; no tokens were committed by this call.
    Finished { reason: FinishReason },
}

/// A resumable decoding session over one request.
///
/// Sessions borrow the [`crate::runtime::ModelRuntime`] they were opened on
/// and own everything else: KV cache position, n-gram pool handle, RNG,
/// window/trajectory state, and per-step [`DecodeStats`]. Drive with
/// [`step`](DecodeSession::step) until [`finished`](DecodeSession::finished)
/// is `Some`, then call [`into_output`](DecodeSession::into_output) for the
/// final record.
pub trait DecodeSession {
    /// Advance one decode step. After the session finishes, further calls
    /// return `Finished` with the same reason and do no work.
    fn step(&mut self) -> Result<StepOutcome>;

    /// Tokens committed so far (already budget/EOS-trimmed).
    fn tokens(&self) -> &[u32];

    /// Per-step statistics so far. Pool counters are folded in when the
    /// session finishes (they are exact in `into_output`'s stats).
    fn stats(&self) -> &DecodeStats;

    /// `Some(reason)` once the session will produce no more tokens.
    fn finished(&self) -> Option<FinishReason>;

    /// Stop the session before its natural end (`FinishReason::Cancelled`
    /// or `FinishReason::Deadline`). Tokens committed so far remain valid;
    /// the next `step()` reports `Finished`. No-op on a finished session.
    fn cancel(&mut self, reason: FinishReason);

    /// Consume the session into the final output (text decoded, wall-clock
    /// and pool stats finalized) plus the n-gram pool handle, returned so
    /// callers that loaned a shared-cache handle get it back.
    fn into_output(self: Box<Self>) -> (GenOutput, PoolHandle);
}

/// One raw engine step: either the tokens Algorithm 2/3/4 committed this
/// step (pre-trim), or a stop condition hit before any model call.
pub(crate) enum RawStep {
    Tokens(Vec<u32>),
    Stop(FinishReason),
}

/// The engine-specific half of a session: one untrimmed Algorithm-2 step.
/// Implementations keep the window/trajectory/cache state; budget and EOS
/// bookkeeping live in [`SessionCore`] so every engine shares one contract.
pub(crate) trait EngineStep {
    fn raw_step(&mut self, core: &mut SessionCore) -> Result<RawStep>;

    /// The session's n-gram pool handle (a detached handle for engines that
    /// keep no pool). Used to seal pool stats and return the handle.
    fn pool_mut(&mut self) -> &mut PoolHandle;
}

/// Shared per-session bookkeeping: params, stats, committed output, and the
/// incremental budget/EOS trimming contract (mirrors `engine::finish`).
pub(crate) struct SessionCore {
    pub params: GenParams,
    pub stats: DecodeStats,
    pub timer: Timer,
    pub out: Vec<u32>,
    pub finished: Option<FinishReason>,
}

impl SessionCore {
    pub fn new(prompt_tokens: usize, params: GenParams) -> SessionCore {
        SessionCore {
            out: Vec::with_capacity(params.max_new_tokens),
            params,
            stats: DecodeStats { prompt_tokens, ..Default::default() },
            timer: Timer::start(),
            finished: None,
        }
    }

    /// Fold one raw step commit into the session: record the accept length,
    /// trim to the remaining budget, cut at EOS, and adjust
    /// `stats.generated_tokens` for every dropped token (the `finish()`
    /// consistency contract). Returns the tokens actually added, and sets
    /// `finished` when the step ended the generation.
    pub fn commit_step(&mut self, raw: Vec<u32>) -> Vec<u32> {
        debug_assert!(self.finished.is_none());
        self.stats.record_accept(raw.len());
        if self.stats.decode_steps == 1 {
            self.stats.ttft = self.timer.elapsed();
        }
        let mut add = raw;
        let remaining = self.params.max_new_tokens.saturating_sub(self.out.len());
        if add.len() >= remaining {
            let dropped = add.len() - remaining;
            self.stats.generated_tokens =
                self.stats.generated_tokens.saturating_sub(dropped);
            add.truncate(remaining);
            self.finished = Some(FinishReason::Budget);
        }
        if self.params.stop_at_eos {
            if let Some(pos) = add.iter().position(|&t| t == EOS_ID) {
                self.stats.generated_tokens =
                    self.stats.generated_tokens.saturating_sub(add.len() - pos);
                add.truncate(pos);
                self.finished = Some(FinishReason::Eos);
            }
        }
        self.out.extend_from_slice(&add);
        add
    }
}

/// Generic session: an [`EngineStep`] plus the shared [`SessionCore`].
/// All five engines are `Session<TheirState>` under the hood.
pub(crate) struct Session<E: EngineStep> {
    core: SessionCore,
    eng: E,
    /// pool stats folded into `core.stats` (exactly once, at finish).
    sealed: bool,
}

impl<E: EngineStep> Session<E> {
    pub fn new(core: SessionCore, eng: E) -> Session<E> {
        Session { core, eng, sealed: false }
    }

    pub fn boxed<'rt>(core: SessionCore, eng: E) -> Box<dyn DecodeSession + 'rt>
    where
        E: 'rt,
    {
        Box::new(Session::new(core, eng))
    }

    fn seal(&mut self) {
        if !self.sealed {
            self.eng.pool_mut().fill_stats(&mut self.core.stats);
            self.sealed = true;
        }
    }
}

impl<E: EngineStep> DecodeSession for Session<E> {
    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.core.finished {
            self.seal();
            return Ok(StepOutcome::Finished { reason });
        }
        // budget exhausted before the step (e.g. max_new_tokens == 0)
        if self.core.out.len() >= self.core.params.max_new_tokens {
            self.core.finished = Some(FinishReason::Budget);
            self.seal();
            return Ok(StepOutcome::Finished { reason: FinishReason::Budget });
        }
        match self.eng.raw_step(&mut self.core) {
            Ok(RawStep::Tokens(raw)) => {
                let added = self.core.commit_step(raw);
                if self.core.finished.is_some() {
                    self.seal();
                }
                Ok(StepOutcome::Committed { tokens: added })
            }
            Ok(RawStep::Stop(reason)) => {
                self.core.finished = Some(reason);
                self.seal();
                Ok(StepOutcome::Finished { reason })
            }
            Err(e) => {
                self.core.finished = Some(FinishReason::Failed);
                self.seal();
                Err(e)
            }
        }
    }

    fn tokens(&self) -> &[u32] {
        &self.core.out
    }

    fn stats(&self) -> &DecodeStats {
        &self.core.stats
    }

    fn finished(&self) -> Option<FinishReason> {
        self.core.finished
    }

    fn cancel(&mut self, reason: FinishReason) {
        if self.core.finished.is_none() {
            self.core.finished = Some(reason);
            self.seal();
        }
    }

    fn into_output(self: Box<Self>) -> (GenOutput, PoolHandle) {
        let mut this = *self;
        this.seal();
        let wall = this.core.timer.elapsed();
        // `finish` is idempotent on an already-trimmed session: no overshoot
        // remains and EOS was cut, so it only decodes text + stamps wall.
        let out = finish(this.core.out, &this.core.params, this.core.stats, wall);
        let pool = std::mem::replace(this.eng.pool_mut(), PoolHandle::none());
        (out, pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SamplingParams;
    use crate::util::rng::Rng;

    /// Scripted engine: commits pre-baked token batches, then stops.
    struct Scripted {
        steps: Vec<Vec<u32>>,
        at: usize,
        pool: PoolHandle,
    }

    impl Scripted {
        fn new(steps: Vec<Vec<u32>>) -> Scripted {
            Scripted { steps, at: 0, pool: PoolHandle::none() }
        }
    }

    impl EngineStep for Scripted {
        fn raw_step(&mut self, _core: &mut SessionCore) -> Result<RawStep> {
            match self.steps.get(self.at) {
                Some(s) => {
                    self.at += 1;
                    Ok(RawStep::Tokens(s.clone()))
                }
                None => Ok(RawStep::Stop(FinishReason::CacheFull)),
            }
        }

        fn pool_mut(&mut self) -> &mut PoolHandle {
            &mut self.pool
        }
    }

    fn params(max: usize) -> GenParams {
        GenParams { max_new_tokens: max, ..Default::default() }
    }

    fn run_session(steps: Vec<Vec<u32>>, p: GenParams) -> (GenOutput, Vec<Vec<u32>>) {
        let mut sess = Session::new(SessionCore::new(1, p), Scripted::new(steps));
        let mut deltas = Vec::new();
        loop {
            match sess.step().unwrap() {
                StepOutcome::Committed { tokens } => deltas.push(tokens),
                StepOutcome::Finished { .. } => break,
            }
        }
        let (out, _) = Box::new(sess).into_output();
        (out, deltas)
    }

    #[test]
    fn budget_trims_overshoot_and_adjusts_stats() {
        let (out, _) = run_session(vec![vec![1, 2], vec![3, 4, 5]], params(3));
        assert_eq!(out.tokens, vec![1, 2, 3]);
        assert_eq!(out.stats.generated_tokens, 3);
        assert_eq!(out.stats.decode_steps, 2);
    }

    #[test]
    fn eos_trims_tail_and_adjusts_stats() {
        let (out, _) = run_session(vec![vec![1, 2], vec![3, EOS_ID, 9]], params(16));
        assert_eq!(out.tokens, vec![1, 2, 3]);
        // EOS + the token after it were dropped; stats must agree with the
        // output (the finish() consistency contract)
        assert_eq!(out.stats.generated_tokens, 3);
        assert_eq!(out.stats.decode_steps, 2);
    }

    #[test]
    fn eos_beyond_budget_reports_budget() {
        let mut sess = Session::new(
            SessionCore::new(1, params(2)),
            Scripted::new(vec![vec![1, 2, EOS_ID]]),
        );
        sess.step().unwrap();
        assert_eq!(sess.finished(), Some(FinishReason::Budget));
        assert_eq!(sess.tokens(), &[1, 2]);
    }

    #[test]
    fn deltas_concatenate_to_final_output() {
        let (out, deltas) =
            run_session(vec![vec![1], vec![2, 3], vec![4, EOS_ID]], params(16));
        let cat: Vec<u32> = deltas.into_iter().flatten().collect();
        assert_eq!(cat, out.tokens);
        assert_eq!(out.tokens, vec![1, 2, 3, 4]);
    }

    #[test]
    fn cache_full_stop_reported() {
        let mut sess =
            Session::new(SessionCore::new(1, params(16)), Scripted::new(vec![vec![7]]));
        assert_eq!(sess.step().unwrap(), StepOutcome::Committed { tokens: vec![7] });
        assert_eq!(
            sess.step().unwrap(),
            StepOutcome::Finished { reason: FinishReason::CacheFull }
        );
        assert_eq!(sess.finished(), Some(FinishReason::CacheFull));
    }

    #[test]
    fn cancel_stops_within_one_step() {
        let mut sess = Session::new(
            SessionCore::new(1, params(16)),
            Scripted::new(vec![vec![1], vec![2], vec![3]]),
        );
        sess.step().unwrap();
        sess.cancel(FinishReason::Cancelled);
        assert_eq!(
            sess.step().unwrap(),
            StepOutcome::Finished { reason: FinishReason::Cancelled }
        );
        let (out, _) = Box::new(sess).into_output();
        assert_eq!(out.tokens, vec![1]); // partial output is well-formed
        assert_eq!(out.stats.generated_tokens, 1);
    }

    #[test]
    fn ttft_recorded_on_first_commit() {
        let mut sess = Session::new(
            SessionCore::new(1, params(4)),
            Scripted::new(vec![vec![1], vec![2]]),
        );
        assert_eq!(sess.stats().ttft, std::time::Duration::ZERO);
        std::thread::sleep(std::time::Duration::from_millis(2));
        sess.step().unwrap();
        let ttft = sess.stats().ttft;
        assert!(ttft > std::time::Duration::ZERO);
        sess.step().unwrap();
        assert_eq!(sess.stats().ttft, ttft, "ttft must not move after step 1");
    }

    #[test]
    fn zero_budget_finishes_immediately() {
        let mut sess = Session::new(
            SessionCore::new(1, params(0)),
            Scripted::new(vec![vec![1]]),
        );
        assert_eq!(
            sess.step().unwrap(),
            StepOutcome::Finished { reason: FinishReason::Budget }
        );
        assert_eq!(sess.stats().decode_steps, 0);
    }

    #[test]
    fn prop_incremental_trim_matches_one_shot_finish() {
        // The streamed (incremental) trimming and the one-shot `finish()`
        // post-processing must agree on tokens AND stats for any step split.
        crate::util::prop::forall(
            200,
            41,
            |r: &mut Rng| {
                let total = r.range(1, 40);
                let toks: Vec<u32> =
                    (0..total).map(|_| if r.below(12) == 0 { EOS_ID } else { r.below(256) as u32 }).collect();
                // random split into step batches
                let mut steps: Vec<Vec<u32>> = Vec::new();
                let mut i = 0;
                while i < toks.len() {
                    let take = r.range(1, 5).min(toks.len() - i);
                    steps.push(toks[i..i + take].to_vec());
                    i += take;
                }
                let max = r.range(1, 48);
                (toks, steps, max)
            },
            |(toks, steps, max)| {
                let p = GenParams {
                    max_new_tokens: *max,
                    sampling: SamplingParams::greedy(),
                    stop_at_eos: true,
                    seed: 0,
                };
                // one-shot: replay the raw stream through finish(), stopping
                // where the old engine loops stopped (EOS or budget)
                let mut raw = Vec::new();
                let mut stats = DecodeStats::default();
                for s in steps.iter() {
                    raw.extend_from_slice(s);
                    stats.record_accept(s.len());
                    if s.contains(&EOS_ID) || raw.len() >= *max {
                        break;
                    }
                }
                let one =
                    finish(raw, &p, stats, std::time::Duration::from_millis(1));
                let (inc, deltas) = run_session(steps.clone(), p);
                if inc.tokens != one.tokens {
                    return Err(format!("tokens {:?} != {:?} (src {toks:?})",
                                       inc.tokens, one.tokens));
                }
                if inc.stats.generated_tokens != one.stats.generated_tokens {
                    return Err(format!(
                        "generated {} != {} (src {toks:?})",
                        inc.stats.generated_tokens, one.stats.generated_tokens));
                }
                if inc.stats.generated_tokens != inc.tokens.len() {
                    return Err("stats disagree with output length".into());
                }
                let cat: Vec<u32> = deltas.into_iter().flatten().collect();
                if cat != inc.tokens {
                    return Err("deltas do not concatenate to output".into());
                }
                Ok(())
            },
        );
    }
}
