//! Resumable decoding sessions — the step-structured view of Algorithm 2.
//!
//! Lookahead decoding commits a variable-length run of verified tokens per
//! step, but the original `Decoder::generate_with_pool` hid that behind a
//! blocking, all-at-once call. [`DecodeSession`] exposes the step structure:
//! `Decoder::begin` opens a session that owns its KV cache, n-gram pool
//! handle, and per-step stats; each [`DecodeSession::step`] advances one
//! fused model call and reports the tokens it committed. The serving layer
//! builds streaming, cancellation, and time-sliced multi-request
//! interleaving on top; the one-shot `generate()`/`generate_with_pool()`
//! are now thin loops over `step()` (byte-exact with the old behavior).
//!
//! Internals: engines implement the private [`EngineStep`] trait (one raw
//! Algorithm-2 step, no budget/EOS bookkeeping); the generic [`Session`]
//! wrapper folds raw commits through [`SessionCore::commit_step`], which
//! applies the same budget/EOS trimming contract as `engine::finish` —
//! incrementally, so streamed deltas concatenate to exactly the one-shot
//! output.

use anyhow::{anyhow, bail, Result};

use crate::engine::{finish, GenOutput, GenParams};
use crate::kv::{EngineState, SessionSnapshot};
use crate::metrics::{DecodeStats, Timer};
use crate::ngram::PoolHandle;
use crate::runtime::{Cache, CacheOverflow, HostKv, ModelRuntime, StepOut};
use crate::tokenizer::EOS_ID;

/// Why a session stopped producing tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// EOS was committed (and trimmed from the output).
    Eos,
    /// `max_new_tokens` reached.
    Budget,
    /// The KV cache cannot hold another step.
    CacheFull,
    /// The caller cancelled the session.
    Cancelled,
    /// The request's serving deadline expired.
    Deadline,
    /// A step returned an error; the session is poisoned.
    Failed,
    /// The session was suspended ([`DecodeSession::suspend`]): its state
    /// lives on in a [`SessionSnapshot`] and the resumed session reports
    /// the true finish reason — a suspended session never emits a final
    /// record of its own.
    Suspended,
}

impl FinishReason {
    /// Stable wire-format tag (the `finish` field of the final record).
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Budget => "budget",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Deadline => "deadline",
            FinishReason::Failed => "failed",
            FinishReason::Suspended => "suspended",
        }
    }
}

/// Result of one [`DecodeSession::step`] call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepOutcome {
    /// The step committed these tokens to the output (already trimmed to the
    /// generation budget and cut at EOS — concatenating every `Committed`
    /// payload reproduces the one-shot output byte-exactly). May be empty
    /// when the step's tokens were entirely trimmed (e.g. EOS first).
    Committed { tokens: Vec<u32> },
    /// The session is finished; no tokens were committed by this call.
    Finished { reason: FinishReason },
}

/// A resumable decoding session over one request.
///
/// Sessions borrow the [`crate::runtime::ModelRuntime`] they were opened on
/// and own everything else: KV cache position, n-gram pool handle, RNG,
/// window/trajectory state, and per-step [`DecodeStats`]. Drive with
/// [`step`](DecodeSession::step) until [`finished`](DecodeSession::finished)
/// is `Some`, then call [`into_output`](DecodeSession::into_output) for the
/// final record.
pub trait DecodeSession {
    /// Advance one decode step. After the session finishes, further calls
    /// return `Finished` with the same reason and do no work.
    fn step(&mut self) -> Result<StepOutcome>;

    /// Tokens committed so far (already budget/EOS-trimmed).
    fn tokens(&self) -> &[u32];

    /// Per-step statistics so far. Pool counters are folded in when the
    /// session finishes (they are exact in `into_output`'s stats).
    fn stats(&self) -> &DecodeStats;

    /// `Some(reason)` once the session will produce no more tokens.
    fn finished(&self) -> Option<FinishReason>;

    /// Stop the session before its natural end (`FinishReason::Cancelled`
    /// or `FinishReason::Deadline`). Tokens committed so far remain valid;
    /// the next `step()` reports `Finished`. No-op on a finished session.
    fn cancel(&mut self, reason: FinishReason);

    /// Consume the session into the final output (text decoded, wall-clock
    /// and pool stats finalized) plus the n-gram pool handle, returned so
    /// callers that loaned a shared-cache handle get it back.
    fn into_output(self: Box<Self>) -> (GenOutput, PoolHandle);

    /// Whether [`DecodeSession::suspend`] can capture this session: the
    /// engine supports state snapshots AND the runtime has a `cache_io`
    /// executable AND the session is still live. The worker's park/revive
    /// scheduler only ever parks suspendable sessions.
    fn suspendable(&self) -> bool {
        false
    }

    /// Capture the full session state into a host-resident
    /// [`SessionSnapshot`] and release the device cache. The session
    /// finishes with [`FinishReason::Suspended`] (no final record); the
    /// snapshot resumes via [`SessionSnapshot::resume`] — in-process, after
    /// a disk round trip, or on another worker — byte-identically. Errors
    /// poison the session (`Failed`).
    fn suspend(&mut self) -> Result<SessionSnapshot> {
        Err(anyhow!("this session does not support suspend/resume"))
    }

    /// Batched-decode extension ([`BatchStep`]): `Some` when this session's
    /// engine can split a step into plan / fused-call / complete phases so
    /// a group of compatible sessions shares one model call per round.
    /// `None` (the default) means the session only supports per-session
    /// `step()` calls — the serving layer falls back accordingly.
    fn batch(&mut self) -> Option<&mut dyn BatchStep> {
        None
    }

    /// Shared-borrow view of the [`BatchStep`] extension (used to gather
    /// caches and token windows from every group member simultaneously
    /// while the fused call is assembled).
    fn batch_ref(&self) -> Option<&dyn BatchStep> {
        None
    }
}

/// Whether a session joins the round's fused decode call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPlan {
    /// The session assembled its token window ([`BatchStep::window`]) and
    /// MUST receive [`BatchStep::complete`] with its slot's output.
    Join,
    /// The session cannot join this round (finished, budget exhausted, or a
    /// stop condition like a full cache): drive it with
    /// [`DecodeSession::step`] instead, which resolves the stop itself.
    Solo,
}

/// The batched-decode extension of [`DecodeSession`]: a group of sessions
/// with equal [`group_key`](BatchStep::group_key)s submits per-step token
/// windows, one fused `decode_batched` / `decode_generic_batched` call
/// serves all of them, and each session folds its slot's [`StepOut`] back
/// through the unchanged `commit_step` budget/EOS-trim semantics — so
/// batched and sequential execution commit byte-identical token streams
/// (pinned by `rust/tests/batched_equivalence.rs`).
///
/// Call protocol per round: `plan()` every member; gather `window()` /
/// `cache()` / `mask()` from the `Join`ers; run the fused call; `complete()`
/// each joiner with its slot output. [`step_group`] drives this protocol.
pub trait BatchStep {
    /// Grouping key: equal keys guarantee the same fused-call shape (same
    /// base executable AND the same mask/relpos layout).
    fn group_key(&self) -> String;

    /// The per-session decode executable the fused call must emulate.
    fn exe_name(&self) -> &str;

    /// Plan the next step (may assemble the token window and consult the
    /// n-gram pool). On error the session is poisoned (`Failed`).
    fn plan(&mut self) -> Result<BatchPlan>;

    /// The step-input token window assembled by the last `Join` plan.
    fn window(&self) -> &[u32];

    /// The session's device cache for the fused call.
    fn cache(&self) -> &Cache;

    /// Generic-path layout, shared across the group (None = linear or
    /// specialized executable; the layout is baked in).
    fn mask(&self) -> Option<(&[i32], &[u8])>;

    /// Fold the fused call's slot output into the session: verification,
    /// per-session commit, window update, and the budget/EOS trim.
    fn complete(&mut self, out: StepOut) -> Result<StepOutcome>;
}

/// One raw engine step: either the tokens Algorithm 2/3/4 committed this
/// step (pre-trim), or a stop condition hit before any model call.
pub(crate) enum RawStep {
    Tokens(Vec<u32>),
    Stop(FinishReason),
}

/// What an engine hands over when its session suspends: the serializable
/// engine state, the host image of its device cache (the device buffer is
/// freed), the draft model's cache image for two-model engines
/// (spec-decode; `None` elsewhere), and the live pool handle.
pub(crate) struct EngineSuspend {
    pub model: String,
    pub state: EngineState,
    pub kv: HostKv,
    pub draft_kv: Option<HostKv>,
    pub pool: PoolHandle,
}

/// Plan result of a batchable engine's step front half.
pub(crate) enum StepPlan {
    /// Token window assembled ([`EngineStep::window`]); run the model call,
    /// then [`EngineStep::finish_step`].
    Run,
    /// A stop condition fired before any model call.
    Stop(FinishReason),
}

/// The engine-specific half of a session: one untrimmed Algorithm-2 step.
/// Implementations keep the window/trajectory/cache state; budget and EOS
/// bookkeeping live in [`SessionCore`] so every engine shares one contract.
///
/// Batchable engines (autoregressive, lookahead) additionally split
/// `raw_step` into `plan_step` (assemble the token window, no model call)
/// and `finish_step` (fold one [`StepOut`] back: verify, commit, window
/// update) and implement `raw_step` as plan → decode → finish, so the
/// per-session and fused paths execute the identical sequence of
/// operations. The remaining hooks expose the fused call's inputs.
pub(crate) trait EngineStep {
    fn raw_step(&mut self, core: &mut SessionCore) -> Result<RawStep>;

    /// The session's n-gram pool handle (a detached handle for engines that
    /// keep no pool). Used to seal pool stats and return the handle.
    fn pool_mut(&mut self) -> &mut PoolHandle;

    // --- suspend/resume hooks (defaults: not suspendable) -------------

    /// Whether this engine can capture its state (and its runtime can
    /// serialize the cache).
    fn suspendable(&self) -> bool {
        false
    }

    /// Capture engine state + download the KV cache; on success the device
    /// cache is freed and the engine must not step again.
    fn suspend_engine(&mut self) -> Result<EngineSuspend> {
        Err(anyhow!("engine does not support suspend"))
    }

    // --- batched-decode hooks (defaults: not batchable) ---------------

    /// Whether this engine supports the plan/finish split at all.
    fn batchable(&self) -> bool {
        false
    }

    fn plan_step(&mut self, _core: &mut SessionCore) -> Result<StepPlan> {
        Ok(StepPlan::Stop(FinishReason::Failed))
    }

    fn finish_step(&mut self, _core: &mut SessionCore, _out: StepOut) -> Result<RawStep> {
        Err(anyhow!("engine does not implement batched steps"))
    }

    /// The token window assembled by the last `plan_step` → `Run`.
    fn window(&self) -> &[u32] {
        &[]
    }

    /// Base decode executable name for the fused call.
    fn batch_exe(&self) -> &str {
        ""
    }

    /// Fused-group compatibility key (must pin executable + layout).
    fn group_key(&self) -> String {
        String::new()
    }

    fn batch_cache(&self) -> Option<&Cache> {
        None
    }

    fn batch_mask(&self) -> Option<(&[i32], &[u8])> {
        None
    }
}

/// Shared per-session bookkeeping: params, stats, committed output, and the
/// incremental budget/EOS trimming contract (mirrors `engine::finish`).
pub(crate) struct SessionCore {
    pub params: GenParams,
    pub stats: DecodeStats,
    pub timer: Timer,
    /// decode wall-clock accumulated before a suspend: `stats.wall` is
    /// stamped as `wall_offset + timer.elapsed()`, so parked time never
    /// counts as decode time.
    pub wall_offset: std::time::Duration,
    pub out: Vec<u32>,
    pub finished: Option<FinishReason>,
}

impl SessionCore {
    pub fn new(prompt_tokens: usize, params: GenParams) -> SessionCore {
        SessionCore {
            out: Vec::with_capacity(params.max_new_tokens),
            params,
            stats: DecodeStats { prompt_tokens, ..Default::default() },
            timer: Timer::start(),
            wall_offset: std::time::Duration::ZERO,
            finished: None,
        }
    }

    /// Rebuild the core of a resumed session from its snapshot parts.
    pub fn resumed(params: GenParams, stats: DecodeStats, out: Vec<u32>,
                   wall_offset: std::time::Duration) -> SessionCore {
        SessionCore {
            params,
            stats,
            timer: Timer::start(),
            wall_offset,
            out,
            finished: None,
        }
    }

    /// Fold one raw step commit into the session: record the accept length,
    /// trim to the remaining budget, cut at EOS, and adjust
    /// `stats.generated_tokens` for every dropped token (the `finish()`
    /// consistency contract). Returns the tokens actually added, and sets
    /// `finished` when the step ended the generation.
    pub fn commit_step(&mut self, raw: Vec<u32>) -> Vec<u32> {
        debug_assert!(self.finished.is_none());
        self.stats.record_accept(raw.len());
        if self.stats.decode_steps == 1 {
            // include time accumulated before a suspend (a session parked
            // before its first commit must not report a resume-relative ttft)
            self.stats.ttft = self.wall_offset + self.timer.elapsed();
        }
        let mut add = raw;
        let remaining = self.params.max_new_tokens.saturating_sub(self.out.len());
        if add.len() >= remaining {
            let dropped = add.len() - remaining;
            self.stats.generated_tokens =
                self.stats.generated_tokens.saturating_sub(dropped);
            add.truncate(remaining);
            self.finished = Some(FinishReason::Budget);
        }
        if self.params.stop_at_eos {
            if let Some(pos) = add.iter().position(|&t| t == EOS_ID) {
                self.stats.generated_tokens =
                    self.stats.generated_tokens.saturating_sub(add.len() - pos);
                add.truncate(pos);
                self.finished = Some(FinishReason::Eos);
            }
        }
        self.out.extend_from_slice(&add);
        add
    }
}

/// Generic session: an [`EngineStep`] plus the shared [`SessionCore`].
/// All five engines are `Session<TheirState>` under the hood.
pub(crate) struct Session<E: EngineStep> {
    core: SessionCore,
    eng: E,
    /// pool stats folded into `core.stats` (exactly once, at finish).
    sealed: bool,
}

impl<E: EngineStep> Session<E> {
    pub fn new(core: SessionCore, eng: E) -> Session<E> {
        Session { core, eng, sealed: false }
    }

    pub fn boxed<'rt>(core: SessionCore, eng: E) -> Box<dyn DecodeSession + 'rt>
    where
        E: 'rt,
    {
        Box::new(Session::new(core, eng))
    }

    fn seal(&mut self) {
        if !self.sealed {
            self.eng.pool_mut().fill_stats(&mut self.core.stats);
            self.sealed = true;
        }
    }

    /// Shared error path for step()/complete(): a typed
    /// [`CacheOverflow`] from `commit` finishes the session gracefully
    /// (`CacheFull` — the tokens committed so far stand); anything else
    /// poisons it (`Failed`).
    fn step_error(&mut self, e: anyhow::Error) -> Result<StepOutcome> {
        if e.downcast_ref::<CacheOverflow>().is_some() {
            self.core.finished = Some(FinishReason::CacheFull);
            self.seal();
            return Ok(StepOutcome::Finished { reason: FinishReason::CacheFull });
        }
        self.core.finished = Some(FinishReason::Failed);
        self.seal();
        Err(e)
    }
}

impl<E: EngineStep> DecodeSession for Session<E> {
    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.core.finished {
            self.seal();
            return Ok(StepOutcome::Finished { reason });
        }
        // budget exhausted before the step (e.g. max_new_tokens == 0)
        if self.core.out.len() >= self.core.params.max_new_tokens {
            self.core.finished = Some(FinishReason::Budget);
            self.seal();
            return Ok(StepOutcome::Finished { reason: FinishReason::Budget });
        }
        match self.eng.raw_step(&mut self.core) {
            Ok(RawStep::Tokens(raw)) => {
                let added = self.core.commit_step(raw);
                if self.core.finished.is_some() {
                    self.seal();
                }
                Ok(StepOutcome::Committed { tokens: added })
            }
            Ok(RawStep::Stop(reason)) => {
                self.core.finished = Some(reason);
                self.seal();
                Ok(StepOutcome::Finished { reason })
            }
            Err(e) => self.step_error(e),
        }
    }

    fn tokens(&self) -> &[u32] {
        &self.core.out
    }

    fn stats(&self) -> &DecodeStats {
        &self.core.stats
    }

    fn finished(&self) -> Option<FinishReason> {
        self.core.finished
    }

    fn cancel(&mut self, reason: FinishReason) {
        if self.core.finished.is_none() {
            self.core.finished = Some(reason);
            self.seal();
        }
    }

    fn suspendable(&self) -> bool {
        self.core.finished.is_none() && self.eng.suspendable()
    }

    fn suspend(&mut self) -> Result<SessionSnapshot> {
        if let Some(reason) = self.core.finished {
            bail!("cannot suspend a session finished with {reason:?}");
        }
        if !self.eng.suspendable() {
            bail!("engine does not support suspend/resume");
        }
        match self.eng.suspend_engine() {
            Ok(es) => {
                self.core.finished = Some(FinishReason::Suspended);
                // the pool handle moved into the snapshot: stats seal
                // happens when the RESUMED session finishes, not here
                self.sealed = true;
                Ok(SessionSnapshot {
                    model: es.model,
                    engine: es.state,
                    kv: es.kv,
                    draft_kv: es.draft_kv,
                    params: self.core.params.clone(),
                    out: std::mem::take(&mut self.core.out),
                    stats: self.core.stats.clone(),
                    wall_offset: self.core.wall_offset + self.core.timer.elapsed(),
                    pool: es.pool,
                })
            }
            Err(e) => {
                self.core.finished = Some(FinishReason::Failed);
                self.seal();
                Err(e)
            }
        }
    }

    fn into_output(self: Box<Self>) -> (GenOutput, PoolHandle) {
        let mut this = *self;
        this.seal();
        let wall = this.core.wall_offset + this.core.timer.elapsed();
        // `finish` is idempotent on an already-trimmed session: no overshoot
        // remains and EOS was cut, so it only decodes text + stamps wall.
        let out = finish(this.core.out, &this.core.params, this.core.stats, wall);
        let pool = std::mem::replace(this.eng.pool_mut(), PoolHandle::none());
        (out, pool)
    }

    fn batch(&mut self) -> Option<&mut dyn BatchStep> {
        if self.eng.batchable() {
            Some(self)
        } else {
            None
        }
    }

    fn batch_ref(&self) -> Option<&dyn BatchStep> {
        if self.eng.batchable() {
            Some(self)
        } else {
            None
        }
    }
}

impl<E: EngineStep> BatchStep for Session<E> {
    fn group_key(&self) -> String {
        self.eng.group_key()
    }

    fn exe_name(&self) -> &str {
        self.eng.batch_exe()
    }

    fn plan(&mut self) -> Result<BatchPlan> {
        // mirror step()'s preamble: finished sessions and pre-exhausted
        // budgets resolve through step() so the finish bookkeeping stays in
        // exactly one place
        if self.core.finished.is_some()
            || self.core.out.len() >= self.core.params.max_new_tokens
        {
            return Ok(BatchPlan::Solo);
        }
        match self.eng.plan_step(&mut self.core) {
            // a stop condition (e.g. cache full) is stateless to plan:
            // step() re-plans and reports the Finished outcome itself
            Ok(StepPlan::Run) => Ok(BatchPlan::Join),
            Ok(StepPlan::Stop(_)) => Ok(BatchPlan::Solo),
            Err(e) => {
                self.core.finished = Some(FinishReason::Failed);
                self.seal();
                Err(e)
            }
        }
    }

    fn window(&self) -> &[u32] {
        self.eng.window()
    }

    fn cache(&self) -> &Cache {
        self.eng.batch_cache().expect("batchable engine must expose its cache")
    }

    fn mask(&self) -> Option<(&[i32], &[u8])> {
        self.eng.batch_mask()
    }

    fn complete(&mut self, out: StepOut) -> Result<StepOutcome> {
        if let Some(reason) = self.core.finished {
            self.seal();
            return Ok(StepOutcome::Finished { reason });
        }
        match self.eng.finish_step(&mut self.core, out) {
            Ok(RawStep::Tokens(raw)) => {
                let added = self.core.commit_step(raw);
                if self.core.finished.is_some() {
                    self.seal();
                }
                Ok(StepOutcome::Committed { tokens: added })
            }
            Ok(RawStep::Stop(reason)) => {
                self.core.finished = Some(reason);
                self.seal();
                Ok(StepOutcome::Finished { reason })
            }
            Err(e) => self.step_error(e),
        }
    }
}

/// Result of one fused round over a session group.
pub struct GroupOutcome {
    /// Per-session step outcome, in group order (same semantics as
    /// [`DecodeSession::step`]: an `Err` poisons that session only).
    pub outcomes: Vec<Result<StepOutcome>>,
    /// Sizes of the fused decode calls actually issued (for the serving
    /// metrics: one entry per `decode_batched` launch, always >= 2; solo
    /// fallbacks, singleton chunks, and sessions that resolved without a
    /// model call do not appear).
    pub fused: Vec<usize>,
}

/// Drive one decode step for every session in `group`, fusing compatible
/// sessions into batched model calls.
///
/// Protocol: every session able to join ([`BatchStep::plan`] → `Join`)
/// contributes its token window and cache to a fused
/// [`ModelRuntime::decode_batched`] / `decode_generic_batched` call; runs
/// of equal [`BatchStep::group_key`] are chunked to the batched
/// executable's capacity. Sessions that cannot join — unsupported engine,
/// finished, stop condition — are driven with plain
/// [`DecodeSession::step`]. When the model has no batched executable for a
/// group's base, each planned session runs its own per-session decode and
/// completes normally (the fallback path: identical bytes, no fusion).
///
/// `rt` must be the runtime every session in `group` was opened on.
pub fn step_group(rt: &ModelRuntime, group: &mut [&mut (dyn DecodeSession + '_)])
                  -> GroupOutcome {
    let n = group.len();
    let mut outcomes: Vec<Option<Result<StepOutcome>>> = (0..n).map(|_| None).collect();
    let mut fused: Vec<usize> = Vec::new();

    // -- plan phase: who joins this round's fused call? -----------------
    let mut joined: Vec<(String, usize)> = Vec::new(); // (group key, index)
    for i in 0..n {
        let plan = match group[i].batch() {
            Some(b) => match b.plan() {
                Ok(p) => p,
                Err(e) => {
                    outcomes[i] = Some(Err(e));
                    continue;
                }
            },
            None => BatchPlan::Solo,
        };
        match plan {
            BatchPlan::Join => {
                let key = group[i].batch_ref().map(|b| b.group_key()).unwrap_or_default();
                joined.push((key, i));
            }
            BatchPlan::Solo => outcomes[i] = Some(group[i].step()),
        }
    }
    joined.sort_by(|a, b| a.0.cmp(&b.0)); // stable: group order kept per key

    // -- fused phase: one batched call per (key, chunk) ------------------
    let mut at = 0;
    while at < joined.len() {
        let mut end = at + 1;
        while end < joined.len() && joined[end].0 == joined[at].0 {
            end += 1;
        }
        let exe = group[joined[at].1]
            .batch_ref()
            .map(|b| b.exe_name().to_string())
            .unwrap_or_default();
        let cap = rt.max_batch(&exe);
        let mut lo = at;
        while lo < end {
            let hi = match cap {
                Some(c) => end.min(lo + c.max(1)),
                None => lo + 1, // no batched executable: per-session decode
            };
            let chunk: Vec<usize> = joined[lo..hi].iter().map(|j| j.1).collect();
            run_chunk(rt, group, &chunk, &exe, cap.is_some(), &mut outcomes, &mut fused);
            lo = hi;
        }
        at = end;
    }

    GroupOutcome {
        outcomes: outcomes
            .into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow!("session skipped by step_group"))))
            .collect(),
        fused,
    }
}

/// One fused (or per-session fallback) decode call over `chunk`, completing
/// every member with its slot output.
fn run_chunk(rt: &ModelRuntime, group: &mut [&mut (dyn DecodeSession + '_)],
             chunk: &[usize], exe: &str, have_batched: bool,
             outcomes: &mut [Option<Result<StepOutcome>>], fused: &mut Vec<usize>) {
    // Solo path — singleton chunk (group drained to one live session: a
    // padded B-slot fused launch would pay up to B× the decode cost for
    // identical bytes) or no batched executable. Runs the base executable
    // once per member with no re-planning, so pool accounting, committed
    // bytes, AND error isolation stay identical to the sequential path: a
    // failing decode poisons only its own session.
    if !(have_batched && chunk.len() > 1) {
        for &i in chunk {
            let res = {
                let b = group[i].batch_ref().expect("joined session lost BatchStep");
                match b.mask() {
                    Some((relpos, m)) => {
                        rt.decode_generic(exe, b.cache(), b.window(), relpos, m)
                    }
                    None => rt.decode(exe, b.cache(), b.window()),
                }
            };
            outcomes[i] = Some(match res {
                Ok(out) => group[i].batch().expect("joined session").complete(out),
                Err(e) => {
                    group[i].cancel(FinishReason::Failed);
                    Err(e)
                }
            });
        }
        return;
    }

    // Fused path: gather every member's inputs through shared borrows, one
    // batched launch serves the whole chunk.
    fused.push(chunk.len());
    let step_outs: Result<Vec<StepOut>> = {
        let members: Vec<&dyn BatchStep> = group
            .iter()
            .enumerate()
            .filter(|(i, _)| chunk.contains(i))
            .map(|(_, s)| (**s).batch_ref().expect("joined session lost BatchStep"))
            .collect();
        let caches: Vec<&Cache> = members.iter().map(|b| b.cache()).collect();
        let windows: Vec<&[u32]> = members.iter().map(|b| b.window()).collect();
        match members[0].mask() {
            Some((relpos, m)) => {
                rt.decode_generic_batched(exe, &caches, &windows, relpos, m)
            }
            None => rt.decode_batched(exe, &caches, &windows),
        }
    };
    match step_outs {
        Ok(outs) => {
            for (&i, out) in chunk.iter().zip(outs) {
                outcomes[i] = Some(group[i].batch().expect("joined session").complete(out));
            }
        }
        Err(e) => {
            // the single fused launch failed for everyone it served: poison
            // every member (same contract as a failed per-session step)
            let msg = format!("batched decode failed: {e}");
            for &i in chunk {
                group[i].cancel(FinishReason::Failed);
                outcomes[i] = Some(Err(anyhow!("{msg}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SamplingParams;
    use crate::util::rng::Rng;

    /// Scripted engine: commits pre-baked token batches, then stops.
    struct Scripted {
        steps: Vec<Vec<u32>>,
        at: usize,
        pool: PoolHandle,
    }

    impl Scripted {
        fn new(steps: Vec<Vec<u32>>) -> Scripted {
            Scripted { steps, at: 0, pool: PoolHandle::none() }
        }
    }

    impl EngineStep for Scripted {
        fn raw_step(&mut self, _core: &mut SessionCore) -> Result<RawStep> {
            match self.steps.get(self.at) {
                Some(s) => {
                    self.at += 1;
                    Ok(RawStep::Tokens(s.clone()))
                }
                None => Ok(RawStep::Stop(FinishReason::CacheFull)),
            }
        }

        fn pool_mut(&mut self) -> &mut PoolHandle {
            &mut self.pool
        }
    }

    fn params(max: usize) -> GenParams {
        GenParams { max_new_tokens: max, ..Default::default() }
    }

    fn run_session(steps: Vec<Vec<u32>>, p: GenParams) -> (GenOutput, Vec<Vec<u32>>) {
        let mut sess = Session::new(SessionCore::new(1, p), Scripted::new(steps));
        let mut deltas = Vec::new();
        loop {
            match sess.step().unwrap() {
                StepOutcome::Committed { tokens } => deltas.push(tokens),
                StepOutcome::Finished { .. } => break,
            }
        }
        let (out, _) = Box::new(sess).into_output();
        (out, deltas)
    }

    #[test]
    fn budget_trims_overshoot_and_adjusts_stats() {
        let (out, _) = run_session(vec![vec![1, 2], vec![3, 4, 5]], params(3));
        assert_eq!(out.tokens, vec![1, 2, 3]);
        assert_eq!(out.stats.generated_tokens, 3);
        assert_eq!(out.stats.decode_steps, 2);
    }

    #[test]
    fn eos_trims_tail_and_adjusts_stats() {
        let (out, _) = run_session(vec![vec![1, 2], vec![3, EOS_ID, 9]], params(16));
        assert_eq!(out.tokens, vec![1, 2, 3]);
        // EOS + the token after it were dropped; stats must agree with the
        // output (the finish() consistency contract)
        assert_eq!(out.stats.generated_tokens, 3);
        assert_eq!(out.stats.decode_steps, 2);
    }

    #[test]
    fn eos_beyond_budget_reports_budget() {
        let mut sess = Session::new(
            SessionCore::new(1, params(2)),
            Scripted::new(vec![vec![1, 2, EOS_ID]]),
        );
        sess.step().unwrap();
        assert_eq!(sess.finished(), Some(FinishReason::Budget));
        assert_eq!(sess.tokens(), &[1, 2]);
    }

    #[test]
    fn deltas_concatenate_to_final_output() {
        let (out, deltas) =
            run_session(vec![vec![1], vec![2, 3], vec![4, EOS_ID]], params(16));
        let cat: Vec<u32> = deltas.into_iter().flatten().collect();
        assert_eq!(cat, out.tokens);
        assert_eq!(out.tokens, vec![1, 2, 3, 4]);
    }

    #[test]
    fn cache_full_stop_reported() {
        let mut sess =
            Session::new(SessionCore::new(1, params(16)), Scripted::new(vec![vec![7]]));
        assert_eq!(sess.step().unwrap(), StepOutcome::Committed { tokens: vec![7] });
        assert_eq!(
            sess.step().unwrap(),
            StepOutcome::Finished { reason: FinishReason::CacheFull }
        );
        assert_eq!(sess.finished(), Some(FinishReason::CacheFull));
    }

    #[test]
    fn cancel_stops_within_one_step() {
        let mut sess = Session::new(
            SessionCore::new(1, params(16)),
            Scripted::new(vec![vec![1], vec![2], vec![3]]),
        );
        sess.step().unwrap();
        sess.cancel(FinishReason::Cancelled);
        assert_eq!(
            sess.step().unwrap(),
            StepOutcome::Finished { reason: FinishReason::Cancelled }
        );
        let (out, _) = Box::new(sess).into_output();
        assert_eq!(out.tokens, vec![1]); // partial output is well-formed
        assert_eq!(out.stats.generated_tokens, 1);
    }

    #[test]
    fn ttft_recorded_on_first_commit() {
        let mut sess = Session::new(
            SessionCore::new(1, params(4)),
            Scripted::new(vec![vec![1], vec![2]]),
        );
        assert_eq!(sess.stats().ttft, std::time::Duration::ZERO);
        crate::util::sync::nap(std::time::Duration::from_millis(2));
        sess.step().unwrap();
        let ttft = sess.stats().ttft;
        assert!(ttft > std::time::Duration::ZERO);
        sess.step().unwrap();
        assert_eq!(sess.stats().ttft, ttft, "ttft must not move after step 1");
    }

    #[test]
    fn zero_budget_finishes_immediately() {
        let mut sess = Session::new(
            SessionCore::new(1, params(0)),
            Scripted::new(vec![vec![1]]),
        );
        assert_eq!(
            sess.step().unwrap(),
            StepOutcome::Finished { reason: FinishReason::Budget }
        );
        assert_eq!(sess.stats().decode_steps, 0);
    }

    #[test]
    fn non_batchable_engine_has_no_batch_view() {
        let mut sess = Session::new(
            SessionCore::new(1, params(4)),
            Scripted::new(vec![vec![1]]),
        );
        assert!(sess.batch().is_none());
        assert!(sess.batch_ref().is_none());
        // and step() still works as before
        assert_eq!(sess.step().unwrap(), StepOutcome::Committed { tokens: vec![1] });
    }

    #[test]
    fn step_group_falls_back_to_solo_for_non_batchable_sessions() {
        // without a runtime-capable engine the group driver must still
        // produce one outcome per session, all via the solo path
        let dir = crate::runtime::sim::ensure_sim_artifacts().unwrap();
        let manifest = crate::runtime::Manifest::load(&dir).unwrap();
        let client = crate::runtime::cpu_client().unwrap();
        let rt = crate::runtime::ModelRuntime::load(&client, &manifest, "tiny").unwrap();

        let mut a = Session::new(SessionCore::new(1, params(4)),
                                 Scripted::new(vec![vec![1], vec![2]]));
        let mut b = Session::new(SessionCore::new(1, params(4)),
                                 Scripted::new(vec![vec![7]]));
        let mut group: Vec<&mut (dyn DecodeSession + '_)> = vec![&mut a, &mut b];
        let out = step_group(&rt, &mut group);
        assert!(out.fused.is_empty(), "scripted engines must not fuse");
        assert_eq!(out.outcomes.len(), 2);
        assert_eq!(*out.outcomes[0].as_ref().unwrap(),
                   StepOutcome::Committed { tokens: vec![1] });
        assert_eq!(*out.outcomes[1].as_ref().unwrap(),
                   StepOutcome::Committed { tokens: vec![7] });
    }

    /// Engine whose every step fails with the given error constructor.
    struct Erroring<F: Fn() -> anyhow::Error> {
        mk: F,
        pool: PoolHandle,
    }

    impl<F: Fn() -> anyhow::Error> EngineStep for Erroring<F> {
        fn raw_step(&mut self, _core: &mut SessionCore) -> Result<RawStep> {
            Err((self.mk)())
        }

        fn pool_mut(&mut self) -> &mut PoolHandle {
            &mut self.pool
        }
    }

    #[test]
    fn commit_overflow_finishes_with_cache_full() {
        // the typed CacheOverflow from ModelRuntime::commit must finish the
        // session gracefully instead of poisoning it
        let mk = || anyhow::Error::new(CacheOverflow { len: 250, add: 10, capacity: 255 });
        let mut sess = Session::new(
            SessionCore::new(1, params(8)),
            Erroring { mk, pool: PoolHandle::none() },
        );
        assert_eq!(
            sess.step().unwrap(),
            StepOutcome::Finished { reason: FinishReason::CacheFull }
        );
        assert_eq!(sess.finished(), Some(FinishReason::CacheFull));
        // the finished session yields a well-formed (empty) output
        let (out, _) = Box::new(sess).into_output();
        assert_eq!(out.tokens, Vec::<u32>::new());
    }

    #[test]
    fn non_overflow_errors_still_poison() {
        let mk = || anyhow!("device fell over");
        let mut sess = Session::new(
            SessionCore::new(1, params(8)),
            Erroring { mk, pool: PoolHandle::none() },
        );
        assert!(sess.step().is_err());
        assert_eq!(sess.finished(), Some(FinishReason::Failed));
    }

    #[test]
    fn suspend_rejected_for_unsupported_engine() {
        let mut sess = Session::new(
            SessionCore::new(1, params(4)),
            Scripted::new(vec![vec![1], vec![2]]),
        );
        assert!(!sess.suspendable());
        assert!(sess.suspend().is_err());
        // a rejected suspend leaves the session fully usable
        assert_eq!(sess.step().unwrap(), StepOutcome::Committed { tokens: vec![1] });
        assert_eq!(sess.finished(), None);
    }

    #[test]
    fn prop_incremental_trim_matches_one_shot_finish() {
        // The streamed (incremental) trimming and the one-shot `finish()`
        // post-processing must agree on tokens AND stats for any step split.
        crate::util::prop::forall(
            200,
            41,
            |r: &mut Rng| {
                let total = r.range(1, 40);
                let toks: Vec<u32> =
                    (0..total).map(|_| if r.below(12) == 0 { EOS_ID } else { r.below(256) as u32 }).collect();
                // random split into step batches
                let mut steps: Vec<Vec<u32>> = Vec::new();
                let mut i = 0;
                while i < toks.len() {
                    let take = r.range(1, 5).min(toks.len() - i);
                    steps.push(toks[i..i + take].to_vec());
                    i += take;
                }
                let max = r.range(1, 48);
                (toks, steps, max)
            },
            |(toks, steps, max)| {
                let p = GenParams {
                    max_new_tokens: *max,
                    sampling: SamplingParams::greedy(),
                    stop_at_eos: true,
                    seed: 0,
                };
                // one-shot: replay the raw stream through finish(), stopping
                // where the old engine loops stopped (EOS or budget)
                let mut raw = Vec::new();
                let mut stats = DecodeStats::default();
                for s in steps.iter() {
                    raw.extend_from_slice(s);
                    stats.record_accept(s.len());
                    if s.contains(&EOS_ID) || raw.len() >= *max {
                        break;
                    }
                }
                let one =
                    finish(raw, &p, stats, std::time::Duration::from_millis(1));
                let (inc, deltas) = run_session(steps.clone(), p);
                if inc.tokens != one.tokens {
                    return Err(format!("tokens {:?} != {:?} (src {toks:?})",
                                       inc.tokens, one.tokens));
                }
                if inc.stats.generated_tokens != one.stats.generated_tokens {
                    return Err(format!(
                        "generated {} != {} (src {toks:?})",
                        inc.stats.generated_tokens, one.stats.generated_tokens));
                }
                if inc.stats.generated_tokens != inc.tokens.len() {
                    return Err("stats disagree with output length".into());
                }
                let cat: Vec<u32> = deltas.into_iter().flatten().collect();
                if cat != inc.tokens {
                    return Err("deltas do not concatenate to output".into());
                }
                Ok(())
            },
        );
    }
}
