//! Decoding engines (L3): the paper's lookahead decoder plus every baseline
//! it is evaluated against.
//!
//! | engine            | paper role                                   |
//! |-------------------|----------------------------------------------|
//! | `autoregressive`  | the greedy-search baseline (HF equivalent)   |
//! | `lookahead`       | the contribution (Algorithms 2/3/4)          |
//! | `jacobi`          | Jacobi decoding (§2, "Limitations")          |
//! | `spec_decode`     | draft-model speculative decoding (§2)        |
//! | `prompt_lookup`   | prompt-lookup baseline (Tab. 3 row ②)        |
//!
//! Every engine exposes the resumable [`DecodeSession`] API: `begin()` opens
//! a session, `step()` commits one variable-length run of verified tokens.
//! The one-shot `generate()`/`generate_with_pool()` calls are thin loops
//! over `step()` and stay byte-exact with the historical behavior.

pub mod autoregressive;
pub mod jacobi;
pub mod lookahead;
pub mod prompt_lookup;
pub mod sampling;
pub mod session;
pub mod spec_decode;
pub mod verify;

use anyhow::Result;

use crate::metrics::DecodeStats;
use crate::ngram::{PoolHandle, PoolSpec};
use crate::runtime::ModelRuntime;
use crate::tokenizer::{ByteTokenizer, EOS_ID, VOCAB_SIZE};

pub use sampling::SamplingParams;
pub use session::{step_group, BatchPlan, BatchStep, DecodeSession, FinishReason,
                  GroupOutcome, StepOutcome};

#[derive(Debug, Clone)]
pub struct GenParams {
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    pub stop_at_eos: bool,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_new_tokens: 64,
            sampling: SamplingParams::greedy(),
            stop_at_eos: true,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenOutput {
    pub tokens: Vec<u32>,
    pub text: String,
    pub stats: DecodeStats,
}

/// A decoding strategy over one model runtime.
pub trait Decoder {
    fn name(&self) -> String;

    /// The n-gram pool shape this engine consults per request, or None when
    /// the engine keeps no pool (autoregressive, Jacobi, spec-decode). The
    /// serving layer uses this to bind requests to the right cross-request
    /// `SharedNgramCache` (keyed by model + n).
    fn pool_spec(&self) -> Option<PoolSpec> {
        None
    }

    /// Open a resumable decoding session for `prompt` (token ids, BOS
    /// included by the caller). The session takes ownership of `pool` — a
    /// cold private pool or a warm cross-request shared cache handle — and
    /// returns it from [`DecodeSession::into_output`]. Pool contents only
    /// affect speed (accept length), never output bytes: greedy engines
    /// stay byte-exact w.r.t. autoregressive decoding (checked by
    /// `rust/tests/output_equivalence.rs` and `rust/tests/streaming.rs`).
    ///
    /// Sessions borrow only the runtime (`'rt`), never the engine, so one
    /// engine instance can have many concurrent sessions — the property the
    /// worker's time-sliced interleave loop relies on.
    fn begin<'rt>(&self, rt: &'rt ModelRuntime, prompt: &[u32], params: &GenParams,
                  pool: PoolHandle) -> Result<Box<dyn DecodeSession + 'rt>>;

    /// One-shot generation through `pool`: drives a session to completion.
    /// Kept for benches/tests and simple callers; new serving code should
    /// use [`Decoder::begin`] directly (see DESIGN.md "Deprecation path").
    ///
    /// On success the caller's `pool` handle is returned intact (with this
    /// request's hit/miss accounting); if `begin`/`step` fail the handle
    /// degrades to a detached one.
    fn generate_with_pool(&mut self, rt: &ModelRuntime, prompt: &[u32],
                          params: &GenParams, pool: &mut PoolHandle)
                          -> Result<GenOutput> {
        let owned = std::mem::replace(pool, PoolHandle::none());
        let mut sess = self.begin(rt, prompt, params, owned)?;
        while sess.finished().is_none() {
            sess.step()?;
        }
        let (out, owned) = sess.into_output();
        *pool = owned;
        Ok(out)
    }

    /// Generate with a cold per-request pool — the paper's single-request
    /// setting and the pre-sharing behavior of this crate.
    fn generate(&mut self, rt: &ModelRuntime, prompt: &[u32], params: &GenParams)
                -> Result<GenOutput> {
        let mut pool = PoolHandle::for_spec(self.pool_spec());
        self.generate_with_pool(rt, prompt, params, &mut pool)
    }
}

/// Shared post-processing: truncate at the budget and at EOS, decode text,
/// finalize stats. Both truncation paths adjust `stats.generated_tokens` so
/// the stats always agree with the returned token list (sessions apply the
/// same contract incrementally in `session::SessionCore::commit_step`).
pub(crate) fn finish(tokens: Vec<u32>, params: &GenParams, mut stats: DecodeStats,
                     wall: std::time::Duration) -> GenOutput {
    let mut tokens = tokens;
    // multi-token steps may overshoot the budget; enforce the contract
    if tokens.len() > params.max_new_tokens {
        let overshoot = tokens.len() - params.max_new_tokens;
        stats.generated_tokens = stats.generated_tokens.saturating_sub(overshoot);
        tokens.truncate(params.max_new_tokens);
    }
    if params.stop_at_eos {
        if let Some(pos) = tokens.iter().position(|&t| t == EOS_ID) {
            let dropped = tokens.len() - pos;
            stats.generated_tokens = stats.generated_tokens.saturating_sub(dropped);
            tokens.truncate(pos);
        }
    }
    stats.wall = wall;
    let text = ByteTokenizer::new().decode(&tokens);
    GenOutput { tokens, text, stats }
}

/// Remaining generation budget given cache capacity (each step may commit up
/// to `margin` tokens past the current one).
pub(crate) fn capacity_left(rt: &ModelRuntime, cache_len: usize, margin: usize) -> bool {
    cache_len + margin + 1 < rt.mm.capacity()
}

/// Live vocab size (ids above VOCAB_SIZE are padding and never sampled).
pub(crate) fn vocab_live(rt: &ModelRuntime) -> usize {
    (VOCAB_SIZE as usize).min(rt.vocab_padded)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_for(steps: &[usize]) -> DecodeStats {
        let mut s = DecodeStats::default();
        for &n in steps {
            s.record_accept(n);
        }
        s
    }

    #[test]
    fn finish_overshoot_adjusts_generated_tokens() {
        let p = GenParams { max_new_tokens: 3, ..Default::default() };
        let out = finish(vec![1, 2, 3, 4, 5], &p, stats_for(&[2, 3]),
                         std::time::Duration::ZERO);
        assert_eq!(out.tokens, vec![1, 2, 3]);
        assert_eq!(out.stats.generated_tokens, 3);
    }

    #[test]
    fn finish_eos_truncation_adjusts_generated_tokens() {
        // regression: the EOS path used to drop tokens without touching
        // stats.generated_tokens while the overshoot path adjusted it
        let p = GenParams { max_new_tokens: 16, ..Default::default() };
        let out = finish(vec![1, 2, EOS_ID, 9], &p, stats_for(&[4]),
                         std::time::Duration::ZERO);
        assert_eq!(out.tokens, vec![1, 2]);
        assert_eq!(out.stats.generated_tokens, out.tokens.len());
    }

    #[test]
    fn finish_both_paths_agree_with_output_len() {
        // EOS beyond the budget: the budget trim removes it first
        let p = GenParams { max_new_tokens: 2, ..Default::default() };
        let out = finish(vec![1, 2, EOS_ID], &p, stats_for(&[3]),
                         std::time::Duration::ZERO);
        assert_eq!(out.tokens, vec![1, 2]);
        assert_eq!(out.stats.generated_tokens, 2);
        // EOS inside the budget: both trims stack consistently
        let out = finish(vec![EOS_ID, 7, 8, 9], &p, stats_for(&[4]),
                         std::time::Duration::ZERO);
        assert_eq!(out.tokens, Vec::<u32>::new());
        assert_eq!(out.stats.generated_tokens, 0);
    }

    #[test]
    fn finish_ignores_eos_when_disabled() {
        let p = GenParams { max_new_tokens: 8, stop_at_eos: false, ..Default::default() };
        let out = finish(vec![1, EOS_ID, 2], &p, stats_for(&[3]),
                         std::time::Duration::ZERO);
        assert_eq!(out.tokens, vec![1, EOS_ID, 2]);
        assert_eq!(out.stats.generated_tokens, 3);
    }
}
