//! Decoding engines (L3): the paper's lookahead decoder plus every baseline
//! it is evaluated against.
//!
//! | engine            | paper role                                   |
//! |-------------------|----------------------------------------------|
//! | `autoregressive`  | the greedy-search baseline (HF equivalent)   |
//! | `lookahead`       | the contribution (Algorithms 2/3/4)          |
//! | `jacobi`          | Jacobi decoding (§2, "Limitations")          |
//! | `spec_decode`     | draft-model speculative decoding (§2)        |
//! | `prompt_lookup`   | prompt-lookup baseline (Tab. 3 row ②)        |

pub mod autoregressive;
pub mod jacobi;
pub mod lookahead;
pub mod prompt_lookup;
pub mod sampling;
pub mod spec_decode;
pub mod verify;

use anyhow::Result;

use crate::metrics::DecodeStats;
use crate::runtime::ModelRuntime;
use crate::tokenizer::{ByteTokenizer, EOS_ID, VOCAB_SIZE};

pub use sampling::SamplingParams;

#[derive(Debug, Clone)]
pub struct GenParams {
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    pub stop_at_eos: bool,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_new_tokens: 64,
            sampling: SamplingParams::greedy(),
            stop_at_eos: true,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenOutput {
    pub tokens: Vec<u32>,
    pub text: String,
    pub stats: DecodeStats,
}

/// A decoding strategy over one model runtime.
pub trait Decoder {
    fn name(&self) -> String;

    /// Generate a continuation of `prompt` (token ids, BOS included by the
    /// caller). Greedy engines must be byte-exact w.r.t. autoregressive
    /// decoding — checked by `rust/tests/output_equivalence.rs`.
    fn generate(&mut self, rt: &ModelRuntime, prompt: &[u32], params: &GenParams)
                -> Result<GenOutput>;
}

/// Shared post-processing: truncate at EOS, decode text, finalize stats.
pub(crate) fn finish(tokens: Vec<u32>, params: &GenParams, mut stats: DecodeStats,
                     wall: std::time::Duration) -> GenOutput {
    let mut tokens = tokens;
    // multi-token steps may overshoot the budget; enforce the contract
    if tokens.len() > params.max_new_tokens {
        let overshoot = tokens.len() - params.max_new_tokens;
        stats.generated_tokens = stats.generated_tokens.saturating_sub(overshoot);
        tokens.truncate(params.max_new_tokens);
    }
    if params.stop_at_eos {
        if let Some(pos) = tokens.iter().position(|&t| t == EOS_ID) {
            tokens.truncate(pos);
        }
    }
    stats.wall = wall;
    let text = ByteTokenizer::new().decode(&tokens);
    GenOutput { tokens, text, stats }
}

/// Remaining generation budget given cache capacity (each step may commit up
/// to `margin` tokens past the current one).
pub(crate) fn capacity_left(rt: &ModelRuntime, cache_len: usize, margin: usize) -> bool {
    cache_len + margin + 1 < rt.mm.capacity()
}

/// Live vocab size (ids above VOCAB_SIZE are padding and never sampled).
pub(crate) fn vocab_live(rt: &ModelRuntime) -> usize {
    (VOCAB_SIZE as usize).min(rt.vocab_padded)
}
