//! Decoding engines (L3): the paper's lookahead decoder plus every baseline
//! it is evaluated against.
//!
//! | engine            | paper role                                   |
//! |-------------------|----------------------------------------------|
//! | `autoregressive`  | the greedy-search baseline (HF equivalent)   |
//! | `lookahead`       | the contribution (Algorithms 2/3/4)          |
//! | `jacobi`          | Jacobi decoding (§2, "Limitations")          |
//! | `spec_decode`     | draft-model speculative decoding (§2)        |
//! | `prompt_lookup`   | prompt-lookup baseline (Tab. 3 row ②)        |

pub mod autoregressive;
pub mod jacobi;
pub mod lookahead;
pub mod prompt_lookup;
pub mod sampling;
pub mod spec_decode;
pub mod verify;

use anyhow::Result;

use crate::metrics::DecodeStats;
use crate::ngram::{PoolHandle, PoolSpec};
use crate::runtime::ModelRuntime;
use crate::tokenizer::{ByteTokenizer, EOS_ID, VOCAB_SIZE};

pub use sampling::SamplingParams;

#[derive(Debug, Clone)]
pub struct GenParams {
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    pub stop_at_eos: bool,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_new_tokens: 64,
            sampling: SamplingParams::greedy(),
            stop_at_eos: true,
            seed: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenOutput {
    pub tokens: Vec<u32>,
    pub text: String,
    pub stats: DecodeStats,
}

/// A decoding strategy over one model runtime.
pub trait Decoder {
    fn name(&self) -> String;

    /// The n-gram pool shape this engine consults per request, or None when
    /// the engine keeps no pool (autoregressive, Jacobi, spec-decode). The
    /// serving layer uses this to bind requests to the right cross-request
    /// `SharedNgramCache` (keyed by model + n).
    fn pool_spec(&self) -> Option<PoolSpec> {
        None
    }

    /// Generate a continuation of `prompt` (token ids, BOS included by the
    /// caller), storing/retrieving speculation n-grams through `pool`. The
    /// handle may wrap a cold private pool or a warm cross-request shared
    /// cache — pool contents only affect speed (accept length), never
    /// output bytes: greedy engines must stay byte-exact w.r.t.
    /// autoregressive decoding (checked by
    /// `rust/tests/output_equivalence.rs`).
    fn generate_with_pool(&mut self, rt: &ModelRuntime, prompt: &[u32],
                          params: &GenParams, pool: &mut PoolHandle)
                          -> Result<GenOutput>;

    /// Generate with a cold per-request pool — the paper's single-request
    /// setting and the pre-sharing behavior of this crate.
    fn generate(&mut self, rt: &ModelRuntime, prompt: &[u32], params: &GenParams)
                -> Result<GenOutput> {
        let mut pool = PoolHandle::for_spec(self.pool_spec());
        self.generate_with_pool(rt, prompt, params, &mut pool)
    }
}

/// Shared post-processing: truncate at EOS, decode text, finalize stats.
pub(crate) fn finish(tokens: Vec<u32>, params: &GenParams, mut stats: DecodeStats,
                     wall: std::time::Duration) -> GenOutput {
    let mut tokens = tokens;
    // multi-token steps may overshoot the budget; enforce the contract
    if tokens.len() > params.max_new_tokens {
        let overshoot = tokens.len() - params.max_new_tokens;
        stats.generated_tokens = stats.generated_tokens.saturating_sub(overshoot);
        tokens.truncate(params.max_new_tokens);
    }
    if params.stop_at_eos {
        if let Some(pos) = tokens.iter().position(|&t| t == EOS_ID) {
            tokens.truncate(pos);
        }
    }
    stats.wall = wall;
    let text = ByteTokenizer::new().decode(&tokens);
    GenOutput { tokens, text, stats }
}

/// Remaining generation budget given cache capacity (each step may commit up
/// to `margin` tokens past the current one).
pub(crate) fn capacity_left(rt: &ModelRuntime, cache_len: usize, margin: usize) -> bool {
    cache_len + margin + 1 < rt.mm.capacity()
}

/// Live vocab size (ids above VOCAB_SIZE are padding and never sampled).
pub(crate) fn vocab_live(rt: &ModelRuntime) -> usize {
    (VOCAB_SIZE as usize).min(rt.vocab_padded)
}
