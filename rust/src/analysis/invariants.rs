//! Invariant lints: config/request struct-literal ban, wall-clock ban in
//! deterministic modules, and the shrink-only unwrap/expect/panic budget
//! for hot-path files (DESIGN.md §9).

use super::lexer::{test_regions, Kind, Lexed};
use super::{allowed, Finding};

/// Types whose struct literals are confined to their defining module —
/// everywhere else construction goes through `Default`/builders, so adding
/// a field is never a silent semantic change at call sites.
const BANNED_LITERALS: &[(&str, &str)] = &[
    ("ServerConfig", "server/config.rs"),
    ("WorkerConfig", "server/config.rs"),
    ("Request", "server/request.rs"),
];

/// Tokens that may legally precede `Type {` without it being a literal:
/// definitions, impl headers, return types, bounds.
const NON_LITERAL_PREV: &[&str] =
    &["struct", "enum", "trait", "impl", "for", "dyn", "as", "->", ":", "&", "<", ">"];

/// Modules that must stay deterministic: replayable schedules, seeded
/// RNG, engine math. `Instant::now` / `SystemTime` there means replay
/// drift, so wall-clock reads need an explicit `wall-clock` allow.
pub const WALL_CLOCK_SCOPE: &[&str] =
    &["bench/load.rs", "util/rng.rs", "/workload/", "/engine/"];

/// Hot-path files under the shrink-only unwrap budget.
pub const HOT_PATH: &[&str] =
    &["server/worker.rs", "server/scheduler.rs", "net/mod.rs"];

pub fn in_wall_clock_scope(file: &str) -> bool {
    WALL_CLOCK_SCOPE.iter().any(|s| file.ends_with(s) || file.contains(s))
}

pub fn is_hot_path(file: &str) -> bool {
    HOT_PATH.iter().any(|s| file.ends_with(s))
}

/// Struct-literal ban: `Type {` outside the defining module, except in
/// definition/type positions.
pub fn check_struct_literals(file: &str, lexed: &Lexed) -> Vec<Finding> {
    let toks = &lexed.toks;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind != Kind::Ident {
            continue;
        }
        let Some((ty, home)) =
            BANNED_LITERALS.iter().find(|(t, _)| toks[i].is_ident(t))
        else {
            continue;
        };
        if file.ends_with(home) {
            continue;
        }
        if i + 1 >= toks.len() || !toks[i + 1].is("{") {
            continue;
        }
        let prev_ok = i > 0
            && (NON_LITERAL_PREV.iter().any(|p| toks[i - 1].is(p))
                || toks[i - 1].is("::"));
        if prev_ok || allowed(lexed, "struct-literal", toks[i].line) {
            continue;
        }
        out.push(Finding::new(
            "struct-literal",
            file,
            toks[i].line,
            format!(
                "`{ty} {{ .. }}` literal outside {home}: construct via \
                 `{ty}::builder()`/`Default` so new fields keep defaults"
            ),
        ));
    }
    out
}

/// Wall-clock ban: `Instant::now` / `SystemTime` / `UNIX_EPOCH` inside the
/// deterministic scope (the caller decides scope via
/// [`in_wall_clock_scope`]).
pub fn check_wall_clock(file: &str, lexed: &Lexed) -> Vec<Finding> {
    let toks = &lexed.toks;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        let hit = (toks[i].is_ident("Instant")
            && i + 2 < toks.len()
            && toks[i + 1].is("::")
            && toks[i + 2].is_ident("now"))
            || toks[i].is_ident("SystemTime")
            || toks[i].is_ident("UNIX_EPOCH");
        if !hit || allowed(lexed, "wall-clock", toks[i].line) {
            continue;
        }
        out.push(Finding::new(
            "wall-clock",
            file,
            toks[i].line,
            format!(
                "wall-clock read `{}` in a deterministic module: derive \
                 time from the seeded schedule, or annotate why real time \
                 is required",
                toks[i].text
            ),
        ));
    }
    out
}

/// Every `.unwrap()` / `.expect(` / `panic!(` site outside `#[cfg(test)]`
/// modules in a hot-path file. The caller compares the count against the
/// shrink-only baseline.
pub fn hot_unwrap_sites(file: &str, lexed: &Lexed) -> Vec<Finding> {
    let toks = &lexed.toks;
    let mask = test_regions(toks);
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if mask[i] {
            continue;
        }
        let site = if toks[i].is(".")
            && i + 2 < toks.len()
            && (toks[i + 1].is_ident("unwrap") || toks[i + 1].is_ident("expect"))
            && toks[i + 2].is("(")
        {
            Some((toks[i + 1].text.clone(), toks[i + 1].line))
        } else if toks[i].is_ident("panic")
            && i + 2 < toks.len()
            && toks[i + 1].is("!")
            && toks[i + 2].is("(")
        {
            Some(("panic!".to_string(), toks[i].line))
        } else {
            None
        };
        let Some((what, line)) = site else { continue };
        if allowed(lexed, "hot-unwrap", line) {
            continue;
        }
        out.push(Finding::new(
            "hot-unwrap",
            file,
            line,
            format!("`{what}` on the hot path: return an error or degrade"),
        ));
    }
    out
}

/// Allow directives with a missing/empty mandatory reason.
pub fn check_allow_reasons(file: &str, lexed: &Lexed) -> Vec<Finding> {
    lexed
        .allows
        .iter()
        .filter(|a| !a.has_reason)
        .map(|a| {
            Finding::new(
                "lint-allow",
                file,
                a.line,
                format!(
                    "`lint: allow({})` without a reason: the escape hatch \
                     grammar requires `reason=<why>`",
                    a.lint
                ),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    #[test]
    fn config_literal_flagged_outside_home() {
        let l = lex("fn f() { let c = ServerConfig { workers: 1 }; }");
        let f = check_struct_literals("rust/tests/x.rs", &l);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].lint, "struct-literal");
        // same text inside the defining module is fine
        assert!(check_struct_literals("rust/src/server/config.rs", &l).is_empty());
    }

    #[test]
    fn type_positions_are_not_literals() {
        let l = lex(
            "impl Default for Request { fn default() -> Request { x() } }\n\
             fn mk() -> ServerConfig { ServerConfig::builder().build() }",
        );
        assert!(check_struct_literals("rust/tests/x.rs", &l).is_empty());
    }

    #[test]
    fn wall_clock_flagged_unless_allowed() {
        let bad = lex("fn f() { let t = Instant::now(); }");
        assert_eq!(check_wall_clock("rust/src/bench/load.rs", &bad).len(), 1);
        let ok = lex(
            "// lint: allow(wall-clock) reason=measures real latency\n\
             fn f() { let t = Instant::now(); }",
        );
        assert!(check_wall_clock("rust/src/bench/load.rs", &ok).is_empty());
    }

    #[test]
    fn unwraps_counted_outside_test_mods_only() {
        let l = lex(
            "fn f() { x.unwrap(); y.expect(\"boom\"); panic!(\"no\"); }\n\
             #[cfg(test)] mod tests { fn t() { z.unwrap(); } }",
        );
        let f = hot_unwrap_sites("rust/src/server/worker.rs", &l);
        assert_eq!(f.len(), 3, "{f:?}");
    }

    #[test]
    fn bare_allow_needs_reason() {
        let l = lex("// lint: allow(wall-clock)\nfn f() {}");
        let f = check_allow_reasons("rust/src/bench/load.rs", &l);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].lint, "lint-allow");
    }
}
