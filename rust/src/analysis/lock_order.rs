//! Lock-order checker: static acquired-while-held analysis over the token
//! stream, resolved against the declared inventory.
//!
//! Per function body the scanner tracks live guards — a let-bound guard
//! lives to the end of its block, a temporary to the end of its statement,
//! `drop(g)` kills early — and records (1) a direct edge `A → B` whenever
//! lock B is acquired while A is held, and (2) every call made while a
//! lock is held. Call effects are closed inter-procedurally: a function's
//! acquire set is its direct acquisitions plus those of everything it
//! calls (fixpoint, callees matched by name across the tree). An edge is a
//! violation unless the held rank is strictly below the acquired rank —
//! strict ascent makes the acquired-while-held graph acyclic by
//! construction, so rank checking subsumes cycle detection.
//!
//! Known soundness trades (DESIGN.md §9): closure bodies are analyzed as
//! separate functions with an empty held-set (spawned/deferred work runs
//! on its own thread); calls chained directly onto a fresh guard
//! (`x.lock().len()`) target the protected data, not a lock, and are not
//! resolved; ubiquitous container-method names (`len`, `insert`, …) are
//! never resolved by name — a lock-bearing method must not hide behind
//! one.

use super::inventory::{self, LockRef};
use super::lexer::{match_brace, Kind, Lexed, Tok};
use super::{allowed, Finding};
use std::collections::{BTreeMap, BTreeSet};

/// Method names too common to resolve by bare name — matching them against
/// same-named crate functions would wire container calls to unrelated lock
/// summaries.
const CALL_SKIP: &[&str] = &[
    "new", "default", "len", "is_empty", "get", "get_mut", "insert", "remove",
    "push", "pop", "push_back", "pop_front", "clear", "contains", "contains_key",
    "extend",
    "drain", "iter", "iter_mut", "into_iter", "keys", "values", "entry",
    "or_insert_with", "or_default", "clone", "cloned", "copied", "collect",
    "map", "and_then", "filter", "find", "any", "all", "position", "take",
    "replace", "unwrap", "unwrap_or", "unwrap_or_else", "unwrap_or_default",
    "expect", "ok_or", "ok_or_else", "send", "recv", "recv_timeout",
    "try_recv", "join", "spawn", "min_by_key", "max_by_key", "sum", "count",
    "write", "read", "flush", "fmt", "to_string", "into", "from", "as_ref",
    "as_mut", "as_str", "parse", "retain", "for_each", "enumerate",
];

const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "else", "move", "fn",
    "let", "in", "as", "ref", "mut", "impl", "pub", "use", "where", "unsafe",
    "dyn", "box", "struct", "enum", "trait", "type", "const", "static",
    "crate", "super", "break", "continue",
];

#[derive(Debug)]
struct Edge {
    from: LockRef,
    to: LockRef,
    file: String,
    line: u32,
}

#[derive(Debug)]
struct CallSite {
    held: LockRef,
    callee: String,
    file: String,
    line: u32,
}

#[derive(Default)]
struct Collected {
    edges: Vec<Edge>,
    calls_held: Vec<CallSite>,
    /// fn name → (direct acquires, all callee names) merged across bodies.
    summaries: BTreeMap<String, (BTreeSet<&'static str>, BTreeSet<String>)>,
    findings: Vec<Finding>,
}

/// Run the checker over every lexed file. `files` carries `/`-normalized
/// paths; the inventory matches on path suffix.
pub fn check(files: &[(String, Lexed)]) -> Vec<Finding> {
    let mut c = Collected::default();
    for (path, lexed) in files {
        for (name, start, end) in function_bodies(&lexed.toks) {
            scan_body(path, lexed, &name, start, end, &mut c);
        }
    }
    // close acquire sets over the call graph (rank count bounds the chain)
    let rank_of: BTreeMap<&str, u8> =
        inventory::all().iter().map(|l| (l.id, l.rank)).collect();
    let mut acq: BTreeMap<String, BTreeSet<&'static str>> = c
        .summaries
        .iter()
        .map(|(k, (d, _))| (k.clone(), d.clone()))
        .collect();
    for _ in 0..16 {
        let mut changed = false;
        for (name, (_, calls)) in &c.summaries {
            let mut add: BTreeSet<&'static str> = BTreeSet::new();
            for callee in calls {
                if let Some(s) = acq.get(callee) {
                    add.extend(s.iter().copied());
                }
            }
            let cur = acq.entry(name.clone()).or_default();
            let before = cur.len();
            cur.extend(add);
            changed |= cur.len() != before;
        }
        if !changed {
            break;
        }
    }
    let mut findings = std::mem::take(&mut c.findings);
    for e in &c.edges {
        if e.from.rank >= e.to.rank {
            findings.push(Finding::new(
                "lock-order",
                &e.file,
                e.line,
                format!(
                    "acquires `{}` (rank {}) while holding `{}` (rank {}): \
                     lock order must strictly ascend",
                    e.to.id, e.to.rank, e.from.id, e.from.rank
                ),
            ));
        }
    }
    for s in &c.calls_held {
        let Some(ids) = acq.get(&s.callee) else { continue };
        for id in ids {
            let r = rank_of.get(id).copied().unwrap_or(0);
            if r <= s.held.rank {
                findings.push(Finding::new(
                    "lock-order",
                    &s.file,
                    s.line,
                    format!(
                        "call to `{}` may acquire `{}` (rank {}) while \
                         holding `{}` (rank {})",
                        s.callee, id, r, s.held.id, s.held.rank
                    ),
                ));
            }
        }
    }
    findings
}

/// Every `fn` body in the file as (name, open-brace idx, close-brace idx).
/// Trait-method declarations (`fn f(…);`) have no body and are skipped.
fn function_bodies(toks: &[Tok]) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if toks[i].is_ident("fn") && toks[i + 1].kind == Kind::Ident {
            let name = toks[i + 1].text.clone();
            let mut j = i + 2;
            while j < toks.len() && !toks[j].is("{") && !toks[j].is(";") {
                j += 1;
            }
            if j < toks.len() && toks[j].is("{") {
                let end = match_brace(toks, j);
                out.push((name, j, end));
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

#[derive(Debug)]
struct Guard {
    lock: LockRef,
    var: Option<String>,
    depth: i32,
    temp: bool,
}

/// Scan one body (tokens `start+1 .. end` for a brace body). Closures are
/// queued and scanned as separate anonymous bodies with an empty held-set.
fn scan_body(
    file: &str,
    lexed: &Lexed,
    fname: &str,
    open: usize,
    close: usize,
    c: &mut Collected,
) {
    let toks = &lexed.toks;
    let mut queue: Vec<(usize, usize)> = vec![(open + 1, close)];
    let mut direct: BTreeSet<&'static str> = BTreeSet::new();
    let mut calls: BTreeSet<String> = BTreeSet::new();
    while let Some((lo, hi)) = queue.pop() {
        let mut guards: Vec<Guard> = Vec::new();
        let mut depth = 0i32;
        let mut pdepth = 0i32;
        let mut stmt_let: Option<String> = None;
        let mut i = lo;
        while i < hi {
            let t = &toks[i];
            // nested fn items get their own entry from function_bodies —
            // skip their tokens here so locks are not double-attributed
            if t.is_ident("fn") && i + 1 < hi && toks[i + 1].kind == Kind::Ident {
                let mut j = i + 2;
                while j < hi && !toks[j].is("{") && !toks[j].is(";") {
                    j += 1;
                }
                i = if j < hi && toks[j].is("{") {
                    match_brace(toks, j) + 1
                } else {
                    j + 1
                };
                continue;
            }
            // closure: body runs later / on another thread — separate scan
            if (t.is("|") || t.is("||")) && i > 0 && closure_prev(&toks[i - 1]) {
                let params_end = if t.is("||") {
                    i
                } else {
                    let mut j = i + 1;
                    while j < hi && !toks[j].is("|") {
                        j += 1;
                    }
                    j
                };
                let body = params_end + 1;
                if body < hi && toks[body].is("{") {
                    let bend = match_brace(toks, body);
                    queue.push((body + 1, bend));
                    i = bend + 1;
                } else {
                    // expression body: runs to `,` or the enclosing `)`
                    let mut j = body;
                    let mut p = 0i32;
                    while j < hi {
                        let tj = &toks[j];
                        if tj.is("(") || tj.is("[") || tj.is("{") {
                            p += 1;
                        } else if tj.is(")") || tj.is("]") || tj.is("}") {
                            if p == 0 {
                                break;
                            }
                            p -= 1;
                        } else if (tj.is(",") || tj.is(";")) && p == 0 {
                            break;
                        }
                        j += 1;
                    }
                    queue.push((body, j));
                    i = j;
                }
                continue;
            }
            // `.lock()` acquisition — must run before the generic punct
            // bookkeeping below, which would otherwise swallow the `.`
            if t.is(".")
                && i + 3 < hi
                && toks[i + 1].is_ident("lock")
                && toks[i + 2].is("(")
                && toks[i + 3].is(")")
            {
                let line = toks[i + 1].line;
                match receiver(toks, i).and_then(|r| inventory::resolve(file, &r)) {
                    Some(lock) => {
                        for g in &guards {
                            if !allowed(lexed, "lock-order", line) {
                                c.edges.push(Edge {
                                    from: g.lock,
                                    to: lock,
                                    file: file.to_string(),
                                    line,
                                });
                            }
                        }
                        direct.insert(lock.id);
                        let bound = stmt_let.is_some()
                            && i + 4 < hi
                            && toks[i + 4].is(";");
                        guards.push(Guard {
                            lock,
                            var: if bound { stmt_let.clone() } else { None },
                            depth,
                            temp: !bound,
                        });
                    }
                    None => {
                        if !allowed(lexed, "lock-inventory", line) {
                            c.findings.push(Finding::new(
                                "lock-inventory",
                                file,
                                line,
                                format!(
                                    "`.lock()` receiver `{}` is not in the \
                                     declared lock inventory",
                                    receiver(toks, i).unwrap_or_default()
                                ),
                            ));
                        }
                    }
                }
                i += 4;
                continue;
            }
            // method call `.name(` — skipped when chained off a fresh guard
            if t.is(".")
                && i + 2 < hi
                && toks[i + 1].kind == Kind::Ident
                && toks[i + 2].is("(")
            {
                let name = toks[i + 1].text.clone();
                if !chain_root_is_lock(toks, i) && !CALL_SKIP.contains(&name.as_str())
                {
                    record_call(&name, &guards, file, toks[i + 1].line, lexed, c,
                                &mut calls);
                }
                i += 2; // land on `(` so pdepth stays balanced
                continue;
            }
            if t.kind == Kind::Punct {
                match t.text.as_str() {
                    "{" => {
                        depth += 1;
                        guards.retain(|g| !g.temp);
                        stmt_let = None;
                    }
                    "}" => {
                        depth -= 1;
                        guards.retain(|g| !g.temp && g.depth <= depth);
                        stmt_let = None;
                    }
                    "(" | "[" => pdepth += 1,
                    ")" | "]" => pdepth -= 1,
                    ";" if pdepth == 0 => {
                        guards.retain(|g| !g.temp);
                        stmt_let = None;
                    }
                    "," if pdepth == 0 => guards.retain(|g| !g.temp),
                    _ => {}
                }
                i += 1;
                continue;
            }
            if t.is_ident("let") {
                let mut j = i + 1;
                if j < hi && toks[j].is_ident("mut") {
                    j += 1;
                }
                stmt_let = if j + 1 < hi
                    && toks[j].kind == Kind::Ident
                    && toks[j + 1].is("=")
                {
                    Some(toks[j].text.clone())
                } else {
                    None
                };
                i += 1;
                continue;
            }
            if t.is_ident("drop")
                && i + 3 < hi
                && toks[i + 1].is("(")
                && toks[i + 2].kind == Kind::Ident
                && toks[i + 3].is(")")
            {
                let name = &toks[i + 2].text;
                guards.retain(|g| g.var.as_deref() != Some(name));
                i += 4;
                continue;
            }
            // free / path call `name(`
            if t.kind == Kind::Ident
                && i + 1 < hi
                && toks[i + 1].is("(")
                && (i == 0 || !toks[i - 1].is("."))
                && !KEYWORDS.contains(&t.text.as_str())
                && !CALL_SKIP.contains(&t.text.as_str())
            {
                let name = t.text.clone();
                record_call(&name, &guards, file, t.line, lexed, c, &mut calls);
                i += 1;
                continue;
            }
            i += 1;
        }
    }
    let entry = c.summaries.entry(fname.to_string()).or_default();
    entry.0.extend(direct);
    entry.1.extend(calls);
}

fn record_call(
    name: &str,
    guards: &[Guard],
    file: &str,
    line: u32,
    lexed: &Lexed,
    c: &mut Collected,
    calls: &mut BTreeSet<String>,
) {
    calls.insert(name.to_string());
    for g in guards {
        if !allowed(lexed, "lock-order", line) {
            c.calls_held.push(CallSite {
                held: g.lock,
                callee: name.to_string(),
                file: file.to_string(),
                line,
            });
        }
    }
}

fn closure_prev(t: &Tok) -> bool {
    t.is("(") || t.is(",") || t.is("=") || t.is("=>") || t.is("{")
        || t.is_ident("move") || t.is_ident("return") || t.is_ident("else")
}

/// Receiver ident of `<recv>.lock()`: the token before the dot, looking
/// through one index `[…]` or call `(…)` group (`shards[i].lock()`,
/// `shard_for(k).lock()`).
fn receiver(toks: &[Tok], dot: usize) -> Option<String> {
    if dot == 0 {
        return None;
    }
    let p = dot - 1;
    match toks[p].kind {
        Kind::Ident => Some(toks[p].text.clone()),
        Kind::Punct if toks[p].is("]") || toks[p].is(")") => {
            let open = rev_match(toks, p)?;
            if open == 0 {
                return None;
            }
            match toks[open - 1].kind {
                Kind::Ident => Some(toks[open - 1].text.clone()),
                _ => None,
            }
        }
        _ => None,
    }
}

/// Does the postfix chain containing the call at `dot` start at a
/// `.lock()` call? (`x.lock().ring.iter()` → yes for both `.ring`-chained
/// calls; `out.extend(…)` → no.)
fn chain_root_is_lock(toks: &[Tok], mut k: usize) -> bool {
    loop {
        if k == 0 {
            return false;
        }
        let mut p = k - 1;
        while p > 0 && toks[p].is("?") {
            p -= 1;
        }
        if toks[p].is(")") || toks[p].is("]") {
            let Some(open) = rev_match(toks, p) else { return false };
            if open == 0 {
                return false;
            }
            let q = open - 1;
            if toks[q].kind == Kind::Ident {
                if toks[q].is_ident("lock") {
                    return true;
                }
                if q >= 1 && toks[q - 1].is(".") {
                    k = q - 1;
                    continue;
                }
            }
            return false;
        }
        if toks[p].kind == Kind::Ident || toks[p].kind == Kind::Num {
            if p >= 1 && toks[p - 1].is(".") {
                k = p - 1;
                continue;
            }
            return false;
        }
        return false;
    }
}

/// Index of the `(`/`[` matching the closer at `close`, scanning backward.
fn rev_match(toks: &[Tok], close: usize) -> Option<usize> {
    let (o, c) = match toks[close].text.as_str() {
        ")" => ("(", ")"),
        "]" => ("[", "]"),
        _ => return None,
    };
    let mut depth = 0i32;
    for j in (0..=close).rev() {
        if toks[j].kind != Kind::Punct {
            continue;
        }
        if toks[j].text == c {
            depth += 1;
        } else if toks[j].text == o {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn run(src: &str, path: &str) -> Vec<Finding> {
        check(&[(path.to_string(), lex(src))])
    }

    #[test]
    fn ascending_nesting_is_clean() {
        // sched.state (20) then cancel.ids (40): strict ascent, no finding
        let src = "fn f(&self) { let st = self.state.lock(); \
                   self.ids.lock().insert(1); }";
        let f = run(src, "rust/src/server/scheduler.rs");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn descending_direct_edge_is_flagged() {
        let src = "fn f(&self) { let m = metrics.lock(); \
                   let s = self.state.lock(); }";
        let f = run(src, "rust/src/server/scheduler.rs");
        assert!(f.iter().any(|f| f.lint == "lock-order"), "{f:?}");
    }

    #[test]
    fn interprocedural_edge_through_named_call() {
        let src = "fn locks_low(&self) { self.state.lock().touch(); }\n\
                   fn caller(&self) { let m = metrics.lock(); \
                   self.sched.locks_low(); }";
        let f = run(src, "rust/src/server/scheduler.rs");
        assert!(
            f.iter().any(|f| f.lint == "lock-order" && f.msg.contains("locks_low")),
            "{f:?}"
        );
    }

    #[test]
    fn guard_chained_calls_are_not_resolved() {
        // `.lock().request()` targets the protected data, not CancelSet
        let src = "fn request(&self) { self.ids.lock().insert(1); }\n\
                   fn f(&self) { self.ids.lock().request(); }";
        let f = run(src, "rust/src/server/scheduler.rs");
        assert!(f.iter().all(|f| f.lint != "lock-order"), "{f:?}");
    }

    #[test]
    fn drop_releases_before_next_acquire() {
        let src = "fn f(&self) { let m = metrics.lock(); drop(m); \
                   let s = self.state.lock(); }";
        let f = run(src, "rust/src/server/scheduler.rs");
        assert!(f.iter().all(|f| f.lint != "lock-order"), "{f:?}");
    }

    #[test]
    fn closure_bodies_scan_with_empty_held_set() {
        let src = "fn f(&self) { let m = metrics.lock(); \
                   spawn(move || { let s = self.state.lock(); s.touch(); }); }";
        let f = run(src, "rust/src/server/scheduler.rs");
        assert!(f.iter().all(|f| f.lint != "lock-order"), "{f:?}");
    }

    #[test]
    fn unknown_receiver_is_an_inventory_finding() {
        let f = run("fn f() { mystery.lock(); }", "rust/src/server/server.rs");
        assert!(f.iter().any(|f| f.lint == "lock-inventory"), "{f:?}");
    }
}
