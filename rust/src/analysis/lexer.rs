//! Hand-rolled Rust token scanner for `lookahead-lint`.
//!
//! Substrate for the repo-aware lints (DESIGN.md §9): the offline image has
//! no `syn`/proc-macro stack, so — like `util/json.rs` — the analysis pass
//! scans source text with a small purpose-built lexer. It produces a flat
//! token stream with line numbers (enough for every lint in
//! [`crate::analysis`]), plus the `// lint: allow(<id>) reason=...` escape
//! hatches found in comments. It is NOT a full Rust lexer: it only needs to
//! be right about idents, literals, comments, and bracket structure.

/// Token class. `Str` carries the literal's content without quotes; `Life`
/// is a lifetime (`'a`), kept distinct from char literals so `&'static str`
/// never confuses the scanner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Life,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    /// Punct/keyword match — never true for string or char literal content.
    pub fn is(&self, text: &str) -> bool {
        self.kind != Kind::Str && self.kind != Kind::Char && self.text == text
    }

    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == Kind::Ident && self.text == text
    }
}

/// One `// lint: allow(<id>) reason=<text>` directive. `has_reason` is
/// false when the `reason=` clause is missing or empty — the allow grammar
/// makes the reason mandatory, and a bare allow is itself a finding.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    pub lint: String,
    pub has_reason: bool,
}

/// Multi-character operators, longest first (maximal munch).
const PUNCTS: &[&str] = &[
    "..=", "<<=", ">>=", "->", "=>", "::", "..", "&&", "||", "<<", ">>", "==",
    "!=", "<=", ">=", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=",
];

#[derive(Clone)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
}

/// Scan `src` into tokens + allow directives. Unterminated constructs
/// (string, block comment) end the scan at EOF rather than erroring: the
/// linter runs over a tree the compiler also sees, so malformed input is
/// the compiler's problem, not ours.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut allows = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            if let Some(a) = parse_allow(&src[start..i], line) {
                allows.push(a);
            }
        } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == b'"' {
            let (text, ni, nl) = scan_string(src, i + 1, line);
            toks.push(Tok { kind: Kind::Str, text, line });
            line = nl;
            i = ni;
        } else if (c == b'r' || c == b'b') && raw_string_start(b, i).is_some() {
            let (hashes, body_start) = raw_string_start(b, i).unwrap();
            let (text, ni, nl) = scan_raw_string(src, body_start, hashes, line);
            toks.push(Tok { kind: Kind::Str, text, line });
            line = nl;
            i = ni;
        } else if c == b'\'' {
            let (tok, ni) = scan_quote(src, i, line);
            toks.push(tok);
            i = ni;
        } else if c.is_ascii_alphabetic() || c == b'_' {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: src[start..i].to_string(), line });
        } else if c.is_ascii_digit() {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            // one fractional part, but never eat a `..` range operator
            if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
            }
            toks.push(Tok { kind: Kind::Num, text: src[start..i].to_string(), line });
        } else {
            let rest = &src[i..];
            let p = PUNCTS.iter().find(|p| rest.starts_with(**p));
            let text = match p {
                Some(p) => p.to_string(),
                None => (c as char).to_string(),
            };
            i += text.len();
            toks.push(Tok { kind: Kind::Punct, text, line });
        }
    }
    Lexed { toks, allows }
}

/// `r"`, `r#"`, `b"`… — returns (hash count, index of first body byte).
fn raw_string_start(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i + 1; // past the r/b marker
    if b[i] == b'b' && j < b.len() && b[j] == b'r' {
        j += 1;
    } else if b[i] == b'b' && j < b.len() && b[j] == b'"' {
        return Some((usize::MAX, j + 1)); // b"…": plain string body
    } else if b[i] == b'b' {
        return None;
    }
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Body of a `"…"` string starting after the opening quote; returns
/// (content, index past closing quote, updated line).
fn scan_string(src: &str, mut i: usize, mut line: u32) -> (String, usize, u32) {
    let b = src.as_bytes();
    let mut out = String::new();
    while i < b.len() {
        match b[i] {
            b'"' => return (out, i + 1, line),
            b'\\' if i + 1 < b.len() => {
                out.push(b[i + 1] as char);
                i += 2;
            }
            b'\n' => {
                line += 1;
                out.push('\n');
                i += 1;
            }
            c => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    (out, i, line)
}

/// Body of a raw string: ends at `"` followed by `hashes` `#`s. A
/// `hashes` of `usize::MAX` marks a `b"…"` byte string (escape rules of a
/// plain string).
fn scan_raw_string(
    src: &str,
    mut i: usize,
    hashes: usize,
    mut line: u32,
) -> (String, usize, u32) {
    if hashes == usize::MAX {
        return scan_string(src, i, line);
    }
    let b = src.as_bytes();
    let mut out = String::new();
    while i < b.len() {
        if b[i] == b'"' {
            let end = i + 1;
            let have = b[end..].iter().take_while(|&&c| c == b'#').count();
            if have >= hashes {
                return (out, end + hashes, line);
            }
        }
        if b[i] == b'\n' {
            line += 1;
        }
        out.push(b[i] as char);
        i += 1;
    }
    (out, i, line)
}

/// `'…'` char literal vs `'a` lifetime: any single character (ident or
/// punctuation — `'.'`, `b'{'`) with a closing quote is a char literal; a
/// quote followed by an ident run with no closing quote is a lifetime.
fn scan_quote(src: &str, i: usize, line: u32) -> (Tok, usize) {
    let b = src.as_bytes();
    let mut j = i + 1;
    if j >= b.len() {
        return (Tok { kind: Kind::Life, text: String::new(), line }, j);
    }
    if b[j] == b'\\' {
        // escaped char literal: consume escape + closing quote
        j += 2;
        while j < b.len() && b[j] != b'\'' {
            j += 1;
        }
        let text = src[i + 1..j.min(src.len())].to_string();
        return (Tok { kind: Kind::Char, text, line }, (j + 1).min(b.len()));
    }
    if j + 1 < b.len() && b[j + 1] == b'\'' && b[j] != b'\'' {
        return (Tok { kind: Kind::Char, text: src[j..j + 1].to_string(), line }, j + 2);
    }
    let mut k = j;
    while k < b.len() && (b[k].is_ascii_alphanumeric() || b[k] == b'_') {
        k += 1;
    }
    (Tok { kind: Kind::Life, text: src[j..k].to_string(), line }, k)
}

/// Parse `// lint: allow(<id>) reason=<text>` out of a line comment.
/// Directives live in plain `//` comments only — doc comments (`///`,
/// `//!`) are documentation and may quote the grammar without enacting it.
fn parse_allow(comment: &str, line: u32) -> Option<Allow> {
    if comment.starts_with("///") || comment.starts_with("//!") {
        return None;
    }
    let at = comment.find("lint: allow(")?;
    let rest = &comment[at + "lint: allow(".len()..];
    let close = rest.find(')')?;
    let lint = rest[..close].trim().to_string();
    let tail = &rest[close + 1..];
    let has_reason = match tail.find("reason=") {
        Some(r) => !tail[r + "reason=".len()..].trim().is_empty(),
        None => false,
    };
    Some(Allow { line, lint, has_reason })
}

/// Index of the `}` matching the `{` at `open` (which must be a `{`).
pub fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (off, t) in toks[open..].iter().enumerate() {
        if t.kind == Kind::Punct {
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    return open + off;
                }
            }
        }
    }
    toks.len() - 1
}

/// Index of the `)`/`]` matching the opener at `open`.
pub fn match_group(toks: &[Tok], open: usize) -> usize {
    let (o, c) = match toks[open].text.as_str() {
        "(" => ("(", ")"),
        "[" => ("[", "]"),
        _ => return open,
    };
    let mut depth = 0usize;
    for (off, t) in toks[open..].iter().enumerate() {
        if t.kind == Kind::Punct {
            if t.text == o {
                depth += 1;
            } else if t.text == c {
                depth -= 1;
                if depth == 0 {
                    return open + off;
                }
            }
        }
    }
    toks.len() - 1
}

/// Per-token flags marking `#[cfg(test)] mod … { … }` regions, so lints
/// scoped to shipping code can skip in-file test modules.
pub fn test_regions(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i + 8 < toks.len() {
        let cfg_test = toks[i].is("#")
            && toks[i + 1].is("[")
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is("(")
            && toks[i + 4].is_ident("test")
            && toks[i + 5].is(")")
            && toks[i + 6].is("]");
        if cfg_test {
            // allow attributes between the cfg and the mod keyword
            let mut j = i + 7;
            while j < toks.len() && toks[j].is("#") {
                if j + 1 < toks.len() && toks[j + 1].is("[") {
                    j = match_group_sq(toks, j + 1) + 1;
                } else {
                    break;
                }
            }
            if j + 1 < toks.len() && toks[j].is_ident("mod") {
                let mut k = j + 1;
                while k < toks.len() && !toks[k].is("{") && !toks[k].is(";") {
                    k += 1;
                }
                if k < toks.len() && toks[k].is("{") {
                    let end = match_brace(toks, k);
                    for m in mask.iter_mut().take(end + 1).skip(i) {
                        *m = true;
                    }
                    i = end + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    mask
}

fn match_group_sq(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (off, t) in toks[open..].iter().enumerate() {
        if t.is("[") {
            depth += 1;
        } else if t.is("]") {
            depth -= 1;
            if depth == 0 {
                return open + off;
            }
        }
    }
    toks.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idents_strings_and_lifetimes() {
        let l = lex("fn f<'a>(s: &'a str) { x.lock(); \"na\\\"me\" }");
        let idents: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, ["fn", "f", "s", "str", "x", "lock"]);
        assert!(l.toks.iter().any(|t| t.kind == Kind::Life && t.text == "a"));
        assert!(l.toks.iter().any(|t| t.kind == Kind::Str && t.text == "na\"me"));
    }

    #[test]
    fn char_literal_is_not_a_lifetime() {
        let l = lex("let c = 'x'; let n = '\\n'; fn g<'de>() {}");
        assert!(l.toks.iter().any(|t| t.kind == Kind::Char && t.text == "x"));
        assert!(l.toks.iter().any(|t| t.kind == Kind::Life && t.text == "de"));
    }

    #[test]
    fn comments_yield_allow_directives() {
        let src = "// lint: allow(wall-clock) reason=measures real latency\n\
                   let t = 1; // lint: allow(lock-order)\n";
        let l = lex(src);
        assert_eq!(l.allows.len(), 2);
        assert_eq!(l.allows[0].lint, "wall-clock");
        assert!(l.allows[0].has_reason);
        assert_eq!(l.allows[1].line, 2);
        assert!(!l.allows[1].has_reason);
    }

    #[test]
    fn test_region_mask_covers_cfg_test_mod() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests { fn t() {} }\n";
        let l = lex(src);
        let mask = test_regions(&l.toks);
        let live = l.toks.iter().position(|t| t.is_ident("live")).unwrap();
        let t = l.toks.iter().rposition(|t| t.is_ident("t")).unwrap();
        assert!(!mask[live]);
        assert!(mask[t]);
    }

    #[test]
    fn numbers_do_not_eat_range_dots() {
        let l = lex("for i in 0..10 { a[i] = 1.5; }");
        assert!(l.toks.iter().any(|t| t.kind == Kind::Punct && t.text == ".."));
        assert!(l.toks.iter().any(|t| t.kind == Kind::Num && t.text == "1.5"));
    }
}
