//! Metrics-name cross-check: every metric the tests or the bench harness
//! read must have a registration site, and every registered counter in the
//! `ctl_*` / `net_*` / `kv_*` / `trace_*` families must be read somewhere
//! — orphaned names are how dashboards silently go dark (DESIGN.md §9).
//!
//! Registrations are the string literals reaching `.inc(` / `.set(` /
//! `.observe(` / `bump(` calls in shipping code; references are the
//! literals reaching `.counter(` / `.summary(` / `report_counter(` calls
//! plus `"counters.<name>"` / `"histograms.<name>"` path strings in the
//! test suite and the bench harness. A literal containing `{` (a
//! `format!` template) registers its prefix as a dynamic family: families
//! satisfy references by prefix and are exempt from the reverse check.

use super::lexer::{match_group, test_regions, Kind, Lexed};
use super::{allowed, Finding};
use std::collections::BTreeMap;

const REG_CALLS: &[&str] = &["inc", "set", "observe", "bump"];
const REF_CALLS: &[&str] = &["counter", "summary", "report_counter"];
const FAMILIES: &[&str] = &["ctl_", "net_", "kv_", "trace_"];

#[derive(Debug, Default, Clone)]
pub struct Names {
    /// exact name → first (file, line)
    pub exact: BTreeMap<String, (String, u32)>,
    /// family prefix (from a `{`-bearing template) → first (file, line)
    pub family: BTreeMap<String, (String, u32)>,
}

impl Names {
    fn add(&mut self, lit: &str, file: &str, line: u32) {
        if lit.is_empty() {
            return;
        }
        match lit.find('{') {
            Some(0) => {}
            Some(b) => {
                self.family
                    .entry(lit[..b].to_string())
                    .or_insert_with(|| (file.to_string(), line));
            }
            None => {
                self.exact
                    .entry(lit.to_string())
                    .or_insert_with(|| (file.to_string(), line));
            }
        }
    }

    fn covers(&self, name: &str) -> bool {
        self.exact.contains_key(name)
            || self.family.keys().any(|f| name.starts_with(f.as_str()))
    }

    fn covers_family(&self, prefix: &str) -> bool {
        let fam = |f: &String| f.starts_with(prefix) || prefix.starts_with(f.as_str());
        self.family.keys().any(fam) || self.exact.keys().any(|n| n.starts_with(prefix))
    }
}

/// Collect literals reaching `calls` in one file; `skip_tests` drops
/// `#[cfg(test)] mod` regions (registrations must live in shipping code,
/// while reference scanning runs over test files wholesale).
fn collect(file: &str, lexed: &Lexed, calls: &[&str], skip_tests: bool) -> Names {
    let toks = &lexed.toks;
    let mask = test_regions(toks);
    let mut out = Names::default();
    for i in 0..toks.len() {
        if skip_tests && mask[i] {
            continue;
        }
        if toks[i].kind != Kind::Ident
            || !calls.contains(&toks[i].text.as_str())
            || i + 1 >= toks.len()
            || !toks[i + 1].is("(")
        {
            continue;
        }
        let end = match_group(toks, i + 1);
        for t in &toks[i + 1..end] {
            if t.kind == Kind::Str {
                out.add(&t.text, file, t.line);
            }
        }
    }
    out
}

/// `"counters.<name>"` / `"histograms.<name>[.stat]"` path literals.
fn collect_paths(file: &str, lexed: &Lexed, out: &mut Names) {
    for t in &lexed.toks {
        if t.kind != Kind::Str {
            continue;
        }
        for prefix in ["counters.", "histograms."] {
            if let Some(rest) = t.text.strip_prefix(prefix) {
                let name = rest.split('.').next().unwrap_or(rest);
                out.add(name, file, t.line);
            }
        }
    }
}

/// Cross-check over the whole corpus. `src` is shipping code (registration
/// side); `refs` is the test suite + bench harness (reference side — the
/// bench harness belongs to BOTH sides, since `bench_json` reads the
/// scrape it also documents).
pub fn check(src: &[(String, Lexed)], refs: &[(String, Lexed)]) -> Vec<Finding> {
    let mut registered = Names::default();
    for (path, lexed) in src {
        let n = collect(path, lexed, REG_CALLS, true);
        for (k, v) in n.exact {
            registered.exact.entry(k).or_insert(v);
        }
        for (k, v) in n.family {
            registered.family.entry(k).or_insert(v);
        }
    }
    let mut referenced = Names::default();
    for (path, lexed) in refs {
        let n = collect(path, lexed, REF_CALLS, false);
        for (k, v) in n.exact {
            referenced.exact.entry(k).or_insert(v);
        }
        for (k, v) in n.family {
            referenced.family.entry(k).or_insert(v);
        }
        collect_paths(path, lexed, &mut referenced);
    }
    let mut findings = Vec::new();
    // forward: everything the tests/bench read must be published somewhere
    for (name, (file, line)) in &referenced.exact {
        if !registered.covers(name) {
            findings.push(Finding::new(
                "metrics-name",
                file,
                *line,
                format!("metric `{name}` is asserted here but never registered"),
            ));
        }
    }
    for (prefix, (file, line)) in &referenced.family {
        if !registered.covers_family(prefix) {
            findings.push(Finding::new(
                "metrics-name",
                file,
                *line,
                format!("metric family `{prefix}*` is asserted here but never \
                         registered"),
            ));
        }
    }
    // reverse: registered ctl_/net_/kv_/trace_ counters must be read
    for (name, (file, line)) in &registered.exact {
        if !FAMILIES.iter().any(|f| name.starts_with(f)) {
            continue;
        }
        if !referenced.covers(name) {
            let lexed = src.iter().find(|(p, _)| p == file).map(|(_, l)| l);
            if lexed.is_some_and(|l| allowed(l, "metrics-name", *line)) {
                continue;
            }
            findings.push(Finding::new(
                "metrics-name",
                file,
                *line,
                format!(
                    "metric `{name}` is registered here but no test or bench \
                     section reads it"
                ),
            ));
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn corpus(src: &str, test: &str) -> Vec<Finding> {
        check(
            &[("rust/src/x.rs".to_string(), lex(src))],
            &[("rust/tests/t.rs".to_string(), lex(test))],
        )
    }

    #[test]
    fn matched_names_are_clean() {
        let f = corpus(
            "fn f(m: &mut R) { m.inc(\"net_hops\", 1); }",
            "fn t() { assert!(m.counter(\"net_hops\") > 0); }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn orphaned_registration_and_dangling_reference() {
        let f = corpus(
            "fn f(m: &mut R) { m.inc(\"net_orphan\", 1); }",
            "fn t() { assert!(m.counter(\"net_ghost\") > 0); }",
        );
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().any(|f| f.msg.contains("net_ghost")));
        assert!(f.iter().any(|f| f.msg.contains("net_orphan")));
    }

    #[test]
    fn format_families_cover_by_prefix_and_skip_reverse() {
        let f = corpus(
            "fn f(m: &mut R) { m.inc(&format!(\"ctl_switch_to_{}\", x), 1); }",
            "fn t() { assert!(m.counter(\"ctl_switch_to_lookahead\") > 0); }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn counters_path_strings_count_as_references() {
        let f = corpus(
            "fn f(m: &mut R) { m.set(\"kv_bytes\", 1); }",
            "fn t() { r.path(\"counters.kv_bytes\"); }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn registrations_inside_test_mods_do_not_count() {
        let f = corpus(
            "#[cfg(test)] mod tests { fn f(m: &mut R) { m.inc(\"net_t\", 1); } }",
            "fn t() { m.counter(\"net_t\"); }",
        );
        assert_eq!(f.len(), 1, "{f:?}");
    }
}
