//! `lookahead-lint`: repo-aware static analysis (DESIGN.md §9).
//!
//! Four lint families run over the lexed tree (see [`lexer`]):
//!
//! * `lock-order` / `lock-inventory` — every `.lock()` site resolves
//!   against the declared inventory ([`inventory`]), and the
//!   acquired-while-held graph must strictly ascend in rank
//!   ([`lock_order`]). The runtime twin is the `debug_assertions` rank
//!   tracker in [`crate::util::sync`] — same hierarchy, enforced live.
//! * `struct-literal` — config/request structs are built via
//!   builders/`Default` outside their defining module ([`invariants`]).
//! * `wall-clock` — deterministic modules derive time from seeded
//!   schedules, never the host clock ([`invariants`]).
//! * `hot-unwrap` — shrink-only unwrap/expect/panic budget on hot-path
//!   files, pinned by `rust/lint_baseline.json` ([`invariants`]).
//! * `metrics-name` — test-asserted metric names and registered
//!   `ctl_*`/`net_*`/`kv_*`/`trace_*` counters cross-check
//!   ([`metrics_check`]).
//!
//! Escape hatch: `// lint: allow(<id>) reason=<why>` on the finding's
//! line or the line above; the reason is mandatory (`lint-allow` fires on
//! a bare allow). The `lookahead-lint` binary walks the tree, prints
//! findings, and exits non-zero — the CI `lint` lane enforces it.

pub mod inventory;
pub mod invariants;
pub mod lexer;
pub mod lock_order;
pub mod metrics_check;

use crate::util::json::Json;
use lexer::Lexed;
use std::collections::BTreeMap;
use std::path::Path;

/// Lint ids a `lint: allow(...)` directive may name.
pub const KNOWN_LINTS: &[&str] = &[
    "lock-order",
    "lock-inventory",
    "struct-literal",
    "wall-clock",
    "hot-unwrap",
    "metrics-name",
    "lint-allow",
];

#[derive(Debug, Clone)]
pub struct Finding {
    pub lint: &'static str,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl Finding {
    pub fn new(lint: &'static str, file: &str, line: u32, msg: String) -> Finding {
        Finding { lint, file: file.to_string(), line, msg }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lint", Json::str(self.lint)),
            ("file", Json::str(self.file.as_str())),
            ("line", Json::num(self.line as f64)),
            ("msg", Json::str(self.msg.as_str())),
        ])
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.msg)
    }
}

/// One source file, path `/`-normalized (suffix-matched by every scope
/// rule, so absolute or repo-relative both work).
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// Is a finding on `line` waived by an allow directive for `lint` on the
/// same line or the line above?
pub(crate) fn allowed(lexed: &Lexed, lint: &str, line: u32) -> bool {
    lexed.allows.iter().any(|a| a.lint == lint && (a.line == line || a.line + 1 == line))
}

/// Read every `.rs` file under `root`, skipping vendored code, build
/// output, and the deliberately-bad lint fixtures.
pub fn load_tree(root: &Path) -> std::io::Result<Vec<SourceFile>> {
    let mut out = Vec::new();
    walk(root, &mut out)?;
    out.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name == "vendor" || name == "target" || name == "lint_fixtures"
                || name.starts_with('.')
            {
                continue;
            }
            walk(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(SourceFile {
                path: path.to_string_lossy().replace('\\', "/"),
                text: std::fs::read_to_string(&path)?,
            });
        }
    }
    Ok(())
}

/// Hot-path unwrap/expect/panic site counts per file (shrink-only budget:
/// the binary compares these against `rust/lint_baseline.json` and also
/// reports files now under budget so the baseline can be tightened).
pub fn hot_unwrap_counts(files: &[SourceFile]) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for f in files {
        if !invariants::is_hot_path(&f.path) {
            continue;
        }
        let lexed = lexer::lex(&f.text);
        out.insert(f.path.clone(), invariants::hot_unwrap_sites(&f.path, &lexed).len());
    }
    out
}

/// Budget for `path` from a baseline keyed by repo-relative paths —
/// matched by suffix in either direction so absolute corpus paths work.
pub fn baseline_budget(baseline: &BTreeMap<String, usize>, path: &str) -> usize {
    baseline
        .iter()
        .find(|(k, _)| path.ends_with(k.as_str()) || k.ends_with(path))
        .map(|(_, v)| *v)
        .unwrap_or(0)
}

/// Run every lint over the corpus. `baseline` caps hot-path unwrap counts
/// per file (shrink-only: counts above budget are findings, below budget
/// is the binary's cue to tighten the committed baseline).
pub fn run(files: &[SourceFile], baseline: &BTreeMap<String, usize>) -> Vec<Finding> {
    let lexed: Vec<(String, Lexed)> =
        files.iter().map(|f| (f.path.clone(), lexer::lex(&f.text))).collect();
    let mut findings = Vec::new();

    // lock discipline: everything except the tracker itself (its tests
    // violate order on purpose, under catch_unwind)
    let lock_corpus: Vec<(String, Lexed)> = lexed
        .iter()
        .filter(|(p, _)| !p.ends_with("util/sync.rs"))
        .cloned()
        .collect();
    findings.extend(lock_order::check(&lock_corpus));

    for (path, l) in &lexed {
        findings.extend(invariants::check_struct_literals(path, l));
        if invariants::in_wall_clock_scope(path) {
            findings.extend(invariants::check_wall_clock(path, l));
        }
        findings.extend(invariants::check_allow_reasons(path, l));
        for a in &l.allows {
            if !KNOWN_LINTS.contains(&a.lint.as_str()) {
                findings.push(Finding::new(
                    "lint-allow",
                    path,
                    a.line,
                    format!("`lint: allow({})` names an unknown lint", a.lint),
                ));
            }
        }
        if invariants::is_hot_path(path) {
            let sites = invariants::hot_unwrap_sites(path, l);
            let budget = baseline_budget(baseline, path);
            if sites.len() > budget {
                let msg = format!(
                    "{} unwrap/expect/panic sites exceed the shrink-only \
                     baseline of {budget}",
                    sites.len()
                );
                for mut s in sites {
                    s.msg = format!("{} ({msg})", s.msg);
                    findings.push(s);
                }
            }
        }
    }

    let src: Vec<(String, Lexed)> =
        lexed.iter().filter(|(p, _)| p.contains("/src/")).cloned().collect();
    let refs: Vec<(String, Lexed)> = lexed
        .iter()
        .filter(|(p, _)| p.contains("/tests/") || p.ends_with("bench/load.rs"))
        .cloned()
        .collect();
    findings.extend(metrics_check::check(&src, &refs));

    findings.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    findings
}

/// Parse `rust/lint_baseline.json` (`{"<path>": <count>, …}`).
pub fn parse_baseline(text: &str) -> Result<BTreeMap<String, usize>, String> {
    let j = Json::parse(text).map_err(|e| e.to_string())?;
    let obj = j.as_obj().ok_or("baseline must be a JSON object")?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        let n = v.as_usize().ok_or_else(|| format!("count for {k} must be a number"))?;
        out.insert(k.clone(), n);
    }
    Ok(out)
}

/// Findings artifact for the CI lane.
pub fn findings_json(findings: &[Finding]) -> Json {
    let mut by_lint: BTreeMap<&str, usize> = BTreeMap::new();
    for f in findings {
        *by_lint.entry(f.lint).or_insert(0) += 1;
    }
    Json::obj(vec![
        ("schema", Json::str("lookahead-lint/v1")),
        ("total", Json::num(findings.len() as f64)),
        (
            "by_lint",
            Json::Obj(
                by_lint
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), Json::num(v as f64)))
                    .collect(),
            ),
        ),
        ("findings", Json::arr(findings.iter().map(Finding::to_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.to_string(), text: text.to_string() }
    }

    #[test]
    fn run_composes_all_lints() {
        let files = vec![
            file(
                "rust/src/server/scheduler.rs",
                "fn f(&self) { let m = metrics.lock(); self.state.lock().touch(); }",
            ),
            file("rust/src/bench/load.rs", "fn f() { let t = Instant::now(); }"),
            file("rust/tests/t.rs", "fn t() { let c = Request { prompt: p }; }"),
        ];
        let f = run(&files, &BTreeMap::new());
        let lints: Vec<&str> = f.iter().map(|f| f.lint).collect();
        assert!(lints.contains(&"lock-order"), "{f:?}");
        assert!(lints.contains(&"wall-clock"), "{f:?}");
        assert!(lints.contains(&"struct-literal"), "{f:?}");
    }

    #[test]
    fn baseline_budget_suffix_matches() {
        let mut b = BTreeMap::new();
        b.insert("rust/src/server/worker.rs".to_string(), 3);
        assert_eq!(baseline_budget(&b, "/abs/repo/rust/src/server/worker.rs"), 3);
        assert_eq!(baseline_budget(&b, "rust/src/net/mod.rs"), 0);
    }

    #[test]
    fn findings_json_schema() {
        let f = vec![Finding::new("wall-clock", "a.rs", 3, "msg".into())];
        let j = findings_json(&f);
        assert_eq!(j.path("schema").unwrap().as_str(), Some("lookahead-lint/v1"));
        assert_eq!(j.path("total").unwrap().as_usize(), Some(1));
        assert_eq!(j.path("by_lint.wall-clock").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn baseline_parses_and_rejects_junk() {
        let b = parse_baseline("{\"rust/src/net/mod.rs\": 2}").unwrap();
        assert_eq!(b.get("rust/src/net/mod.rs"), Some(&2));
        assert!(parse_baseline("[1]").is_err());
    }
}
