//! Declared lock inventory for the lock-order checker.
//!
//! Every `RankedMutex` in the tree is declared here as (file, receiver
//! ident) → (lock id, rank), mirroring the runtime registration in
//! [`crate::util::sync`]: the id matches the `name` passed to
//! `RankedMutex::new`, the rank matches its `rank::*` constant. The static
//! checker resolves each `.lock()` site against this table; a site whose
//! receiver is not listed is a `lock-inventory` finding, which is what
//! keeps the table complete as the tree grows (DESIGN.md §9).

use crate::util::sync::rank;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LockRef {
    pub id: &'static str,
    pub rank: u8,
}

/// (file suffix, receiver ident, lock). An empty file suffix applies in
/// any file — used for the metrics registry, which crosses module
/// boundaries behind `Arc<RankedMutex<Registry>>`.
const INVENTORY: &[(&str, &str, LockRef)] = &[
    // -- setup --------------------------------------------------------------
    ("runtime/sim.rs", "ENSURE_LOCK", LockRef { id: "sim.ensure", rank: rank::SETUP }),
    // -- rebalance hub ------------------------------------------------------
    ("server/scheduler.rs", "st", LockRef { id: "hub.st", rank: rank::HUB }),
    ("server/scheduler.rs", "remote", LockRef { id: "hub.remote", rank: rank::HUB }),
    // -- scheduler / admission ----------------------------------------------
    ("server/scheduler.rs", "state", LockRef { id: "sched.state", rank: rank::SCHED }),
    // -- pending-reply tables -----------------------------------------------
    ("server/server.rs", "pending", LockRef { id: "srv.pending", rank: rank::PENDING }),
    ("server/server.rs", "pending_c",
     LockRef { id: "srv.pending", rank: rank::PENDING }),
    ("server/server.rs", "remote_cancels",
     LockRef { id: "srv.remote_cancels", rank: rank::PENDING }),
    ("server/server.rs", "rc_c",
     LockRef { id: "srv.remote_cancels", rank: rank::PENDING }),
    ("server/server.rs", "relay_joins",
     LockRef { id: "srv.relay_joins", rank: rank::PENDING }),
    // -- cancellation rendezvous --------------------------------------------
    ("server/scheduler.rs", "ids", LockRef { id: "cancel.ids", rank: rank::CANCEL }),
    // -- kv -----------------------------------------------------------------
    ("kv/prefix.rs", "inner", LockRef { id: "kv.prefix", rank: rank::KV }),
    // -- shared n-gram pools ------------------------------------------------
    ("ngram/shared.rs", "caches",
     LockRef { id: "ngram.registry", rank: rank::NGRAM_REGISTRY }),
    ("ngram/shared.rs", "shards",
     LockRef { id: "ngram.shard", rank: rank::NGRAM_SHARD }),
    ("ngram/shared.rs", "shard_for",
     LockRef { id: "ngram.shard", rank: rank::NGRAM_SHARD }),
    ("ngram/shared.rs", "s", LockRef { id: "ngram.shard", rank: rank::NGRAM_SHARD }),
    // -- leaves -------------------------------------------------------------
    ("server/server.rs", "net_cuts", LockRef { id: "net.cuts", rank: rank::LEAF }),
    ("server/worker.rs", "m", LockRef { id: "metrics.registry", rank: rank::LEAF }),
    ("server/worker.rs", "reg", LockRef { id: "metrics.registry", rank: rank::LEAF }),
    ("trace/mod.rs", "shard", LockRef { id: "trace.shard", rank: rank::LEAF }),
    ("trace/mod.rs", "shards", LockRef { id: "trace.shard", rank: rank::LEAF }),
    ("net/mod.rs", "cuts", LockRef { id: "net.cuts", rank: rank::LEAF }),
    ("net/mod.rs", "st", LockRef { id: "net.relay_buf", rank: rank::LEAF }),
    ("net/mod.rs", "roster", LockRef { id: "net.peers", rank: rank::LEAF }),
    ("net/mod.rs", "table", LockRef { id: "net.xfer_table", rank: rank::LEAF }),
    ("tests/net.rs", "payloads", LockRef { id: "test.payloads", rank: rank::LEAF }),
    ("tests/net.rs", "cancelled", LockRef { id: "test.cancelled", rank: rank::LEAF }),
    ("", "metrics", LockRef { id: "metrics.registry", rank: rank::LEAF }),
    ("", "metrics_c", LockRef { id: "metrics.registry", rank: rank::LEAF }),
];

/// Resolve a `.lock()` receiver ident in `file` (a `/`-normalized path).
/// File-specific entries win over the file-agnostic fallbacks.
pub fn resolve(file: &str, ident: &str) -> Option<LockRef> {
    let hit = INVENTORY
        .iter()
        .find(|(f, id, _)| !f.is_empty() && file.ends_with(f) && *id == ident);
    match hit {
        Some((_, _, l)) => Some(*l),
        None => INVENTORY
            .iter()
            .find(|(f, id, _)| f.is_empty() && *id == ident)
            .map(|(_, _, l)| *l),
    }
}

/// Every declared lock id with its rank — the hierarchy table the design
/// doc and the findings report print.
pub fn all() -> Vec<LockRef> {
    let mut out: Vec<LockRef> = Vec::new();
    for (_, _, l) in INVENTORY {
        if !out.iter().any(|o| o.id == l.id) {
            out.push(*l);
        }
    }
    out.sort_by_key(|l| (l.rank, l.id));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_specific_beats_global() {
        let st = resolve("rust/src/net/mod.rs", "st").unwrap();
        assert_eq!(st.id, "net.relay_buf");
        let hub = resolve("rust/src/server/scheduler.rs", "st").unwrap();
        assert_eq!(hub.id, "hub.st");
        let m = resolve("rust/src/anywhere.rs", "metrics").unwrap();
        assert_eq!(m.id, "metrics.registry");
        assert!(resolve("rust/src/anywhere.rs", "mystery").is_none());
    }

    #[test]
    fn hierarchy_is_strictly_ranked_at_the_top() {
        let all = all();
        assert!(all.len() >= 10, "inventory should cover the tree: {all:?}");
        assert_eq!(all.first().unwrap().id, "sim.ensure");
        assert!(all.iter().filter(|l| l.rank == rank::LEAF).count() >= 5);
    }
}
