//! Span-level tracing substrate (L3 observability — DESIGN.md §8).
//!
//! A [`Tracer`] is a lock-sharded, bounded span recorder: every worker (plus
//! one lane for the net transport and one for the server/dispatcher) owns a
//! shard, so recording a span takes one uncontended mutex on the recording
//! thread's own lane. Each shard is a fixed-capacity ring — when it fills,
//! the **oldest** span is dropped and counted, never blocking and never
//! growing. All timestamps are microsecond offsets from the tracer's epoch
//! (one monotonic [`Instant`] captured at construction), so spans from
//! different threads of one process order correctly without clock reads
//! beyond `Instant::elapsed`.
//!
//! Identity: a `trace_id` is minted per admitted session ([`Tracer::mint`],
//! subject to `--trace-sample N` — every Nth admission traces; a
//! per-request `"trace": true` flag forces it). The id rides inside
//! `ParkedSession`/`MigratedSession` and the PR 8 wire meta, so a session
//! that parks, revives, rebalances, or crosses a process boundary keeps one
//! id and its spans stitch into a single timeline ([`merge_chrome`]).
//! `trace_id == 0` means "not traced": every recording site guards on it,
//! so sampled-out sessions cost one branch on the decode path and tracing
//! disabled (`Tracer` absent) costs nothing at all.
//!
//! Export: [`Tracer::chrome_json`] renders the Chrome trace-event format
//! (`chrome://tracing` / Perfetto-loadable; `ph:"X"` complete events,
//! pid=process, tid=worker lane, args carry the engine/session tags);
//! [`validate_trace_json`] is the schema gate CI runs on the dumped file;
//! [`trace_section`] folds a trace into the BENCH `"trace"` section.
//!
//! Span taxonomy (name / cat — the full table is DESIGN.md §8):
//! `admit`/session, `prefill`/prefill (args: `mode` cold|fork), `plan` +
//! `launch`/decode (batch grouping + fused step), `round`/decode (per
//! session per scheduling round; args: engine, steps, tokens), `park` +
//! `revive`/kv, `decide` + `switch`/ctl, `transfer` + `adopt` + `relay` +
//! `attach`/net.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::metrics::Histogram;
use crate::util::json::Json;
use crate::util::sync::{rank, RankedMutex};

/// Default per-shard ring capacity (`--trace-buf`).
pub const DEFAULT_TRACE_BUF: usize = 65_536;

/// Cap on a per-request timeline accumulator (the compact `"timeline"`
/// section on the final record) — long generations keep the newest entries.
pub const TIMELINE_CAP: usize = 256;

/// `trace_id` wire form: fixed-width hex (u64 doesn't survive the f64-backed
/// JSON number path above 2^53).
pub fn hex_id(v: u64) -> String {
    format!("{v:016x}")
}

/// Parse a [`hex_id`] string back; `None` on malformed input.
pub fn parse_hex_id(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

/// One completed span: a named, categorized interval on a worker lane,
/// tagged with the session's `trace_id` (0 = process-level span) and a
/// small set of string args.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: String,
    pub cat: String,
    pub trace_id: u64,
    /// lane: worker id, or the tracer's net/main lanes.
    pub tid: usize,
    /// microseconds since the tracer epoch.
    pub start_us: u64,
    pub dur_us: u64,
    pub args: Vec<(String, String)>,
}

impl Span {
    /// Chainable tag: `tracer.span(..).arg("engine", tag)`.
    pub fn arg(mut self, k: impl Into<String>, v: impl Into<String>) -> Span {
        self.args.push((k.into(), v.into()));
        self
    }
}

#[derive(Default)]
struct Shard {
    ring: VecDeque<Span>,
    recorded: u64,
    dropped: u64,
}

/// The shared span recorder. One per server process, behind an
/// `Option<Arc<Tracer>>` — `None` is "tracing disabled" and costs callers a
/// single `if let` per site.
pub struct Tracer {
    epoch: Instant,
    pid: u64,
    sample: u64,
    cap: usize,
    workers: usize,
    /// [`rank::LEAF`]: trace shards are locked one at a time, with no other
    /// lock acquired underneath — same leaf tier as the metrics registry.
    shards: Vec<RankedMutex<Shard>>,
    admitted: AtomicU64,
    next_trace: AtomicU64,
}

impl Tracer {
    /// `workers` worker lanes plus two extra shards: [`Tracer::net_tid`] for
    /// the transport/relay threads and [`Tracer::main_tid`] for the
    /// server/dispatcher. `sample` = trace every Nth admission (0 and 1 both
    /// mean "every"); `cap` = per-shard ring capacity.
    pub fn new(workers: usize, sample: u64, cap: usize) -> Tracer {
        Tracer {
            epoch: Instant::now(),
            pid: std::process::id() as u64,
            sample: sample.max(1),
            cap: cap.max(1),
            workers,
            shards: (0..workers + 2)
                .map(|_| RankedMutex::new(rank::LEAF, "trace.shard", Shard::default()))
                .collect(),
            admitted: AtomicU64::new(0),
            next_trace: AtomicU64::new(0),
        }
    }

    /// Lane for net transport/relay spans.
    pub fn net_tid(&self) -> usize {
        self.workers
    }

    /// Lane for server/dispatcher spans.
    pub fn main_tid(&self) -> usize {
        self.workers + 1
    }

    /// Microseconds since the tracer epoch (monotonic).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Mint a `trace_id` at admission. Every `sample`-th admission traces;
    /// `force` (the per-request `"trace"` flag) always does. Returns 0 for
    /// sampled-out sessions — the universal "not traced" guard value.
    pub fn mint(&self, force: bool) -> u64 {
        let k = self.admitted.fetch_add(1, Ordering::Relaxed);
        if !force && k % self.sample != 0 {
            return 0;
        }
        let n = self.next_trace.fetch_add(1, Ordering::Relaxed) + 1;
        (self.pid << 32) | (n & 0xffff_ffff)
    }

    /// Build a completed span whose interval is `[start_us, now]`. The
    /// caller captured `start_us` via [`Tracer::now_us`] before the work.
    pub fn span(&self, tid: usize, trace_id: u64, name: &str, cat: &str,
                start_us: u64) -> Span {
        Span {
            name: name.to_string(),
            cat: cat.to_string(),
            trace_id,
            tid,
            start_us,
            dur_us: self.now_us().saturating_sub(start_us),
            args: Vec::new(),
        }
    }

    /// RAII variant: records the span when the guard drops.
    pub fn guard(&self, tid: usize, trace_id: u64, name: &str,
                 cat: &str) -> SpanGuard<'_> {
        SpanGuard {
            t: self,
            span: Some(Span {
                name: name.to_string(),
                cat: cat.to_string(),
                trace_id,
                tid,
                start_us: self.now_us(),
                dur_us: 0,
                args: Vec::new(),
            }),
        }
    }

    /// Record a completed span into its lane's ring. Full ring: drop the
    /// oldest span and count it — recording never blocks on capacity.
    pub fn push(&self, span: Span) {
        let shard = &self.shards[span.tid % self.shards.len()];
        let mut s = shard.lock();
        s.recorded += 1;
        if s.ring.len() >= self.cap {
            s.ring.pop_front();
            s.dropped += 1;
        }
        s.ring.push_back(span);
    }

    /// (recorded, dropped) totals across all shards.
    pub fn stats(&self) -> (u64, u64) {
        let mut rec = 0;
        let mut drop = 0;
        for shard in &self.shards {
            let s = shard.lock();
            rec += s.recorded;
            drop += s.dropped;
        }
        (rec, drop)
    }

    /// Non-destructive copy of every retained span, time-ordered.
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(shard.lock().ring.iter().cloned());
        }
        out.sort_by(|a, b| (a.start_us, a.tid).cmp(&(b.start_us, b.tid)));
        out
    }

    /// Render the Chrome trace-event JSON (the `--trace-out` /
    /// `{"trace": true}` payload): `traceEvents` of `ph:"X"` complete
    /// events plus a `stats` block viewers ignore.
    pub fn chrome_json(&self) -> Json {
        let (recorded, dropped) = self.stats();
        let events = self.snapshot().iter().map(|s| span_event(self.pid, s)).collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            ("stats", Json::obj(vec![
                ("pid", Json::num(self.pid as f64)),
                ("recorded", Json::num(recorded as f64)),
                ("dropped", Json::num(dropped as f64)),
            ])),
        ])
    }
}

fn span_event(pid: u64, s: &Span) -> Json {
    let mut args: BTreeMap<String, Json> = s
        .args
        .iter()
        .map(|(k, v)| (k.clone(), Json::str(v.clone())))
        .collect();
    if s.trace_id != 0 {
        args.insert("trace_id".to_string(), Json::str(hex_id(s.trace_id)));
    }
    Json::obj(vec![
        ("name", Json::str(s.name.clone())),
        ("cat", Json::str(s.cat.clone())),
        ("ph", Json::str("X")),
        ("ts", Json::num(s.start_us as f64)),
        ("dur", Json::num(s.dur_us as f64)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(s.tid as f64)),
        ("args", Json::Obj(args)),
    ])
}

/// RAII span: finalizes its duration and records when dropped (scope exit).
pub struct SpanGuard<'a> {
    t: &'a Tracer,
    span: Option<Span>,
}

impl SpanGuard<'_> {
    pub fn add_arg(&mut self, k: impl Into<String>, v: impl Into<String>) {
        if let Some(s) = self.span.as_mut() {
            s.args.push((k.into(), v.into()));
        }
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(mut s) = self.span.take() {
            s.dur_us = self.t.now_us().saturating_sub(s.start_us);
            self.t.push(s);
        }
    }
}

/// Schema gate for a Chrome trace-event JSON blob (CI's
/// `serve_bench --validate-trace`): a `traceEvents` array of complete
/// (`ph:"X"`) events, each carrying name/cat/ph strings and numeric
/// ts/dur/pid/tid.
pub fn validate_trace_json(text: &str) -> Result<()> {
    let j = Json::parse(text).map_err(|e| anyhow!("malformed json: {e}"))?;
    let events = j
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'traceEvents' array"))?;
    for (i, ev) in events.iter().enumerate() {
        for key in ["name", "cat", "ph"] {
            ev.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("event {i}: missing string '{key}'"))?;
        }
        if ev.get("ph").and_then(Json::as_str) != Some("X") {
            bail!("event {i}: only complete ('X') events are emitted");
        }
        for key in ["ts", "dur", "pid", "tid"] {
            ev.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("event {i}: missing number '{key}'"))?;
        }
    }
    Ok(())
}

/// Stitch per-process Chrome traces into one: concatenate `traceEvents`
/// (each event keeps its own pid, so viewers show one track group per
/// process) and sum the `stats` blocks. Events re-sort by timestamp; the
/// processes' epochs differ, so cross-process ordering is approximate —
/// within a process it is exact.
pub fn merge_chrome(parts: &[Json]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut recorded = 0.0;
    let mut dropped = 0.0;
    for p in parts {
        if let Some(evs) = p.get("traceEvents").and_then(Json::as_arr) {
            events.extend(evs.iter().cloned());
        }
        recorded += p.path("stats.recorded").and_then(Json::as_f64).unwrap_or(0.0);
        dropped += p.path("stats.dropped").and_then(Json::as_f64).unwrap_or(0.0);
    }
    events.sort_by(|a, b| {
        let ta = a.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
        let tb = b.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
        ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
    });
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        ("stats", Json::obj(vec![
            ("recorded", Json::num(recorded)),
            ("dropped", Json::num(dropped)),
        ])),
    ])
}

/// Fold a Chrome trace into the BENCH `"trace"` section: span totals plus
/// per-phase (span cat) duration summaries in milliseconds.
pub fn trace_section(chrome: &Json) -> Json {
    let mut phases: BTreeMap<String, Histogram> = BTreeMap::new();
    let events = chrome.get("traceEvents").and_then(Json::as_arr).unwrap_or(&[]);
    for ev in events {
        let cat = ev.get("cat").and_then(Json::as_str).unwrap_or("?");
        let dur_ms =
            ev.get("dur").and_then(Json::as_f64).unwrap_or(0.0) / 1000.0;
        phases.entry(cat.to_string()).or_default().record(dur_ms);
    }
    let phase_json: BTreeMap<String, Json> = phases
        .into_iter()
        .map(|(k, mut h)| {
            let s = h.summarize();
            (k, Json::obj(vec![
                ("count", Json::num(s.count as f64)),
                ("mean_ms", Json::num(s.mean)),
                ("p99_ms", Json::num(s.p99)),
            ]))
        })
        .collect();
    Json::obj(vec![
        ("spans", Json::num(events.len() as f64)),
        ("recorded",
         Json::num(chrome.path("stats.recorded").and_then(Json::as_f64)
             .unwrap_or(0.0))),
        ("dropped",
         Json::num(chrome.path("stats.dropped").and_then(Json::as_f64)
             .unwrap_or(0.0))),
        ("phases", Json::Obj(phase_json)),
    ])
}

/// The compact per-request `"timeline"` on a final record: the session's
/// accumulated spans as `[{name, cat, ts_us, dur_us}]`.
pub fn timeline_json(spans: &[Span]) -> Json {
    Json::Arr(
        spans
            .iter()
            .map(|s| Json::obj(vec![
                ("name", Json::str(s.name.clone())),
                ("cat", Json::str(s.cat.clone())),
                ("ts_us", Json::num(s.start_us as f64)),
                ("dur_us", Json::num(s.dur_us as f64)),
            ]))
            .collect(),
    )
}

/// Bounded push for a per-request timeline accumulator: keeps the newest
/// [`TIMELINE_CAP`] entries.
pub fn timeline_push(tl: &mut Vec<Span>, span: Span) {
    if tl.len() >= TIMELINE_CAP {
        tl.remove(0);
    }
    tl.push(span);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spanned(t: &Tracer, tid: usize, trace_id: u64, name: &str, ts: u64) -> Span {
        Span {
            name: name.to_string(),
            cat: "test".to_string(),
            trace_id,
            tid,
            start_us: ts,
            dur_us: 5,
            args: Vec::new(),
        }
    }

    #[test]
    fn mint_samples_every_nth_and_force_overrides() {
        let t = Tracer::new(1, 3, 16);
        let ids: Vec<u64> = (0..6).map(|_| t.mint(false)).collect();
        assert_ne!(ids[0], 0, "admission 0 must trace under sample 3");
        assert_eq!(ids[1], 0);
        assert_eq!(ids[2], 0);
        assert_ne!(ids[3], 0);
        assert_eq!(ids[4], 0);
        assert_ne!(t.mint(true), 0, "the per-request flag must force a mint");
        let a = t.mint(true);
        let b = t.mint(true);
        assert_ne!(a, b, "minted ids must be unique");
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let t = Tracer::new(1, 1, 4);
        for i in 0..10u64 {
            t.push(spanned(&t, 0, 1, &format!("s{i}"), i));
        }
        let (recorded, dropped) = t.stats();
        assert_eq!(recorded, 10);
        assert_eq!(dropped, 6);
        let snap = t.snapshot();
        assert_eq!(snap.len(), 4, "ring must hold exactly its capacity");
        let names: Vec<&str> = snap.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["s6", "s7", "s8", "s9"],
                   "overflow must drop the oldest spans");
    }

    #[test]
    fn snapshot_orders_across_shards() {
        let t = Tracer::new(2, 1, 16);
        t.push(spanned(&t, 1, 1, "late", 100));
        t.push(spanned(&t, 0, 1, "early", 10));
        t.push(spanned(&t, t.net_tid(), 0, "mid", 50));
        let names: Vec<&str> = t.snapshot().iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["early", "mid", "late"]);
    }

    #[test]
    fn chrome_json_is_schema_valid_and_carries_tags() {
        let t = Tracer::new(1, 1, 16);
        let tid0 = t.now_us();
        let sp = t.span(0, 7, "prefill", "prefill", tid0).arg("mode", "cold");
        t.push(sp);
        let j = t.chrome_json();
        validate_trace_json(&j.dump()).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        let ev = &evs[0];
        assert_eq!(ev.get("name").unwrap().as_str(), Some("prefill"));
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(ev.path("args.mode").unwrap().as_str(), Some("cold"));
        assert_eq!(ev.path("args.trace_id").unwrap().as_str(),
                   Some(hex_id(7).as_str()));
        assert_eq!(j.path("stats.recorded").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn guard_records_on_drop() {
        let t = Tracer::new(1, 1, 16);
        {
            let mut g = t.guard(0, 3, "round", "decode");
            g.add_arg("steps", "4");
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].name, "round");
        assert_eq!(snap[0].trace_id, 3);
        assert_eq!(snap[0].args, vec![("steps".to_string(), "4".to_string())]);
    }

    #[test]
    fn validator_rejects_bad_blobs() {
        assert!(validate_trace_json("not json").is_err());
        assert!(validate_trace_json(r#"{"foo": 1}"#).is_err());
        assert!(validate_trace_json(
            r#"{"traceEvents": [{"name": "x", "cat": "c", "ph": "B",
                "ts": 0, "dur": 1, "pid": 1, "tid": 0}]}"#
        )
        .is_err());
        assert!(validate_trace_json(
            r#"{"traceEvents": [{"name": "x", "cat": "c", "ph": "X",
                "ts": 0, "pid": 1, "tid": 0}]}"#
        )
        .is_err());
        validate_trace_json(r#"{"traceEvents": []}"#).unwrap();
    }

    #[test]
    fn merge_stitches_and_sums_stats() {
        let a = Tracer::new(1, 1, 16);
        a.push(spanned(&a, 0, 9, "prefill", 20));
        let b = Tracer::new(1, 1, 2);
        for i in 0..4u64 {
            b.push(spanned(&b, 0, 9, "round", 30 + i));
        }
        let merged = merge_chrome(&[a.chrome_json(), b.chrome_json()]);
        validate_trace_json(&merged.dump()).unwrap();
        let evs = merged.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 3, "1 span + 2 retained after overflow");
        assert_eq!(merged.path("stats.recorded").unwrap().as_usize(), Some(5));
        assert_eq!(merged.path("stats.dropped").unwrap().as_usize(), Some(2));
        // stitched: the shared trace_id appears in events from both parts
        let ids: Vec<&str> = evs
            .iter()
            .filter_map(|e| e.path("args.trace_id").and_then(Json::as_str))
            .collect();
        assert!(ids.iter().all(|&s| s == hex_id(9)), "{ids:?}");
    }

    #[test]
    fn trace_section_summarizes_phases() {
        let t = Tracer::new(1, 1, 16);
        let mut p = spanned(&t, 0, 1, "prefill", 0);
        p.cat = "prefill".into();
        p.dur_us = 2000;
        t.push(p);
        let mut r = spanned(&t, 0, 1, "round", 10);
        r.cat = "decode".into();
        r.dur_us = 1000;
        t.push(r);
        let sec = trace_section(&t.chrome_json());
        assert_eq!(sec.get("spans").unwrap().as_usize(), Some(2));
        assert_eq!(sec.path("phases.prefill.count").unwrap().as_usize(), Some(1));
        assert_eq!(sec.path("phases.prefill.mean_ms").unwrap().as_f64(), Some(2.0));
        assert_eq!(sec.path("phases.decode.p99_ms").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn timeline_push_is_bounded() {
        let t = Tracer::new(1, 1, 16);
        let mut tl = Vec::new();
        for i in 0..(TIMELINE_CAP as u64 + 10) {
            timeline_push(&mut tl, spanned(&t, 0, 1, &format!("e{i}"), i));
        }
        assert_eq!(tl.len(), TIMELINE_CAP);
        assert_eq!(tl[0].name, "e10", "bounded push keeps the newest entries");
        let j = timeline_json(&tl);
        assert_eq!(j.as_arr().unwrap().len(), TIMELINE_CAP);
        assert_eq!(j.as_arr().unwrap()[0].get("name").unwrap().as_str(),
                   Some("e10"));
    }

    #[test]
    fn hex_id_round_trips() {
        for v in [0u64, 1, u64::MAX, 0xdead_beef_0000_0001] {
            assert_eq!(parse_hex_id(&hex_id(v)), Some(v));
        }
        assert_eq!(parse_hex_id("zz"), None);
    }
}
