//! Adaptive speculation control (L3): pick and re-tune the decoding engine
//! *live*, per session.
//!
//! The paper fixes the engine and its (W,N,G) statically per request, but
//! the right FLOPs-for-steps trade point depends on the workload and drifts
//! within a single generation. This module closes the loop the serving
//! layer already measures: a [`Controller`] observes per-step accept
//! lengths at every commit boundary (plus warm/cold signals from the shared
//! n-gram registry) and issues [`EngineSwitch`] decisions; the worker
//! applies them through [`switch_session`], which rides the existing
//! suspend/resume machinery — suspend to a [`SessionSnapshot`], swap the
//! engine state, resume — so the committed prefix stays byte-identical and
//! a switch works mid-stream, across parks, and across rebalance hand-offs.
//!
//! Switching is restricted to **greedy** sessions: all five engines are
//! byte-exact w.r.t. autoregressive greedy decoding, so the controller can
//! never change output bytes, only the step count that produces them.
//! (Sampled sessions consume per-engine RNG streams; a switch would change
//! the sampled continuation, so the worker never offers them for control.)
//!
//! Policy (see DESIGN.md §6): per-session EWMA of the accept length with a
//! hysteresis band [`low`, `high`] plus warmup/cooldown round counts.
//! Below `low` a speculative engine is not earning its extra FLOPs — step
//! down its ladder and eventually fall back to autoregressive. Above
//! `high`, step up (wider lookahead level, wider spec gamma). A warm
//! tenant n-gram cache promotes autoregressive sessions to prompt_lookup,
//! the cheapest draft-free speculator over shared history.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::engine::DecodeSession;
use crate::kv::{EngineState, SessionSnapshot};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// A concrete engine configuration a session can run under — the
/// controller's unit of choice. Levels mirror `Worker::make_engine`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineLevel {
    Autoregressive,
    Lookahead { w: usize, n: usize, g: usize },
    Jacobi { k: usize },
    PromptLookup { k: usize, match_len: usize },
    SpecDecode { gamma: usize },
}

impl EngineLevel {
    /// The request-method family this level belongs to (stable wire tag,
    /// also the `accept_len_{method}` histogram suffix).
    pub fn method(&self) -> &'static str {
        match self {
            EngineLevel::Autoregressive => "autoregressive",
            EngineLevel::Lookahead { .. } => "lookahead",
            EngineLevel::Jacobi { .. } => "jacobi",
            EngineLevel::PromptLookup { .. } => "prompt_lookup",
            EngineLevel::SpecDecode { .. } => "spec_decode",
        }
    }

    /// Human/log tag pinning the full level, e.g. `lookahead:w5n3g5`.
    pub fn tag(&self) -> String {
        match self {
            EngineLevel::Autoregressive => "autoregressive".into(),
            EngineLevel::Lookahead { w, n, g } => format!("lookahead:w{w}n{n}g{g}"),
            EngineLevel::Jacobi { k } => format!("jacobi:k{k}"),
            EngineLevel::PromptLookup { k, match_len } => {
                format!("prompt_lookup:k{k}m{match_len}")
            }
            EngineLevel::SpecDecode { gamma } => format!("spec_decode:g{gamma}"),
        }
    }
}

/// Per-session controller bookkeeping that lives OUTSIDE the session and
/// its snapshot: the encoded prompt ids (history-backed switch targets
/// rebuild `prompt + committed output` from them), the tenant (scopes the
/// warm-cache signal), and the session's effective controller mode. The
/// serving layer threads this through parks and cross-worker migrations so
/// a switch can land wherever the session is currently being driven.
#[derive(Debug, Clone)]
pub struct CtlCarry {
    pub prompt_ids: Vec<u32>,
    pub tenant: Option<String>,
    /// effective mode (server default + per-request override), already
    /// gated on greedy sampling — only greedy sessions may switch.
    pub adaptive: bool,
}

/// The [`EngineLevel`] a suspended session's snapshot encodes — how a
/// revived or adopted session re-enters controller tracking without its
/// original request in hand.
pub fn level_from_state(engine: &EngineState) -> EngineLevel {
    match engine {
        EngineState::Autoregressive { .. } => EngineLevel::Autoregressive,
        EngineState::Lookahead { w, n, g, .. } => {
            EngineLevel::Lookahead { w: *w, n: *n, g: *g }
        }
        EngineState::Jacobi { k, .. } => EngineLevel::Jacobi { k: *k },
        EngineState::PromptLookup { k, match_len, .. } => {
            EngineLevel::PromptLookup { k: *k, match_len: *match_len }
        }
        EngineState::SpecDecode { gamma, .. } => {
            EngineLevel::SpecDecode { gamma: *gamma }
        }
    }
}

/// One commit-boundary observation for a session: the stats deltas since
/// the controller last saw it, plus shared-registry signals.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundObs {
    /// decode steps the session took this round.
    pub steps: u64,
    /// tokens it committed this round.
    pub tokens: u64,
    /// the tenant's shared n-gram cache holds harvested entries (warm) —
    /// the promote-prompt_lookup signal.
    pub ngram_warm: bool,
}

/// A controller decision at a commit boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineSwitch {
    Stay,
    Switch(EngineLevel),
}

/// Live engine-selection policy. One controller instance serves every
/// session on its worker; per-session state is keyed by session id and
/// must be dropped via [`Controller::retire`] when the session ends.
///
/// `decide` is only ever called for greedy, suspendable sessions (the
/// worker filters), so any `Switch` it returns is safe to apply.
pub trait Controller {
    fn name(&self) -> &'static str;

    /// Observe one commit boundary and decide whether to switch engines.
    fn decide(&mut self, sid: u64, current: &EngineLevel, obs: &RoundObs)
              -> EngineSwitch;

    /// Forget a session (finished, failed, parked away for good).
    fn retire(&mut self, sid: u64);
}

/// The `--controller static` policy: never switches. The zero-overhead
/// baseline every adaptive run is compared against.
#[derive(Debug, Default)]
pub struct StaticController;

impl Controller for StaticController {
    fn name(&self) -> &'static str {
        "static"
    }

    fn decide(&mut self, _sid: u64, _current: &EngineLevel, _obs: &RoundObs)
              -> EngineSwitch {
        EngineSwitch::Stay
    }

    fn retire(&mut self, _sid: u64) {}
}

/// Tuning knobs of [`AdaptiveController`]. Defaults are sized for the sim
/// artifacts' executable inventory; the worker filters the ladders down to
/// what the loaded model actually provides before constructing one.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// EWMA smoothing factor for the per-session accept length (weight of
    /// the newest round).
    pub alpha: f64,
    /// hysteresis floor: a speculative engine whose EWMA accept length sits
    /// below this is demoted one ladder step (eventually to autoregressive).
    pub low: f64,
    /// hysteresis ceiling: above this, promote one ladder step.
    pub high: f64,
    /// rounds observed under the current engine before the first decision.
    pub warmup_rounds: u32,
    /// rounds to hold after a switch before deciding again.
    pub cooldown_rounds: u32,
    /// lookahead (W,N,G) ladder, narrow to wide.
    pub lookahead_levels: Vec<(usize, usize, usize)>,
    /// jacobi chain-length ladder, narrow to wide.
    pub jacobi_ks: Vec<usize>,
    /// spec-decode gamma ladder, narrow to wide.
    pub spec_gammas: Vec<usize>,
    /// prompt_lookup level used when promoting off a warm n-gram cache.
    pub prompt_lookup: (usize, usize),
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            alpha: 0.4,
            low: 1.10,
            high: 1.60,
            warmup_rounds: 2,
            cooldown_rounds: 2,
            lookahead_levels: vec![(3, 2, 3), (5, 3, 5), (8, 4, 8)],
            jacobi_ks: vec![5, 8],
            spec_gammas: vec![4, 7],
            prompt_lookup: (8, 1),
        }
    }
}

#[derive(Debug, Default)]
struct SessState {
    /// EWMA of tokens-per-step; `None` until the first observed round
    /// under the current engine (reset on every switch).
    ewma: Option<f64>,
    rounds: u32,
    cooldown: u32,
}

/// The `--controller adaptive` policy: EWMA accept lengths + hysteresis
/// band over the registered engine ladders.
pub struct AdaptiveController {
    pub cfg: AdaptiveConfig,
    sessions: HashMap<u64, SessState>,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        AdaptiveController { cfg, sessions: HashMap::new() }
    }

    /// Demote/promote one step along a ladder of comparable levels.
    /// Returns `None` when already at the requested end.
    fn ladder_step<T: PartialEq + Copy>(ladder: &[T], at: T, up: bool) -> Option<T> {
        let i = ladder.iter().position(|&l| l == at)?;
        if up {
            ladder.get(i + 1).copied()
        } else {
            i.checked_sub(1).map(|j| ladder[j])
        }
    }

    fn pick(&self, current: &EngineLevel, ewma: f64, warm: bool) -> EngineSwitch {
        let (low, high) = (self.cfg.low, self.cfg.high);
        let collapse = ewma < low;
        let surge = ewma > high;
        let next = match current {
            EngineLevel::Autoregressive => {
                // AR's accept length is 1.0 by construction: the only
                // upgrade signal is a warm shared n-gram cache, which makes
                // prompt_lookup speculation nearly free
                if warm {
                    let (k, m) = self.cfg.prompt_lookup;
                    Some(EngineLevel::PromptLookup { k, match_len: m })
                } else {
                    None
                }
            }
            EngineLevel::PromptLookup { .. } if collapse => {
                Some(EngineLevel::Autoregressive)
            }
            EngineLevel::Lookahead { w, n, g } if collapse || surge => {
                match Self::ladder_step(&self.cfg.lookahead_levels, (*w, *n, *g),
                                        surge) {
                    Some((w, n, g)) => Some(EngineLevel::Lookahead { w, n, g }),
                    None if collapse => Some(EngineLevel::Autoregressive),
                    None => None,
                }
            }
            EngineLevel::Jacobi { k } if collapse || surge => {
                match Self::ladder_step(&self.cfg.jacobi_ks, *k, surge) {
                    Some(k) => Some(EngineLevel::Jacobi { k }),
                    None if collapse => Some(EngineLevel::Autoregressive),
                    None => None,
                }
            }
            EngineLevel::SpecDecode { gamma } if collapse || surge => {
                match Self::ladder_step(&self.cfg.spec_gammas, *gamma, surge) {
                    Some(gamma) => Some(EngineLevel::SpecDecode { gamma }),
                    None if collapse => Some(EngineLevel::Autoregressive),
                    None => None,
                }
            }
            _ => None,
        };
        match next {
            Some(level) if level != *current => EngineSwitch::Switch(level),
            _ => EngineSwitch::Stay,
        }
    }
}

impl Controller for AdaptiveController {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn decide(&mut self, sid: u64, current: &EngineLevel, obs: &RoundObs)
              -> EngineSwitch {
        let st = self.sessions.entry(sid).or_default();
        if obs.steps > 0 {
            let rate = obs.tokens as f64 / obs.steps as f64;
            let a = self.cfg.alpha;
            st.ewma = Some(match st.ewma {
                Some(e) => a * rate + (1.0 - a) * e,
                None => rate,
            });
            st.rounds += 1;
        }
        if st.cooldown > 0 {
            st.cooldown -= 1;
            return EngineSwitch::Stay;
        }
        if st.rounds < self.cfg.warmup_rounds {
            return EngineSwitch::Stay;
        }
        let ewma = match st.ewma {
            Some(e) => e,
            None => return EngineSwitch::Stay,
        };
        let decision = self.pick(current, ewma, obs.ngram_warm);
        if let EngineSwitch::Switch(_) = decision {
            // new engine, new accept profile: re-warm before judging it
            let st = self.sessions.entry(sid).or_default();
            st.ewma = None;
            st.rounds = 0;
            st.cooldown = self.cfg.cooldown_rounds;
        }
        decision
    }

    fn retire(&mut self, sid: u64) {
        self.sessions.remove(&sid);
    }
}

/// Synthesize the engine state a fresh `begin` under `target` would start
/// with, over an already-advanced KV cache. `cur` is the last committed
/// token; `history` (prompt ids + committed output) is required for
/// history-backed targets (prompt_lookup).
fn synth_state(target: &EngineLevel, seed: u64, cur: u32,
               history: Option<&[u32]>) -> Result<EngineState> {
    Ok(match target {
        EngineLevel::Autoregressive => {
            // matches AutoRegressive::begin's rng derivation
            EngineState::Autoregressive { cur, rng: Rng::new(seed).state() }
        }
        EngineLevel::Lookahead { w, n, g } => {
            // matches Lookahead::begin: fresh rng, random window init
            // (Algorithm 2 line 4), LookaheadConfig::new attn defaults
            let mut rng = Rng::new(seed ^ 0x1007AE4D);
            let rows: Vec<Vec<u32>> = (0..n - 1)
                .map(|_| (0..*w).map(|_| rng.below(256) as u32).collect())
                .collect();
            EngineState::Lookahead {
                w: *w,
                n: *n,
                g: *g,
                attn: "jnp".into(),
                force_generic: false,
                rows,
                cur,
                rng: rng.state(),
            }
        }
        EngineLevel::Jacobi { k } => {
            // matches Jacobi::begin: fresh rng, random guess init
            let mut rng = Rng::new(seed ^ 0x1AC0B1);
            let guesses: Vec<u32> =
                (0..k - 1).map(|_| rng.below(256) as u32).collect();
            EngineState::Jacobi { k: *k, guesses, cur, rng: rng.state() }
        }
        EngineLevel::PromptLookup { k, match_len } => {
            let history = history
                .ok_or_else(|| anyhow!("prompt_lookup switch needs the session's \
                                        token history"))?;
            EngineState::PromptLookup {
                k: *k,
                match_len: *match_len,
                history: history.to_vec(),
            }
        }
        EngineLevel::SpecDecode { .. } => {
            bail!("spec_decode state is synthesized inside switch_session \
                   (it needs the draft cache)")
        }
    })
}

fn state_cur(engine: &EngineState) -> u32 {
    match engine {
        EngineState::Autoregressive { cur, .. }
        | EngineState::Lookahead { cur, .. }
        | EngineState::Jacobi { cur, .. }
        | EngineState::SpecDecode { cur, .. } => *cur,
        // a live session's history is never empty (it starts as the prompt)
        EngineState::PromptLookup { history, .. } => {
            history.last().copied().unwrap_or(0)
        }
    }
}

/// Switch a live session to `target` at a commit boundary: suspend it into
/// a [`SessionSnapshot`], replace the engine state with what a fresh
/// `begin` under `target` would hold, and resume over the same KV cache.
/// The committed prefix (`snapshot.out`) rides through untouched, so under
/// greedy sampling the final output is byte-identical to never switching.
///
/// `prompt_ids` is the session's encoded prompt (required for
/// history-backed targets: prompt_lookup, and spec_decode promotion from a
/// draft-less engine). `draft` must serve spec_decode targets.
///
/// On error before the suspend the session is untouched; a resume failure
/// after the suspend poisons it (the caller retires it as failed) — the
/// worker pre-validates executable availability to keep that path cold.
pub fn switch_session<'rt>(sess: &mut Box<dyn DecodeSession + 'rt>,
                           rt: &'rt ModelRuntime, target: &EngineLevel,
                           prompt_ids: Option<&[u32]>,
                           draft: Option<Rc<ModelRuntime>>) -> Result<()> {
    if !sess.suspendable() {
        bail!("session is not suspendable; cannot switch engines");
    }
    let mut snap = sess.suspend()?;
    let cur = state_cur(&snap.engine);
    let history: Option<Vec<u32>> = prompt_ids.map(|p| {
        let mut h = Vec::with_capacity(p.len() + snap.out.len());
        h.extend_from_slice(p);
        h.extend_from_slice(&snap.out);
        h
    });
    let mut draft_for_resume = None;
    match target {
        EngineLevel::SpecDecode { gamma } => {
            let d = draft.ok_or_else(|| {
                anyhow!("spec_decode switch needs a draft runtime")
            })?;
            if snap.draft_kv.is_none() {
                // promotion from a draft-less engine: rebuild the draft
                // cache by prefilling the full token history (its length
                // equals the target cache's committed rows)
                let h = history.as_deref().ok_or_else(|| {
                    anyhow!("spec_decode promotion needs the session's \
                             token history")
                })?;
                if h.len() > d.prefill_len {
                    bail!("history ({} tokens) exceeds draft prefill capacity \
                           {}", h.len(), d.prefill_len);
                }
                let dcache = d.prefill_reuse(h)?;
                snap.draft_kv = Some(d.cache_to_host(&dcache)?);
            }
            snap.engine = EngineState::SpecDecode {
                gamma: *gamma,
                cur,
                draft: d.mm.name.clone(),
            };
            draft_for_resume = Some(d);
        }
        _ => {
            snap.engine =
                synth_state(target, snap.params.seed, cur, history.as_deref())?;
            snap.draft_kv = None;
        }
    }
    *sess = snap.resume_with(rt, draft_for_resume)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(steps: u64, tokens: u64) -> RoundObs {
        RoundObs { steps, tokens, ngram_warm: false }
    }

    fn warm(steps: u64, tokens: u64) -> RoundObs {
        RoundObs { steps, tokens, ngram_warm: true }
    }

    fn adaptive() -> AdaptiveController {
        AdaptiveController::new(AdaptiveConfig::default())
    }

    #[test]
    fn static_controller_never_switches() {
        let mut c = StaticController;
        let la = EngineLevel::Lookahead { w: 5, n: 3, g: 5 };
        for _ in 0..10 {
            assert_eq!(c.decide(1, &la, &obs(4, 1)), EngineSwitch::Stay);
        }
    }

    #[test]
    fn collapse_steps_down_ladder_then_autoregressive() {
        let mut c = adaptive();
        let mid = EngineLevel::Lookahead { w: 5, n: 3, g: 5 };
        // warmup rounds: no decision yet
        assert_eq!(c.decide(1, &mid, &obs(4, 4)), EngineSwitch::Stay);
        // accept length stuck at 1.0 < low: demote one level
        let d = c.decide(1, &mid, &obs(4, 4));
        assert_eq!(d,
                   EngineSwitch::Switch(EngineLevel::Lookahead { w: 3, n: 2, g: 3 }));
        // cooldown holds at the new level, then demote to the floor
        let narrow = EngineLevel::Lookahead { w: 3, n: 2, g: 3 };
        for _ in 0..2 {
            assert_eq!(c.decide(1, &narrow, &obs(4, 4)), EngineSwitch::Stay);
        }
        assert_eq!(c.decide(1, &narrow, &obs(4, 4)),
                   EngineSwitch::Switch(EngineLevel::Autoregressive));
    }

    #[test]
    fn surge_widens_and_band_holds_steady() {
        let mut c = adaptive();
        let mid = EngineLevel::Lookahead { w: 5, n: 3, g: 5 };
        assert_eq!(c.decide(1, &mid, &obs(2, 6)), EngineSwitch::Stay);
        assert_eq!(c.decide(1, &mid, &obs(2, 6)),
                   EngineSwitch::Switch(EngineLevel::Lookahead { w: 8, n: 4, g: 8 }));
        // inside the band nothing moves (hysteresis: no oscillation)
        let mut c = adaptive();
        for _ in 0..10 {
            assert_eq!(c.decide(2, &mid, &obs(4, 5)), EngineSwitch::Stay,
                       "EWMA 1.25 is inside [1.10, 1.60] and must hold");
        }
    }

    #[test]
    fn warm_cache_promotes_autoregressive_to_prompt_lookup() {
        let mut c = adaptive();
        let ar = EngineLevel::Autoregressive;
        assert_eq!(c.decide(1, &ar, &warm(4, 4)), EngineSwitch::Stay);
        assert_eq!(
            c.decide(1, &ar, &warm(4, 4)),
            EngineSwitch::Switch(EngineLevel::PromptLookup { k: 8, match_len: 1 })
        );
        // a cold cache never promotes
        let mut c = adaptive();
        for _ in 0..6 {
            assert_eq!(c.decide(1, &ar, &obs(4, 4)), EngineSwitch::Stay);
        }
    }

    #[test]
    fn spec_gamma_ladder_and_collapse() {
        let mut c = adaptive();
        let g4 = EngineLevel::SpecDecode { gamma: 4 };
        assert_eq!(c.decide(1, &g4, &obs(2, 8)), EngineSwitch::Stay);
        assert_eq!(c.decide(1, &g4, &obs(2, 8)),
                   EngineSwitch::Switch(EngineLevel::SpecDecode { gamma: 7 }));
        // collapse at the bottom of the gamma ladder falls back to AR
        let mut c = adaptive();
        let _ = c.decide(2, &g4, &obs(8, 8));
        assert_eq!(c.decide(2, &g4, &obs(8, 8)),
                   EngineSwitch::Switch(EngineLevel::Autoregressive));
    }

    #[test]
    fn retire_drops_state() {
        let mut c = adaptive();
        let mid = EngineLevel::Lookahead { w: 5, n: 3, g: 5 };
        let _ = c.decide(1, &mid, &obs(4, 4));
        assert!(!c.sessions.is_empty());
        c.retire(1);
        assert!(c.sessions.is_empty());
    }

    #[test]
    fn level_tags_are_stable() {
        assert_eq!(EngineLevel::Lookahead { w: 5, n: 3, g: 5 }.tag(),
                   "lookahead:w5n3g5");
        assert_eq!(EngineLevel::SpecDecode { gamma: 4 }.method(), "spec_decode");
        assert_eq!(EngineLevel::PromptLookup { k: 8, match_len: 1 }.tag(),
                   "prompt_lookup:k8m1");
    }
}
