//! Analytic models from the paper's §4 plus the device-projection model used
//! to translate CPU-measured step compression into GPU-class speedups
//! (DESIGN.md §7):
//!
//! - Eq. 4: E[#tokens] for single-sequence speculative decoding,
//! - Eq. 5: E[#tokens] for b parallel speculations,
//! - Eq. 7: step compression S given good-speculation frequency f,
//! - a memory-bandwidth-bound latency model for A100/RTX3090 projections,
//! - per-step communication volumes for TP / PP / LP (Fig. 6/7 shapes).

/// Eq. 4 — expected accepted tokens, one speculation of length gamma with
/// per-token acceptance rate alpha.
pub fn expected_tokens_single(alpha: f64, gamma: usize) -> f64 {
    if (alpha - 1.0).abs() < 1e-12 {
        return gamma as f64 + 1.0;
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha)
}

/// Eq. 5 — expected accepted tokens with b parallel speculations.
pub fn expected_tokens_batched(alpha: f64, gamma: usize, b: usize) -> f64 {
    let mut sum = 0.0;
    for i in 1..=gamma {
        sum += (1.0 - alpha.powi(i as i32)).powi(b as i32);
    }
    (gamma as f64 + 1.0) - sum
}

/// Eq. 7 — step compression: one good speculation every f steps.
pub fn compression(alpha: f64, gamma: usize, b: usize, f: f64) -> f64 {
    let e = expected_tokens_batched(alpha, gamma, b);
    (f - 1.0 + e) / f
}

/// Fit (alpha, f) to measured (gamma, b, S) points by grid search — used to
/// overlay the Eq. 7 curve on Fig. 4(a) measurements, as the paper does with
/// alpha = 0.425, f = 3.106.
pub fn fit_alpha_f(points: &[(usize, usize, f64)]) -> (f64, f64) {
    let mut best = (0.4, 3.0);
    let mut best_err = f64::INFINITY;
    let mut a = 0.05;
    while a < 0.95 {
        let mut f = 1.0;
        while f < 12.0 {
            let err: f64 = points
                .iter()
                .map(|&(g, b, s)| {
                    let p = compression(a, g, b, f);
                    (p - s) * (p - s)
                })
                .sum();
            if err < best_err {
                best_err = err;
                best = (a, f);
            }
            f += 0.05;
        }
        a += 0.01;
    }
    best
}

// ---------------------------------------------------------------------------
// Device latency model (DESIGN.md §7)
// ---------------------------------------------------------------------------

/// A decoding device, memory-bandwidth-bound at batch 1.
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub name: &'static str,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// peak compute, FLOP/s (fp16 tensor).
    pub flops: f64,
}

pub const A100: Device =
    Device { name: "A100", mem_bw: 2.0e12, flops: 312.0e12 };
pub const RTX3090: Device =
    Device { name: "RTX3090", mem_bw: 0.936e12, flops: 71.0e12 };

/// Step latency on `dev` for a model with `params` weights (fp16) processing
/// `t_in` tokens: max(weight streaming, compute), plus fixed launch overhead.
/// The "free lunch" region is where weight streaming dominates.
pub fn step_latency(dev: &Device, params: f64, t_in: usize) -> f64 {
    let bytes = 2.0 * params; // fp16 weights
    let io = bytes / dev.mem_bw;
    let compute = 2.0 * params * t_in as f64 / dev.flops;
    let fixed = 20e-6; // kernel-launch floor
    fixed + io.max(compute)
}

/// Projected wall-clock speedup of lookahead vs autoregressive on `dev`,
/// given measured step compression `s` and per-step input size `t_in`.
pub fn projected_speedup(dev: &Device, params: f64, t_in: usize, s: f64) -> f64 {
    s * step_latency(dev, params, 1) / step_latency(dev, params, t_in)
}

// ---------------------------------------------------------------------------
// Parallelism communication model (Fig. 6/7 shapes)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    /// Tensor parallel: two all-reduces of activations per layer per step.
    TP,
    /// Pipeline parallel: activation hop per stage boundary, pipeline bubble.
    PP,
    /// Lookahead parallel: full model per device, one token sync per step.
    LP,
}

/// Per-step communication time (seconds) on an NVLink-class interconnect.
pub fn comm_time(p: Parallelism, devices: usize, layers: usize, d_model: usize,
                 t_in: usize) -> f64 {
    if devices <= 1 {
        return 0.0;
    }
    let link_bw = 300.0e9; // NVLink effective bytes/s
    let latency = 8e-6; // per collective hop
    let act_bytes = 2.0 * (t_in * d_model) as f64;
    match p {
        Parallelism::TP => {
            // 2 all-reduces per layer; ring all-reduce moves 2(p-1)/p of data
            let vol = 2.0 * act_bytes * 2.0 * (devices - 1) as f64 / devices as f64;
            layers as f64 * (vol / link_bw + 2.0 * latency)
        }
        Parallelism::PP => {
            // one activation hop per stage boundary (bubble handled by caller)
            (devices - 1) as f64 * (act_bytes / link_bw + latency)
        }
        Parallelism::LP => {
            // sync only the <= N accepted token ids (few bytes) per step
            latency
        }
    }
}

/// End-to-end per-step latency under a parallelism scheme. For TP, compute
/// is sharded; for PP, stages serialize at batch 1 (the paper's observed
/// 0.75-0.82x slowdown); for LP, per-device t_in shrinks.
pub fn parallel_step_latency(p: Parallelism, dev: &Device, devices: usize,
                             params: f64, layers: usize, d_model: usize,
                             t_in: usize) -> f64 {
    let comm = comm_time(p, devices, layers, d_model, t_in);
    match p {
        Parallelism::TP => step_latency(dev, params / devices as f64, t_in) + comm,
        Parallelism::PP => {
            // each stage holds params/devices; at batch 1 stages execute
            // sequentially so weight-streaming time is unchanged + hops
            step_latency(dev, params, t_in) + comm
        }
        Parallelism::LP => {
            let shard = t_in.div_ceil(devices);
            step_latency(dev, params, shard.max(1)) + comm
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq4_matches_closed_form() {
        // alpha=0.5, gamma=2: 1 + 0.5 + 0.25 = 1.75
        assert!((expected_tokens_single(0.5, 2) - 1.75).abs() < 1e-12);
        assert!((expected_tokens_single(1.0, 3) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn eq5_reduces_to_eq4_at_b1() {
        for &a in &[0.2, 0.425, 0.8] {
            for g in 1..6 {
                let e4 = expected_tokens_single(a, g);
                let e5 = expected_tokens_batched(a, g, 1);
                assert!((e4 - e5).abs() < 1e-9, "a={a} g={g}: {e4} vs {e5}");
            }
        }
    }

    #[test]
    fn eq5_monotone_in_b() {
        let e1 = expected_tokens_batched(0.425, 4, 1);
        let e8 = expected_tokens_batched(0.425, 4, 8);
        let e64 = expected_tokens_batched(0.425, 4, 64);
        assert!(e1 < e8 && e8 < e64);
    }

    #[test]
    fn eq5_log_scaling_regime() {
        // Linear-in-log(b) growth (the paper's scaling law): the increment
        // per doubling of b should be roughly constant before saturation.
        let e = |b| expected_tokens_batched(0.425, 12, b);
        let d1 = e(4) - e(2);
        let d2 = e(8) - e(4);
        let d3 = e(16) - e(8);
        assert!(d1 > 0.0 && d2 > 0.0 && d3 > 0.0);
        assert!((d1 / d2) < 2.0 && (d2 / d3) < 2.0, "{d1} {d2} {d3}");
    }

    #[test]
    fn compression_at_paper_setting() {
        // paper Fig. 4(b): alpha=0.425, f=3.106 — S must be >1 and grow in b
        let s1 = compression(0.425, 4, 1, 3.106);
        let s15 = compression(0.425, 4, 15, 3.106);
        assert!(s1 > 1.0 && s15 > s1);
        assert!(s15 < 4.0); // sanity: gamma+1 bound
    }

    #[test]
    fn fit_recovers_parameters() {
        let truth = (0.45, 3.0);
        let pts: Vec<(usize, usize, f64)> = [1usize, 2, 4, 8, 15, 30]
            .iter()
            .map(|&b| (4, b, compression(truth.0, 4, b, truth.1)))
            .collect();
        let (a, f) = fit_alpha_f(&pts);
        assert!((a - truth.0).abs() < 0.03, "alpha {a}");
        assert!((f - truth.1).abs() < 0.3, "f {f}");
    }

    #[test]
    fn free_lunch_region_on_a100() {
        // 7B params: t_in=120 should cost < 2.2x a single-token step
        let p = 7e9;
        let l1 = step_latency(&A100, p, 1);
        let l120 = step_latency(&A100, p, 120);
        assert!(l120 / l1 < 2.2, "ratio {}", l120 / l1);
        // and the projected speedup at S=2 stays well above 1
        assert!(projected_speedup(&A100, p, 120, 2.0) > 1.3);
    }

    #[test]
    fn weaker_device_smaller_speedup() {
        // Fig. 8: RTX3090's FLOPs cap bites earlier than A100's.
        let p = 7e9;
        let a = projected_speedup(&A100, p, 120, 2.0);
        let r = projected_speedup(&RTX3090, p, 120, 2.0);
        assert!(r < a, "3090 {r} vs A100 {a}");
    }

    #[test]
    fn lp_comm_negligible_tp_grows() {
        let lp = comm_time(Parallelism::LP, 4, 32, 4096, 120);
        let tp = comm_time(Parallelism::TP, 4, 32, 4096, 120);
        assert!(lp < tp / 10.0);
    }

    #[test]
    fn tp_pp_slow_down_single_batch_decode() {
        // paper §5.2: TP/PP bring slowdowns at batch 1 while LP speeds up.
        let p = 7e9;
        let base = step_latency(&A100, p, 120);
        let tp = parallel_step_latency(Parallelism::TP, &A100, 4, p, 32, 4096, 120);
        let pp = parallel_step_latency(Parallelism::PP, &A100, 4, p, 32, 4096, 120);
        let lp = parallel_step_latency(Parallelism::LP, &A100, 4, p, 32, 4096, 120);
        assert!(pp > base, "pp {pp} base {base}");
        assert!(lp < base * 1.05, "lp {lp} base {base}");
        // TP shards weights so it can help raw latency, but it must pay
        // comm that LP does not:
        assert!(tp > step_latency(&A100, p / 4.0, 120));
    }
}
