//! Shared bench driver: run an engine over a prompt suite and aggregate the
//! paper's measurables (S, tok/s, per-step latency), plus the A100/3090
//! projections from DESIGN.md §7.

use std::sync::Arc;

use anyhow::Result;

use crate::analytic::{projected_speedup, Device};
use crate::engine::{Decoder, GenParams, SamplingParams};
use crate::metrics::DecodeStats;
use crate::ngram::{PoolHandle, SharedNgramCache};
use crate::runtime::ModelRuntime;
use crate::tokenizer::ByteTokenizer;

#[derive(Debug, Clone, Default)]
pub struct SuiteRun {
    pub prompts: usize,
    pub tokens: usize,
    pub steps: usize,
    pub wall_s: f64,
    pub decode_wall_s: f64,
    pub pool_hits: usize,
    pub pool_misses: usize,
    /// requests that started against an already-populated n-gram store.
    pub warm_starts: usize,
}

impl SuiteRun {
    /// Step compression ratio S (Eq. 6).
    pub fn s(&self) -> f64 {
        if self.steps == 0 {
            1.0
        } else {
            self.tokens as f64 / self.steps as f64
        }
    }

    pub fn tok_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.wall_s
        }
    }

    pub fn ms_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.decode_wall_s * 1e3 / self.steps as f64
        }
    }

    /// Paper-device projection: speedup vs AR on `dev` for a `params`-sized
    /// model with per-step input `t_in` (memory-bound latency model).
    pub fn projected(&self, dev: &Device, params: f64, t_in: usize) -> f64 {
        projected_speedup(dev, params, t_in.max(1), self.s())
    }

    /// Pool hit rate aggregated over the suite.
    pub fn pool_hit_rate(&self) -> f64 {
        crate::metrics::hit_rate(self.pool_hits as u64, self.pool_misses as u64)
    }

    fn absorb(&mut self, st: &DecodeStats) {
        self.prompts += 1;
        self.tokens += st.generated_tokens;
        self.steps += st.decode_steps;
        self.wall_s += st.wall.as_secs_f64();
        self.decode_wall_s += (st.wall - st.prefill_wall).as_secs_f64();
        self.pool_hits += st.pool_hits;
        self.pool_misses += st.pool_misses;
        self.warm_starts += st.pool_warm_start as usize;
    }
}

/// Options for [`run_suite_with`] — the single suite entry point.
/// Defaults: greedy (temperature 0), cold per-request pools.
#[derive(Debug, Clone, Default)]
pub struct SuiteOptions<'a> {
    pub max_tokens: usize,
    pub temperature: f64,
    /// When set, every request is served from this cross-request
    /// [`SharedNgramCache`] — the serving scenario where request k+1 reuses
    /// the n-grams requests 1..k harvested. `None` reproduces the paper's
    /// cold per-request pools.
    pub cache: Option<&'a Arc<SharedNgramCache>>,
}

impl<'a> SuiteOptions<'a> {
    pub fn new(max_tokens: usize) -> Self {
        SuiteOptions { max_tokens, ..Default::default() }
    }

    pub fn temperature(mut self, t: f64) -> Self {
        self.temperature = t;
        self
    }

    pub fn cache(mut self, c: &'a Arc<SharedNgramCache>) -> Self {
        self.cache = Some(c);
        self
    }
}

/// Aggregate run plus the generated texts (Tab. 2 ROUGE needs them; callers
/// that only want numbers take `.run`).
#[derive(Debug, Clone, Default)]
pub struct SuiteOutcome {
    pub run: SuiteRun,
    pub texts: Vec<String>,
}

/// Run `engine` over `prompts` under `opts`; the one suite entry point.
pub fn run_suite_with(rt: &ModelRuntime, engine: &mut dyn Decoder,
                      prompts: &[String], opts: SuiteOptions<'_>)
                      -> Result<SuiteOutcome> {
    let SuiteOptions { max_tokens, temperature, cache } = opts;
    let tok = ByteTokenizer::new();
    // warmup: pay one-time executable compilation outside the timed region
    // (always against a private pool so a shared cache stays cold until the
    // measured requests run)
    if let Some(p0) = prompts.first() {
        let ids = tok.encode_with_bos(p0);
        let warm = GenParams { max_new_tokens: 2, ..GenParams::default() };
        let _ = engine.generate(rt, &ids, &warm);
    }
    let mut agg = SuiteRun::default();
    let mut texts = Vec::with_capacity(prompts.len());
    for (i, p) in prompts.iter().enumerate() {
        let ids = tok.encode_with_bos(p);
        let params = GenParams {
            max_new_tokens: max_tokens,
            sampling: SamplingParams {
                temperature,
                ..SamplingParams::default()
            },
            stop_at_eos: true,
            seed: i as u64,
        };
        let mut pool = match cache {
            Some(c) => PoolHandle::shared(c.clone()),
            None => PoolHandle::for_spec(engine.pool_spec()),
        };
        let out = engine.generate_with_pool(rt, &ids, &params, &mut pool)?;
        agg.absorb(&out.stats);
        texts.push(out.text);
    }
    Ok(SuiteOutcome { run: agg, texts })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_run_aggregates() {
        let mut r = SuiteRun::default();
        let mut st = DecodeStats::default();
        st.record_accept(2);
        st.record_accept(2);
        st.wall = std::time::Duration::from_secs(1);
        r.absorb(&st);
        assert_eq!(r.tokens, 4);
        assert_eq!(r.steps, 2);
        assert!((r.s() - 2.0).abs() < 1e-12);
        assert!((r.tok_per_sec() - 4.0).abs() < 1e-9);
    }
}
