//! Open-loop serving load generator (the serving-bench harness substrate).
//!
//! Three layers, strictly separated so determinism is checkable:
//!
//! 1. **Schedule generation** ([`LoadSpec`] -> [`Schedule::generate`]) is a
//!    pure function of the spec: seeded Poisson arrivals (exponential
//!    inter-arrival times), workload-mix class picks, synthetic prompts
//!    from [`MixClass::synth`], and cancel/deadline churn all come from one
//!    [`Rng`] stream. The same seed replays the byte-identical schedule —
//!    [`Schedule::dump`] / [`Schedule::fingerprint`] pin that contract.
//! 2. **Driving** replays a schedule against a live server, open-loop:
//!    arrivals fire at their planned times whether or not earlier requests
//!    finished. [`drive_inprocess`] uses [`ServerHandle::submit`];
//!    [`drive_tcp`] speaks the JSON-lines protocol with one connection per
//!    request (plus one per planned cancel and one final report scrape, so
//!    the total connection count is deterministic — see
//!    [`Schedule::tcp_conns`]).
//! 3. **Aggregation** ([`LoadRun`] -> [`bench_json`]) folds per-request
//!    final records plus the server's scraped metrics report into the
//!    `BENCH_*.json` schema (`lookahead-serve-bench/v1`) that CI validates
//!    with [`validate_bench_json`].
//!
//! Latencies vary run to run (wall clock is real); the *schedule*, the
//! request set, and schedule-derived counters never do.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::{hit_rate, Histogram};
use crate::server::{Request, Response, ServerHandle};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::MixClass;

/// What load to offer: everything the schedule generator needs, nothing the
/// driver measures. Chainable like the config builders:
/// `LoadSpec::new(7).requests(64).rate_per_s(50.0)`.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub seed: u64,
    pub requests: usize,
    /// mean Poisson arrival rate (requests per second of offered load).
    pub rate_per_s: f64,
    /// workload mix: (class, weight) pairs, weights need not sum to 1.
    pub mix: Vec<(MixClass, f64)>,
    /// fraction of requests cancelled mid-flight (they run `stream: true`
    /// so the TCP client learns the server id from the first chunk).
    pub cancel_frac: f64,
    /// fraction of requests carrying a serving deadline.
    pub deadline_frac: f64,
    pub deadline_ms: u64,
    /// per-request token budget drawn uniformly from [min, max].
    pub max_tokens_min: usize,
    pub max_tokens_max: usize,
    /// decoding methods cycled through by weight-free uniform choice.
    pub methods: Vec<String>,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            seed: 0,
            requests: 32,
            rate_per_s: 50.0,
            mix: MixClass::ALL.iter().map(|&c| (c, 1.0)).collect(),
            cancel_frac: 0.0,
            deadline_frac: 0.0,
            deadline_ms: 40,
            max_tokens_min: 8,
            max_tokens_max: 24,
            methods: vec!["lookahead".into()],
        }
    }
}

impl LoadSpec {
    pub fn new(seed: u64) -> LoadSpec {
        LoadSpec { seed, ..Default::default() }
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.requests = n;
        self
    }

    pub fn rate_per_s(mut self, r: f64) -> Self {
        self.rate_per_s = r;
        self
    }

    pub fn mix(mut self, mix: Vec<(MixClass, f64)>) -> Self {
        self.mix = mix;
        self
    }

    pub fn cancel_frac(mut self, f: f64) -> Self {
        self.cancel_frac = f;
        self
    }

    pub fn deadline_frac(mut self, f: f64) -> Self {
        self.deadline_frac = f;
        self
    }

    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = ms;
        self
    }

    pub fn max_tokens(mut self, min: usize, max: usize) -> Self {
        self.max_tokens_min = min;
        self.max_tokens_max = max;
        self
    }

    pub fn methods(mut self, m: Vec<String>) -> Self {
        self.methods = m;
        self
    }

    /// Parse a `--mix templated:2,tenant:1,prefix:1` CLI string.
    pub fn parse_mix(s: &str) -> Result<Vec<(MixClass, f64)>> {
        let mut mix = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, w) = match part.split_once(':') {
                Some((n, w)) => {
                    (n, w.parse::<f64>().map_err(|_| anyhow!("bad weight in '{part}'"))?)
                }
                None => (part, 1.0),
            };
            if w < 0.0 {
                bail!("negative mix weight in '{part}'");
            }
            mix.push((MixClass::parse(name)?, w));
        }
        if mix.is_empty() {
            bail!("empty mix spec '{s}'");
        }
        Ok(mix)
    }

    /// Spec as JSON for the BENCH file's `config` section.
    pub fn to_json(&self) -> Json {
        let mix = Json::Obj(
            self.mix
                .iter()
                .map(|(c, w)| (c.name().to_string(), Json::num(*w)))
                .collect(),
        );
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("rate_per_s", Json::num(self.rate_per_s)),
            ("mix", mix),
            ("cancel_frac", Json::num(self.cancel_frac)),
            ("deadline_frac", Json::num(self.deadline_frac)),
            ("deadline_ms", Json::num(self.deadline_ms as f64)),
            ("max_tokens", Json::arr(vec![
                Json::num(self.max_tokens_min as f64),
                Json::num(self.max_tokens_max as f64),
            ])),
            ("methods",
             Json::arr(self.methods.iter().map(|m| Json::str(m.clone())).collect())),
        ])
    }
}

/// One planned arrival: when, what, and the churn attached to it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedRequest {
    /// offset from the run start, ms.
    pub at_ms: u64,
    pub class: MixClass,
    pub req: Request,
    /// cancel this many ms after submission (the request runs streaming so
    /// the TCP client can learn its server-side id first).
    pub cancel_after_ms: Option<u64>,
}

/// The full deterministic arrival schedule for one run.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub spec_seed: u64,
    pub items: Vec<PlannedRequest>,
}

impl Schedule {
    /// Pure: same spec -> identical schedule, byte for byte.
    pub fn generate(spec: &LoadSpec) -> Schedule {
        let mut rng = Rng::new(spec.seed);
        let weights: Vec<f32> = spec.mix.iter().map(|(_, w)| *w as f32).collect();
        let rate = spec.rate_per_s.max(1e-6);
        let mut t_ms = 0.0f64;
        let mut items = Vec::with_capacity(spec.requests);
        for i in 0..spec.requests {
            // Poisson process: exponential inter-arrival times
            let u = rng.f64();
            t_ms += -(1.0 - u).ln() / rate * 1e3;
            let class = spec.mix[rng.weighted(&weights)].0;
            let (prompt, tenant) = class.synth(&mut rng);
            let max_tokens =
                rng.range(spec.max_tokens_min, spec.max_tokens_max.max(spec.max_tokens_min) + 1);
            let method = rng.choose(&spec.methods).clone();
            let mut req =
                Request::new(prompt).max_tokens(max_tokens).method(method).seed(i as u64);
            if let Some(t) = tenant {
                req = req.tenant(t);
            }
            let cancel_after_ms = rng.bool(spec.cancel_frac).then(|| {
                req.stream = true;
                rng.range(5, 30) as u64
            });
            if cancel_after_ms.is_none() && rng.bool(spec.deadline_frac) {
                req = req.deadline_ms(spec.deadline_ms);
            }
            items.push(PlannedRequest {
                at_ms: t_ms.round() as u64,
                class,
                req,
                cancel_after_ms,
            });
        }
        Schedule { spec_seed: spec.seed, items }
    }

    /// Canonical text form — one line per planned request, every field that
    /// defines the run. Two schedules are "the same" iff their dumps are
    /// byte-identical (the determinism test's criterion).
    pub fn dump(&self) -> String {
        let mut s = format!("seed={}\n", self.spec_seed);
        for it in &self.items {
            let cancel = match it.cancel_after_ms {
                Some(ms) => format!("{ms}"),
                None => "-".to_string(),
            };
            s.push_str(&format!(
                "{} {} cancel={} {}\n",
                it.at_ms,
                it.class.name(),
                cancel,
                it.req.to_json_line()
            ));
        }
        s
    }

    /// FNV-1a 64 over [`Schedule::dump`] — a compact schedule identity for
    /// the BENCH file.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self.dump().as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// Schedule-derived aggregate counters (deterministic, unlike
    /// latencies): per-class request counts + planned churn totals.
    pub fn counts(&self) -> BTreeMap<String, u64> {
        let mut m = BTreeMap::new();
        for it in &self.items {
            *m.entry(format!("class_{}", it.class.name())).or_default() += 1;
            if it.cancel_after_ms.is_some() {
                *m.entry("cancels_planned".to_string()).or_default() += 1;
            }
            if it.req.deadline_ms.is_some() {
                *m.entry("deadlines_planned".to_string()).or_default() += 1;
            }
        }
        m.insert("total".to_string(), self.items.len() as u64);
        m
    }

    /// Connections [`drive_tcp`] opens: one per request, one per planned
    /// cancel (always opened, even if the id was never learned, so the
    /// count stays deterministic), one for the final report scrape. Pass
    /// this as `max_conns` to `serve_tcp` so the server exits cleanly.
    pub fn tcp_conns(&self) -> usize {
        let cancels =
            self.items.iter().filter(|i| i.cancel_after_ms.is_some()).count();
        self.items.len() + cancels + 1
    }
}

/// Client-side record of one request's fate (from its final record).
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub class: MixClass,
    pub ok: bool,
    pub finish: String,
    pub tokens: usize,
    pub wall_ms: f64,
    pub queue_ms: f64,
    pub ttft_ms: f64,
}

impl RequestOutcome {
    fn from_response(class: MixClass, r: &Response) -> RequestOutcome {
        RequestOutcome {
            class,
            ok: r.error.is_none(),
            finish: r.finish.clone(),
            tokens: r.tokens,
            wall_ms: r.wall_ms,
            queue_ms: r.queue_ms,
            ttft_ms: r.ttft_ms,
        }
    }

    fn failed(class: MixClass) -> RequestOutcome {
        RequestOutcome {
            class,
            ok: false,
            finish: String::new(),
            tokens: 0,
            wall_ms: 0.0,
            queue_ms: 0.0,
            ttft_ms: 0.0,
        }
    }

    /// Per-token decode latency (TPOT): time after the first token,
    /// amortized over the remaining tokens. None for empty results.
    fn per_token_ms(&self) -> Option<f64> {
        if !self.ok || self.tokens == 0 {
            return None;
        }
        Some((self.wall_ms - self.ttft_ms).max(0.0) / (self.tokens - 1).max(1) as f64)
    }
}

/// One driven run: per-request outcomes, total wall time, and the server's
/// scraped metrics report (the `{"report": true}` JSON).
#[derive(Debug, Clone)]
pub struct LoadRun {
    pub outcomes: Vec<RequestOutcome>,
    pub wall_s: f64,
    pub report: Json,
}

fn sleep_until(t0: Instant, at_ms: u64) {
    let target = Duration::from_millis(at_ms);
    let elapsed = t0.elapsed();
    if elapsed < target {
        crate::util::sync::nap(target - elapsed);
    }
}

/// Replay `sched` against an in-process server, open-loop: submissions fire
/// at their planned offsets, planned cancels at submit-time + delta; final
/// records are drained after the last arrival.
pub fn drive_inprocess(handle: &ServerHandle, sched: &Schedule) -> LoadRun {
    // lint: allow(wall-clock) reason=open-loop runner measures real latency
    let t0 = Instant::now();
    let mut streams = Vec::new();
    let mut outcomes: Vec<Option<RequestOutcome>> = vec![None; sched.items.len()];
    // (due_ms from t0, server id) — fired while waiting for later arrivals
    let mut cancels: Vec<(u64, u64)> = Vec::new();
    for (i, item) in sched.items.iter().enumerate() {
        // fire cancels that come due before this arrival
        cancels.sort_unstable();
        while let Some(&(due, id)) = cancels.first() {
            if due > item.at_ms {
                break;
            }
            sleep_until(t0, due);
            handle.cancel(id);
            cancels.remove(0);
        }
        sleep_until(t0, item.at_ms);
        match handle.submit(item.req.clone()) {
            Ok(rs) => {
                if let Some(delta) = item.cancel_after_ms {
                    cancels.push((item.at_ms + delta, rs.id));
                }
                streams.push((i, rs));
            }
            Err(_) => outcomes[i] = Some(RequestOutcome::failed(item.class)),
        }
    }
    cancels.sort_unstable();
    for (due, id) in cancels {
        sleep_until(t0, due);
        handle.cancel(id);
    }
    for (i, rs) in streams {
        let class = sched.items[i].class;
        outcomes[i] = Some(match rs.wait() {
            Ok(resp) => RequestOutcome::from_response(class, &resp),
            Err(_) => RequestOutcome::failed(class),
        });
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let report = handle.report_json();
    LoadRun {
        outcomes: outcomes.into_iter().map(Option::unwrap).collect(),
        wall_s,
        report,
    }
}

/// Replay `sched` against a TCP server at `addr`: one thread + connection
/// per request, one extra connection per planned cancel, and a final
/// `{"report": true}` scrape. Open-loop like [`drive_inprocess`].
pub fn drive_tcp(addr: &str, sched: &Schedule) -> Result<LoadRun> {
    // lint: allow(wall-clock) reason=open-loop runner measures real latency
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for item in sched.items.iter().cloned() {
        let addr = addr.to_string();
        joins.push(std::thread::spawn(move || request_thread(&addr, t0, &item)));
    }
    let mut outcomes = Vec::with_capacity(joins.len());
    for j in joins {
        outcomes
            .push(j.join().unwrap_or_else(|_| RequestOutcome::failed(MixClass::Templated)));
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let line = crate::server::client_request(addr, r#"{"report": true}"#)?;
    let j = Json::parse(&line).map_err(|e| anyhow!("bad report line: {e}"))?;
    let report = j
        .get("report")
        .cloned()
        .ok_or_else(|| anyhow!("report scrape missing 'report' key: {line}"))?;
    Ok(LoadRun { outcomes, wall_s, report })
}

/// One TCP request end-to-end: wait for the planned arrival, send, stream
/// lines until the final record. A planned cancel spawns a companion that
/// ALWAYS opens its control connection at the planned offset (id 0 when the
/// request never streamed a chunk — the ack is then `ok:false`), keeping
/// the total connection count schedule-deterministic.
fn request_thread(addr: &str, t0: Instant, item: &PlannedRequest) -> RequestOutcome {
    sleep_until(t0, item.at_ms);
    let id_slot = Arc::new(AtomicU64::new(0));
    let canceller = item.cancel_after_ms.map(|delta| {
        let addr = addr.to_string();
        let due = item.at_ms + delta;
        let slot = id_slot.clone();
        std::thread::spawn(move || {
            sleep_until(t0, due);
            let id = slot.load(Ordering::Relaxed);
            let line = format!("{{\"cancel\": {id}}}");
            let _ = crate::server::client_request(&addr, &line);
        })
    });
    let outcome = (|| -> Result<RequestOutcome> {
        let mut stream = TcpStream::connect(addr).context("connect")?;
        stream.write_all(item.req.to_json_line().as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                bail!("connection closed before the final record");
            }
            let t = line.trim_end();
            if let Ok(resp) = Response::from_json_line(t) {
                return Ok(RequestOutcome::from_response(item.class, &resp));
            }
            // chunk line: learn the server-side id for the canceller
            if let Ok(j) = Json::parse(t) {
                if let Some(id) = j.get("id").and_then(Json::as_usize) {
                    id_slot.store(id as u64, Ordering::Relaxed);
                }
            }
        }
    })()
    .unwrap_or_else(|_| RequestOutcome::failed(item.class));
    if let Some(c) = canceller {
        let _ = c.join();
    }
    outcome
}

fn hist_of(values: impl IntoIterator<Item = f64>) -> Histogram {
    let mut h = Histogram::new();
    for v in values {
        h.record(v);
    }
    h
}

fn summary_json(h: &mut Histogram) -> Json {
    let s = h.summarize();
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("mean", Json::num(s.mean)),
        ("p50", Json::num(s.p50)),
        ("p99", Json::num(s.p99)),
    ])
}

fn report_counter(report: &Json, name: &str) -> u64 {
    report.path(&format!("counters.{name}")).and_then(Json::as_usize).unwrap_or(0)
        as u64
}

/// Fold a run into the `lookahead-serve-bench/v1` BENCH record. The caller
/// (serve_bench) adds the `server` section and any `sweeps` before writing.
pub fn bench_json(pr: u64, spec: &LoadSpec, sched: &Schedule, run: &LoadRun) -> Json {
    let mut ttft = hist_of(run.outcomes.iter().filter(|o| o.ok).map(|o| o.ttft_ms));
    let mut lat = hist_of(run.outcomes.iter().filter(|o| o.ok).map(|o| o.wall_ms));
    let mut queue = hist_of(run.outcomes.iter().filter(|o| o.ok).map(|o| o.queue_ms));
    let mut tpot = hist_of(run.outcomes.iter().filter_map(RequestOutcome::per_token_ms));

    let sent = run.outcomes.len() as u64;
    let ok = run.outcomes.iter().filter(|o| o.ok).count() as u64;
    let errors = sent - ok;
    let cancelled =
        run.outcomes.iter().filter(|o| o.finish == "cancelled").count() as u64;
    let deadline =
        run.outcomes.iter().filter(|o| o.finish == "deadline").count() as u64;
    let tokens_all: usize = run.outcomes.iter().map(|o| o.tokens).sum();
    // goodput counts only work a client actually wanted to completion:
    // eos/budget finishes. Cancelled/deadline partials are throughput, not
    // goodput.
    let tokens_good: usize = run
        .outcomes
        .iter()
        .filter(|o| o.ok && (o.finish == "eos" || o.finish == "budget"))
        .map(|o| o.tokens)
        .sum();
    let wall = run.wall_s.max(1e-9);

    // scraped server-side views
    let occupancy = run
        .report
        .path("histograms.batch_size")
        .cloned()
        .unwrap_or_else(|| Json::obj(vec![
            ("count", Json::num(0.0)),
            ("mean", Json::num(0.0)),
            ("p50", Json::num(0.0)),
            ("p99", Json::num(0.0)),
        ]));
    let ph = report_counter(&run.report, "prefix_hits");
    let pm = report_counter(&run.report, "prefix_miss");
    let prefix = Json::obj(vec![
        ("hits", Json::num(ph as f64)),
        ("misses", Json::num(pm as f64)),
        ("hit_rate", Json::num(hit_rate(ph, pm))),
    ]);
    let warm = report_counter(&run.report, "ngram_warm_requests");
    let cold = report_counter(&run.report, "ngram_cold_requests");
    let pool_mean = run
        .report
        .path("histograms.pool_hit_rate.mean")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let ngram = Json::obj(vec![
        ("warm_requests", Json::num(warm as f64)),
        ("cold_requests", Json::num(cold as f64)),
        ("warm_frac", Json::num(hit_rate(warm, cold))),
        ("mean_hit_rate", Json::num(pool_mean)),
    ]);
    // engine-selection controller activity (all zero under --controller
    // static — the counters only move when adaptive sessions switch)
    let controller = Json::obj(vec![
        ("decisions",
         Json::num(report_counter(&run.report, "ctl_decisions") as f64)),
        ("switches",
         Json::num(report_counter(&run.report, "ctl_switches") as f64)),
        ("rejected", Json::num(report_counter(&run.report, "ctl_rejected") as f64)),
        ("failed",
         Json::num(report_counter(&run.report, "ctl_switch_failed") as f64)),
    ]);
    // wire hand-off activity (all zero on single-node runs): donated
    // transfers split into adopted/bounced, resume attempts, and duplicate
    // deliveries the adopter suppressed
    let net = Json::obj(vec![
        ("transfers", Json::num(report_counter(&run.report, "net_transfers") as f64)),
        ("adopted", Json::num(report_counter(&run.report, "net_adopted") as f64)),
        ("bounced", Json::num(report_counter(&run.report, "net_bounced") as f64)),
        ("resumes", Json::num(report_counter(&run.report, "net_resumes") as f64)),
        ("dup_dropped",
         Json::num(report_counter(&run.report, "net_dup_dropped") as f64)),
        ("transfer_fail",
         Json::num(report_counter(&run.report, "net_transfer_fail") as f64)),
        ("attach_resumes",
         Json::num(report_counter(&run.report, "net_attach_resumes") as f64)),
        ("peers_alive",
         Json::num(report_counter(&run.report, "net_peers_alive") as f64)),
        ("bytes",
         run.report.path("histograms.net_transfer_bytes").cloned()
             .unwrap_or(Json::Null)),
    ]);
    let sched_counts = Json::Obj(
        sched
            .counts()
            .into_iter()
            .map(|(k, v)| (k, Json::num(v as f64)))
            .collect(),
    );

    Json::obj(vec![
        ("schema", Json::str("lookahead-serve-bench/v1")),
        ("bench", Json::str("serve_bench")),
        ("pr", Json::num(pr as f64)),
        ("config", spec.to_json()),
        ("schedule", Json::obj(vec![
            ("fingerprint", Json::str(format!("{:016x}", sched.fingerprint()))),
            ("counts", sched_counts),
        ])),
        ("requests", Json::obj(vec![
            ("sent", Json::num(sent as f64)),
            ("ok", Json::num(ok as f64)),
            ("errors", Json::num(errors as f64)),
            ("cancelled", Json::num(cancelled as f64)),
            ("deadline", Json::num(deadline as f64)),
        ])),
        ("ttft_ms", summary_json(&mut ttft)),
        ("latency_ms", summary_json(&mut lat)),
        ("queue_ms", summary_json(&mut queue)),
        ("per_token_ms", summary_json(&mut tpot)),
        ("wall_s", Json::num(run.wall_s)),
        ("throughput_tok_per_s", Json::num(tokens_all as f64 / wall)),
        ("goodput_tok_per_s", Json::num(tokens_good as f64 / wall)),
        ("batch_occupancy", occupancy),
        ("batched_rounds",
         Json::num(report_counter(&run.report, "batched_rounds") as f64)),
        ("prefix_cache", prefix),
        ("ngram", ngram),
        ("controller", controller),
        ("net", net),
    ])
}

/// Baselines below this are noise, not a reference point: a p99 of
/// microseconds would turn any real measurement into a "regression" of
/// thousands of percent (and the old percent math divided by ~0).
pub const BASELINE_P99_FLOOR_MS: f64 = 1.0;

/// The serve_bench `--baseline` tail-latency gate: Some(reason) when
/// `new_p99` regressed past both the +20% relative budget AND the absolute
/// [`BASELINE_P99_FLOOR_MS`] — sub-floor baselines never gate, and a jitter
/// of less than the floor never fails the build.
pub fn p99_ttft_regression(new_p99: f64, base_p99: f64) -> Option<String> {
    if base_p99 < BASELINE_P99_FLOOR_MS {
        return None;
    }
    if new_p99 > base_p99 * 1.20 && new_p99 - base_p99 > BASELINE_P99_FLOOR_MS {
        return Some(format!(
            "p99 TTFT regression: {new_p99:.2} ms vs baseline {base_p99:.2} ms \
             (>{:.2} ms budget, +20%)",
            base_p99 * 1.20
        ));
    }
    None
}

/// Required dotted paths every schema-valid BENCH record must carry — the
/// CI smoke lane fails on the first missing one.
pub const BENCH_REQUIRED_PATHS: [&str; 17] = [
    "schema",
    "pr",
    "config.seed",
    "config.requests",
    "config.rate_per_s",
    "schedule.fingerprint",
    "requests.sent",
    "requests.ok",
    "ttft_ms.p50",
    "ttft_ms.p99",
    "per_token_ms.mean",
    "goodput_tok_per_s",
    "throughput_tok_per_s",
    "batch_occupancy.mean",
    "prefix_cache.hit_rate",
    "ngram.mean_hit_rate",
    "net.transfers",
];

/// Validate one BENCH_*.json text blob against the v1 schema.
pub fn validate_bench_json(text: &str) -> Result<()> {
    let j = Json::parse(text).map_err(|e| anyhow!("malformed json: {e}"))?;
    let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
    if schema != "lookahead-serve-bench/v1" {
        bail!("bad schema '{schema}' (want lookahead-serve-bench/v1)");
    }
    for path in BENCH_REQUIRED_PATHS {
        if j.path(path).is_none() {
            bail!("missing required field '{path}'");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> LoadSpec {
        LoadSpec::new(7)
            .requests(40)
            .rate_per_s(200.0)
            .cancel_frac(0.2)
            .deadline_frac(0.2)
    }

    #[test]
    fn schedule_is_seed_deterministic() {
        let a = Schedule::generate(&spec());
        let b = Schedule::generate(&spec());
        assert_eq!(a.dump(), b.dump(), "same seed must replay byte-identically");
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.counts(), b.counts());
        let c = Schedule::generate(&LoadSpec { seed: 8, ..spec() });
        assert_ne!(a.dump(), c.dump(), "different seeds must differ");
    }

    #[test]
    fn schedule_respects_spec() {
        let s = Schedule::generate(&spec());
        assert_eq!(s.items.len(), 40);
        let mut prev = 0;
        for it in &s.items {
            assert!(it.at_ms >= prev, "arrivals must be time-ordered");
            prev = it.at_ms;
            assert!(it.req.max_tokens >= 8 && it.req.max_tokens <= 24);
            if it.cancel_after_ms.is_some() {
                assert!(it.req.stream, "cancel targets must stream to expose ids");
                assert!(it.req.deadline_ms.is_none(),
                        "churn kinds are mutually exclusive");
            }
            if it.class == MixClass::MultiTenant {
                assert!(it.req.tenant.is_some());
            }
        }
        let counts = s.counts();
        assert_eq!(counts["total"], 40);
        let planned = counts.get("cancels_planned").copied().unwrap_or(0);
        assert_eq!(s.tcp_conns(), 40 + planned as usize + 1);
    }

    #[test]
    fn churn_fractions_cover_extremes() {
        let all_cancel = Schedule::generate(
            &LoadSpec::new(1).requests(10).cancel_frac(1.0),
        );
        assert!(all_cancel.items.iter().all(|i| i.cancel_after_ms.is_some()));
        assert_eq!(all_cancel.tcp_conns(), 10 + 10 + 1);
        let all_deadline = Schedule::generate(
            &LoadSpec::new(1).requests(10).deadline_frac(1.0).deadline_ms(25),
        );
        assert!(all_deadline
            .items
            .iter()
            .all(|i| i.req.deadline_ms == Some(25) && i.cancel_after_ms.is_none()));
        let quiet = Schedule::generate(&LoadSpec::new(1).requests(10));
        assert!(quiet
            .items
            .iter()
            .all(|i| i.cancel_after_ms.is_none() && i.req.deadline_ms.is_none()));
    }

    #[test]
    fn mix_parses() {
        let m = LoadSpec::parse_mix("templated:2,tenant:1,prefix:1").unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m[0], (MixClass::Templated, 2.0));
        assert_eq!(LoadSpec::parse_mix("prefix").unwrap(),
                   vec![(MixClass::LongSharedPrefix, 1.0)]);
        assert!(LoadSpec::parse_mix("bogus:1").is_err());
        assert!(LoadSpec::parse_mix("").is_err());
    }

    #[test]
    fn single_class_mix_only_emits_that_class() {
        let sp = LoadSpec::new(3)
            .requests(20)
            .mix(vec![(MixClass::LongSharedPrefix, 1.0)]);
        let s = Schedule::generate(&sp);
        assert!(s.items.iter().all(|i| i.class == MixClass::LongSharedPrefix));
        assert!(s
            .items
            .iter()
            .all(|i| i.req.prompt.starts_with(crate::workload::SHARED_PREFIX)));
    }

    #[test]
    fn bench_json_is_schema_valid() {
        let sp = spec();
        let sched = Schedule::generate(&sp);
        // synthetic outcomes — bench_json must not require a live server
        let outcomes: Vec<RequestOutcome> = sched
            .items
            .iter()
            .map(|it| RequestOutcome {
                class: it.class,
                ok: true,
                finish: "budget".into(),
                tokens: it.req.max_tokens,
                wall_ms: 20.0,
                queue_ms: 1.0,
                ttft_ms: 5.0,
            })
            .collect();
        let run = LoadRun {
            outcomes,
            wall_s: 1.0,
            report: Json::parse(r#"{"counters": {}, "histograms": {}}"#).unwrap(),
        };
        let j = bench_json(6, &sp, &sched, &run);
        validate_bench_json(&j.dump()).unwrap();
        assert!(j.path("goodput_tok_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.path("requests.ok").unwrap().as_usize(), Some(40));
        // controller section present, all-zero without ctl_* counters
        assert_eq!(j.path("controller.decisions").unwrap().as_usize(), Some(0));
        assert_eq!(j.path("controller.switches").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn p99_gate_ignores_noise_baselines_and_noise_regressions() {
        // a microsecond baseline is noise: 0.0001 -> 5.0 ms must NOT gate
        // (the old percent math called this a +4999900% regression)
        assert_eq!(p99_ttft_regression(5.0, 0.0001), None);
        assert_eq!(p99_ttft_regression(1000.0, 0.0), None);
        // real regression past both budgets gates with a readable reason
        let msg = p99_ttft_regression(13.0, 10.0).expect("+30% must gate");
        assert!(msg.contains("13.00") && msg.contains("10.00"), "{msg}");
        // +15% is inside the relative budget
        assert_eq!(p99_ttft_regression(11.5, 10.0), None);
        // past +20% relatively but under the absolute floor: still noise
        assert_eq!(p99_ttft_regression(1.9, 1.5), None);
    }

    #[test]
    fn bench_json_net_section_reflects_report_counters() {
        let sp = LoadSpec::new(9).requests(1);
        let sched = Schedule::generate(&sp);
        let outcomes = vec![RequestOutcome::failed(MixClass::Templated)];
        let report = Json::parse(
            r#"{"counters": {"net_transfers": 4, "net_adopted": 3,
                "net_bounced": 1, "net_resumes": 2},
                "histograms": {"net_transfer_bytes": {"count": 3,
                "mean": 2048.0, "p50": 2048.0, "p99": 2048.0}}}"#,
        )
        .unwrap();
        let run = LoadRun { outcomes, wall_s: 1.0, report };
        let j = bench_json(8, &sp, &sched, &run);
        validate_bench_json(&j.dump()).unwrap();
        assert_eq!(j.path("net.transfers").unwrap().as_usize(), Some(4));
        assert_eq!(j.path("net.adopted").unwrap().as_usize(), Some(3));
        assert_eq!(j.path("net.bounced").unwrap().as_usize(), Some(1));
        assert_eq!(j.path("net.resumes").unwrap().as_usize(), Some(2));
        assert_eq!(j.path("net.dup_dropped").unwrap().as_usize(), Some(0));
        assert_eq!(j.path("net.bytes.count").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn validator_rejects_bad_blobs() {
        assert!(validate_bench_json("not json").is_err());
        assert!(validate_bench_json(r#"{"schema": "other/v1"}"#).is_err());
        let e = validate_bench_json(r#"{"schema": "lookahead-serve-bench/v1"}"#)
            .unwrap_err();
        assert!(e.to_string().contains("missing required field"));
    }
}
