//! Bench harness substrate (no `criterion` offline): warmup + repeated
//! timing, aligned-table output shared by every `rust/benches/*` target,
//! and JSON result dumps for EXPERIMENTS.md provenance.

pub mod driver;
pub mod load;

use std::time::Instant;

use crate::util::json::Json;

/// Time one closure: `warmup` unmeasured runs, then `reps` measured.
pub fn time_it<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing::from_samples(samples)
}

#[derive(Debug, Clone)]
pub struct Timing {
    pub samples: Vec<f64>,
}

impl Timing {
    pub fn from_samples(mut samples: Vec<f64>) -> Timing {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Timing { samples }
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn median(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples[self.samples.len() / 2]
    }

    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(0.0)
    }
}

/// Fixed-width table printer for bench output (paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }

    /// Rows as JSON (array of objects keyed by header) for results files.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|row| {
                    Json::Obj(
                        self.headers
                            .iter()
                            .cloned()
                            .zip(row.iter().map(|c| Json::Str(c.clone())))
                            .collect(),
                    )
                })
                .collect(),
        )
    }
}

/// Append a bench result blob to `bench_results.json` in the repo root
/// (best-effort provenance for EXPERIMENTS.md).
pub fn save_result(bench: &str, payload: Json) {
    let path = "bench_results.json";
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .unwrap_or_else(|| Json::Obj(Default::default()));
    if let Json::Obj(m) = &mut root {
        m.insert(bench.to_string(), payload);
    }
    let _ = std::fs::write(path, root.dump());
}

/// Artifact gating shared by integration tests and benches: returns true
/// (after logging why) when the AOT artifacts are absent so the caller can
/// skip cleanly — CI runs without PJRT or `make artifacts`.
pub fn skip_without_artifacts(what: &str) -> bool {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        return false;
    }
    eprintln!("{what}: skipping — artifacts/ missing (run `make artifacts`)");
    true
}

/// Common CLI for bench binaries: honor `--quick` (fewer prompts) and
/// cargo-bench's trailing `--bench` flag.
pub fn bench_args() -> crate::util::cli::Args {
    let raw: Vec<String> =
        std::env::args().skip(1).filter(|a| a != "--bench").collect();
    crate::util::cli::Args::parse("bench".into(), raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let t = Timing::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.median(), 2.0);
        assert!((t.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn time_it_runs() {
        let mut n = 0;
        let t = time_it(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.samples.len(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn table_json_shape() {
        let mut t = Table::new(&["k", "v"]);
        t.row(vec!["x".into(), "1".into()]);
        let j = t.to_json();
        assert_eq!(j.as_arr().unwrap()[0].get("k").unwrap().as_str(), Some("x"));
    }
}
