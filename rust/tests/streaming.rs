//! Streaming-equivalence suite for the resumable `DecodeSession` API.
//!
//! Core claims verified end-to-end against real artifacts:
//!   1. For EVERY engine, the concatenation of per-step `Committed` deltas
//!      (tokens and incrementally-decoded text) is byte-identical to the
//!      one-shot `generate()` output for the same seed.
//!   2. A worker interleaves >= 2 concurrent sessions under a time-slice:
//!      a short request submitted behind a long one finishes first.
//!   3. Cancelling mid-generation stops within one step and still yields a
//!      well-formed final record with the partial text.
//!   4. Time-to-first-token is recorded on sessions and served responses.
//!
//! Every runtime-dependent test skips when `artifacts/` is absent (CI runs
//! without PJRT).

use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::jacobi::Jacobi;
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::engine::spec_decode::SpecDecode;
use lookahead::engine::{Decoder, FinishReason, GenParams, StepOutcome};
use lookahead::ngram::PoolHandle;
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::server::{Reply, Request, ServerConfig, ServerHandle};
use lookahead::tokenizer::{ByteTokenizer, Utf8StreamDecoder};

/// Skip (returning true) when the AOT artifacts are not built.
fn no_artifacts() -> bool {
    lookahead::bench::skip_without_artifacts(module_path!())
}

fn setup() -> (Manifest, ModelRuntime) {
    let manifest = Manifest::load("artifacts").expect("run `make artifacts` first");
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    (manifest, rt)
}

fn engines(manifest: &Manifest, rt: &ModelRuntime) -> Vec<Box<dyn Decoder>> {
    let draft = ModelRuntime::load(&rt.client, manifest, "draft").unwrap();
    vec![
        Box::new(AutoRegressive::new()),
        Box::new(Lookahead::with_wng(5, 3, 5)),
        Box::new(Jacobi::new(8)),
        Box::new(PromptLookup::new(8, 1)),
        Box::new(SpecDecode::new(draft, 4)),
    ]
}

/// Drive a session to completion, returning (token deltas, streamed text).
fn drive_session(engine: &dyn Decoder, rt: &ModelRuntime, prompt: &[u32],
                 params: &GenParams) -> (Vec<u32>, String, FinishReason) {
    let tok = ByteTokenizer::new();
    let pool = PoolHandle::for_spec(engine.pool_spec());
    let mut sess = engine.begin(rt, prompt, params, pool).unwrap();
    let mut toks: Vec<u32> = Vec::new();
    let mut dec = Utf8StreamDecoder::new();
    let mut text = String::new();
    let reason = loop {
        match sess.step().unwrap() {
            StepOutcome::Committed { tokens } => {
                text.push_str(&dec.push(&tok.bytes(&tokens)));
                toks.extend(tokens);
            }
            StepOutcome::Finished { reason } => break reason,
        }
    };
    text.push_str(&dec.finish());
    assert_eq!(sess.tokens(), &toks[..], "session token log != deltas");
    (toks, text, reason)
}

#[test]
fn step_deltas_match_one_shot_for_every_engine() {
    if no_artifacts() {
        return;
    }
    let (manifest, rt) = setup();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("def add_ab(a, b):\n    result = a");
    let params = GenParams { max_new_tokens: 48, ..Default::default() };
    for mut engine in engines(&manifest, &rt) {
        let one = engine.generate(&rt, &prompt, &params).unwrap();
        let (toks, text, _) = drive_session(engine.as_ref(), &rt, &prompt, &params);
        assert_eq!(toks, one.tokens, "{}: step deltas diverged from one-shot",
                   engine.name());
        assert_eq!(text, one.text, "{}: streamed text diverged from one-shot",
                   engine.name());
        assert_eq!(one.stats.generated_tokens, one.tokens.len(),
                   "{}: stats disagree with output length", engine.name());
    }
}

#[test]
fn session_stats_match_one_shot_for_lookahead() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("Q: what is 12 + 34?\n");
    let params = GenParams { max_new_tokens: 32, ..Default::default() };
    let mut engine = Lookahead::with_wng(5, 3, 5);
    let one = engine.generate(&rt, &prompt, &params).unwrap();

    let pool = PoolHandle::for_spec(engine.pool_spec());
    let mut sess = engine.begin(&rt, &prompt, &params, pool).unwrap();
    while sess.finished().is_none() {
        sess.step().unwrap();
    }
    let (out, _pool) = sess.into_output();
    assert_eq!(out.tokens, one.tokens);
    assert_eq!(out.stats.generated_tokens, one.stats.generated_tokens);
    assert_eq!(out.stats.decode_steps, one.stats.decode_steps);
    assert_eq!(out.stats.accepted_by_len, one.stats.accepted_by_len);
    assert!(out.stats.ttft > std::time::Duration::ZERO, "ttft not recorded");
    assert!(out.stats.ttft <= out.stats.wall, "ttft beyond total wall");
}

#[test]
fn session_cancel_yields_partial_output() {
    if no_artifacts() {
        return;
    }
    let (_, rt) = setup();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("def add_ab(a, b):\n    result = a");
    let params = GenParams { max_new_tokens: 64, ..Default::default() };
    let engine = AutoRegressive::new();
    let mut sess = engine.begin(&rt, &prompt, &params, PoolHandle::none()).unwrap();
    sess.step().unwrap();
    let before = sess.tokens().len();
    assert!(before > 0);
    sess.cancel(FinishReason::Cancelled);
    // cancelled session stops within one step: no further tokens
    assert_eq!(sess.step().unwrap(),
               StepOutcome::Finished { reason: FinishReason::Cancelled });
    assert_eq!(sess.tokens().len(), before);
    let (out, _) = sess.into_output();
    assert_eq!(out.tokens.len(), before);
    assert_eq!(out.stats.generated_tokens, before);
}

// ---------------------------------------------------------------------------
// serving-layer tests: interleave, streaming wire, cancel, deadline, ttft
// ---------------------------------------------------------------------------

fn cfg(max_live: usize, time_slice: usize) -> ServerConfig {
    ServerConfig::builder()
        .queue_depth(64)
        .time_slice(time_slice)
        .max_live(max_live)
        .build()
}

fn req(prompt: &str, max_tokens: usize) -> Request {
    Request::new(prompt).max_tokens(max_tokens)
}

#[test]
fn streaming_chunks_concatenate_to_final_text() {
    if no_artifacts() {
        return;
    }
    let h = ServerHandle::start(cfg(2, 2)).unwrap();
    let mut r = req("def add_ab(a, b):\n    result = a", 32);
    r.stream = true;
    let rs = h.submit(r).unwrap();
    let mut streamed = String::new();
    let mut chunks = 0usize;
    let mut last_seq = 0u64;
    let done = loop {
        match rs.recv().unwrap() {
            Reply::Chunk(c) => {
                assert!(c.seq > last_seq, "chunk seq must increase");
                last_seq = c.seq;
                chunks += 1;
                streamed.push_str(&c.delta);
            }
            Reply::Done(resp) => break resp,
        }
    };
    assert!(done.error.is_none(), "{:?}", done.error);
    assert!(chunks > 1, "a 32-token generation must stream multiple chunks");
    assert_eq!(streamed, done.text,
               "concatenated chunk deltas must equal the final text");
    assert!(done.ttft_ms > 0.0, "ttft must be recorded");
    assert!(done.ttft_ms <= done.wall_ms + 1e-6);
    assert!(!done.finish.is_empty(), "final record must carry a finish reason");
    h.shutdown();
}

#[test]
fn worker_interleaves_concurrent_sessions() {
    if no_artifacts() {
        return;
    }
    // one worker, two live session slots, one step per slice: the short
    // request submitted AFTER the long one must finish first — impossible
    // under run-to-completion serving.
    let h = ServerHandle::start(cfg(2, 1)).unwrap();
    let long = h.submit(req("def add_ab(a, b):\n    result = a", 192)).unwrap();
    let short = h.submit(req("Q: what is 12 + 34?\n", 4)).unwrap();
    let short_resp = short.wait().unwrap();
    assert!(short_resp.error.is_none(), "{:?}", short_resp.error);
    assert!(
        long.try_recv().is_none(),
        "long request finished before the short one: worker did not interleave"
    );
    let long_resp = long.wait().unwrap();
    assert!(long_resp.error.is_none(), "{:?}", long_resp.error);
    assert!(long_resp.tokens > short_resp.tokens);
    h.shutdown();
}

#[test]
fn cancel_in_flight_stops_with_partial_record() {
    if no_artifacts() {
        return;
    }
    let h = ServerHandle::start(cfg(2, 1)).unwrap();
    let mut r = req("def add_ab(a, b):\n    result = a", 256);
    r.stream = true;
    let rs = h.submit(r).unwrap();
    // wait until generation demonstrably started, then cancel
    let first = loop {
        match rs.recv().unwrap() {
            Reply::Chunk(c) => break c,
            Reply::Done(resp) => panic!("finished before first chunk: {resp:?}"),
        }
    };
    assert!(!first.delta.is_empty());
    assert!(h.cancel(rs.id), "cancel of an in-flight request must be accepted");
    let mut streamed = first.delta.clone();
    let done = loop {
        match rs.recv().unwrap() {
            Reply::Chunk(c) => streamed.push_str(&c.delta),
            Reply::Done(resp) => break resp,
        }
    };
    assert!(done.error.is_none(), "{:?}", done.error);
    assert_eq!(done.finish, "cancelled");
    assert!(done.tokens < 256, "cancelled request must return a partial");
    assert!(done.tokens > 0, "partial must contain the pre-cancel tokens");
    assert_eq!(streamed, done.text, "partial record must be well-formed");
    h.shutdown();
}

#[test]
fn cancel_queued_request_never_runs() {
    if no_artifacts() {
        return;
    }
    // max_live = 1: the second request stays queued while the first runs
    let h = ServerHandle::start(cfg(1, 4)).unwrap();
    let first = h.submit(req("def add_ab(a, b):\n    result = a", 96)).unwrap();
    let queued = h.submit(req("Q: what is 1 + 1?\n", 32)).unwrap();
    assert!(h.cancel(queued.id), "queued request must be cancellable");
    let resp = queued.wait().unwrap();
    assert_eq!(resp.finish, "cancelled");
    assert_eq!(resp.tokens, 0, "a queued-cancelled request never decodes");
    assert!(resp.error.is_none());
    assert!(first.wait().unwrap().error.is_none());
    assert!(!h.cancel(9999), "unknown id must report false");
    h.shutdown();
}

#[test]
fn deadline_expires_to_partial_record() {
    if no_artifacts() {
        return;
    }
    let h = ServerHandle::start(cfg(1, 1)).unwrap();
    let mut r = req("def add_ab(a, b):\n    result = a", 512);
    r.deadline_ms = Some(1); // expires almost immediately
    let resp = h.submit(r).unwrap().wait().unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.finish, "deadline");
    assert!(resp.tokens < 512);
    let m = h.metrics.lock().counter("finish_deadline");
    assert_eq!(m, 1);
    h.shutdown();
}

// ---------------------------------------------------------------------------
// batched-round cancellation (simulated artifacts: runs without PJRT)
// ---------------------------------------------------------------------------

/// Regression for the batched drive loop: the worker must check the
/// `CancelSet` between *fused rounds* (not just between whole slices), so a
/// cancel arriving while a session sits inside a batched group still lands
/// within one decode step and yields a well-formed partial record — while
/// the group's other member keeps decoding unharmed.
#[test]
fn batched_round_cancel_lands_within_one_step() {
    // slow sim artifacts (~5ms per decode launch): the cancel round-trip is
    // orders of magnitude shorter than the remaining generation, so "stops
    // within one step" is observable without PJRT
    let dir = lookahead::runtime::sim::ensure_slow_sim_artifacts().unwrap();
    let mut c = cfg(4, 4);
    c.worker.artifacts_dir = dir.to_string_lossy().into_owned();
    c.batch_decode = true;
    c.share_ngrams = false;
    let h = ServerHandle::start(c).unwrap();

    // pick a prompt whose (deterministic) sim generation runs >= 48 tokens
    // before its natural EOS (>= 240ms of decode wall under the slow
    // artifacts) — probe with the instant artifacts
    let tok = ByteTokenizer::new();
    let fast = lookahead::runtime::sim::ensure_sim_artifacts().unwrap();
    let manifest = Manifest::load(&fast).unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let mut ar = AutoRegressive::new();
    let candidates: Vec<String> =
        (0..32).map(|i| format!("probe prompt #{i}: def f_{i}(x):\n    return x")).collect();
    let prompt: &str = candidates
        .iter()
        .map(String::as_str)
        .find(|p| {
            let ids = tok.encode_with_bos(p);
            let params = GenParams { max_new_tokens: 512, ..Default::default() };
            ar.generate(&rt, &ids, &params).unwrap().tokens.len() >= 48
        })
        .expect("no sim prompt decodes >= 48 tokens");

    let mk = |max| {
        let mut r = req(prompt, max);
        r.method = "autoregressive".into();
        r.stream = true;
        r
    };
    let a = h.submit(mk(512)).unwrap();
    let b = h.submit(mk(512)).unwrap();

    // wait until BOTH sessions demonstrably decode (so they coexist in one
    // batched group), then cancel A
    let first_a = loop {
        match a.recv().unwrap() {
            Reply::Chunk(ch) => break ch,
            Reply::Done(r) => panic!("A finished before first chunk: {r:?}"),
        }
    };
    loop {
        match b.recv().unwrap() {
            Reply::Chunk(_) => break,
            Reply::Done(r) => panic!("B finished before first chunk: {r:?}"),
        }
    }
    assert!(h.cancel(a.id), "in-flight cancel must be accepted");

    let mut streamed = first_a.delta.clone();
    let done_a = loop {
        match a.recv().unwrap() {
            Reply::Chunk(c) => streamed.push_str(&c.delta),
            Reply::Done(r) => break r,
        }
    };
    assert!(done_a.error.is_none(), "{:?}", done_a.error);
    assert_eq!(done_a.finish, "cancelled");
    assert!(done_a.tokens > 0, "partial must keep pre-cancel tokens");
    assert!(done_a.tokens < 512, "cancelled request must stop early");
    assert_eq!(streamed, done_a.text, "partial record must be well-formed");

    // the surviving group member is unaffected
    let done_b = b.wait().unwrap();
    assert!(done_b.error.is_none(), "{:?}", done_b.error);
    assert!(done_b.tokens > done_a.tokens,
            "survivor must outlive the cancelled session");

    // and the batched path provably ran while both were live
    assert!(h.metrics.lock().counter("batched_rounds") > 0,
            "cancel regression must exercise the batched drive loop");
    h.shutdown();
}

#[test]
fn ttft_metric_recorded_for_served_requests() {
    if no_artifacts() {
        return;
    }
    let h = ServerHandle::start(cfg(2, 4)).unwrap();
    let resp = h.submit(req("Q: what is 12 + 34?\n", 16)).unwrap().wait().unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(resp.ttft_ms > 0.0, "response must carry ttft");
    assert!(resp.ttft_ms <= resp.wall_ms + 1e-6);
    let report = h.report();
    assert!(report.contains("ttft_ms"), "server metrics must report ttft:\n{report}");
    assert!(report.contains("accept_len"),
            "server metrics must report the accept-length histogram:\n{report}");
    h.shutdown();
}
