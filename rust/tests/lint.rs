//! `lookahead-lint` suite (DESIGN.md §9): the deliberately-bad fixture
//! corpus must be detected with the expected lint id at the expected span,
//! the shipped tree must be lint-clean under the committed baseline, and —
//! the runtime cross-check — a live simulated server must exercise the
//! declared lock-rank hierarchy end to end.
//!
//! Fixtures live in `rust/tests/lint_fixtures/` and are NOT compiled (the
//! tree walk skips the directory; no Cargo target points at them). Each
//! test lexes a fixture and runs the relevant checker with a crafted path,
//! since path suffixes decide lint scope (inventory file, hot path,
//! deterministic modules).

use lookahead::analysis::{self, invariants, lexer, lock_order, metrics_check};
use lookahead::server::{Request, ServerConfig, ServerHandle};
use std::path::Path;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

fn lock_findings(name: &str, as_path: &str) -> Vec<analysis::Finding> {
    lock_order::check(&[(as_path.to_string(), lexer::lex(&fixture(name)))])
}

#[test]
fn abba_half_is_flagged_at_the_descending_acquisition() {
    let f = lock_findings("bad_abba.rs", "rust/src/server/scheduler.rs");
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].lint, "lock-order");
    assert_eq!(f[0].line, 14);
    assert!(f[0].msg.contains("sched.state") && f[0].msg.contains("cancel.ids"),
            "{}", f[0].msg);
}

#[test]
fn hierarchy_violation_is_caught_interprocedurally_at_the_call_site() {
    let f = lock_findings("bad_hierarchy.rs", "rust/src/server/scheduler.rs");
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].lint, "lock-order");
    assert_eq!(f[0].line, 12);
    assert!(f[0].msg.contains("touch_sched"), "{}", f[0].msg);
}

#[test]
fn undeclared_lock_receiver_is_an_inventory_finding() {
    let f = lock_findings("bad_unknown_lock.rs", "rust/src/server/server.rs");
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].lint, "lock-inventory");
    assert_eq!(f[0].line, 5);
    assert!(f[0].msg.contains("mystery"), "{}", f[0].msg);
}

#[test]
fn config_struct_literal_outside_home_module_is_flagged() {
    let path = "rust/tests/lint_fixtures/bad_config_literal.rs";
    let l = lexer::lex(&fixture("bad_config_literal.rs"));
    let f = invariants::check_struct_literals(path, &l);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].lint, "struct-literal");
    assert_eq!(f[0].line, 6);
    assert!(f[0].msg.contains("ServerConfig"), "{}", f[0].msg);
}

#[test]
fn wall_clock_read_in_deterministic_scope_is_flagged() {
    let path = "rust/src/engine/bad_wallclock.rs";
    assert!(invariants::in_wall_clock_scope(path));
    let l = lexer::lex(&fixture("bad_wallclock.rs"));
    let f = invariants::check_wall_clock(path, &l);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].lint, "wall-clock");
    assert_eq!(f[0].line, 5);
}

#[test]
fn hot_path_unwrap_expect_panic_sites_are_all_counted() {
    let path = "rust/src/server/worker.rs";
    assert!(invariants::is_hot_path(path));
    let l = lexer::lex(&fixture("bad_unwrap_hot.rs"));
    let f = invariants::hot_unwrap_sites(path, &l);
    let lines: Vec<u32> = f.iter().map(|f| f.line).collect();
    assert_eq!(lines, [6, 7, 9], "{f:?}");
    assert!(f.iter().all(|f| f.lint == "hot-unwrap"));
}

#[test]
fn orphaned_family_metric_fails_the_reverse_cross_check() {
    let src = vec![(
        "rust/src/net/fixture.rs".to_string(),
        lexer::lex(&fixture("bad_metric_orphan.rs")),
    )];
    let refs: Vec<(String, lexer::Lexed)> = Vec::new();
    let f = metrics_check::check(&src, &refs);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].lint, "metrics-name");
    assert_eq!(f[0].line, 5);
    assert!(f[0].msg.contains("net_fixture_orphan"), "{}", f[0].msg);
}

#[test]
fn bare_allow_suppresses_its_target_but_is_itself_a_finding() {
    let l = lexer::lex(&fixture("bad_allow_noreason.rs"));
    let f = invariants::check_allow_reasons("rust/src/engine/x.rs", &l);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].lint, "lint-allow");
    assert_eq!(f[0].line, 6);
    // the (bare) allow still waives the wall-clock finding itself
    assert!(invariants::check_wall_clock("rust/src/engine/x.rs", &l).is_empty());
}

#[test]
fn good_fixture_is_clean_under_every_lint() {
    let text = fixture("good_locks.rs");
    let l = lexer::lex(&text);
    assert!(lock_findings("good_locks.rs", "rust/src/server/scheduler.rs").is_empty());
    assert!(invariants::check_wall_clock("rust/src/engine/x.rs", &l).is_empty());
    assert!(invariants::check_allow_reasons("x.rs", &l).is_empty());
    assert!(invariants::check_struct_literals("x.rs", &l).is_empty());
}

#[test]
fn shipped_tree_is_lint_clean_under_the_committed_baseline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust");
    let files = analysis::load_tree(&root).expect("walk rust/");
    assert!(files.len() > 40, "tree walk must see the crate, got {}", files.len());
    let bpath = root.join("lint_baseline.json");
    let baseline = analysis::parse_baseline(
        &std::fs::read_to_string(&bpath).expect("read baseline"),
    )
    .expect("parse baseline");
    let findings = analysis::run(&files, &baseline);
    let report: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
    assert!(findings.is_empty(), "shipped tree must be lint-clean:\n{}",
            report.join("\n"));
}

#[test]
fn baseline_is_tight_against_the_current_tree() {
    // shrink-only policy: the committed budgets must equal the live counts,
    // so a fixed unwrap forces the baseline down with it
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust");
    let files = analysis::load_tree(&root).expect("walk rust/");
    let baseline = analysis::parse_baseline(
        &std::fs::read_to_string(root.join("lint_baseline.json")).unwrap(),
    )
    .unwrap();
    for (path, count) in analysis::hot_unwrap_counts(&files) {
        let budget = analysis::baseline_budget(&baseline, &path);
        assert_eq!(count, budget,
                   "{path}: budget {budget} != live count {count} — tighten \
                    rust/lint_baseline.json");
    }
}

#[test]
fn live_server_exercises_the_declared_rank_hierarchy() {
    // runtime twin of the static checker: a served burst on simulated
    // artifacts must pass the debug rank tracker and touch >= 5 distinct
    // ranks (setup, hub, sched, pending, cancel, kv, leaf ...)
    let dir = lookahead::runtime::sim::ensure_sim_artifacts().unwrap();
    let c = ServerConfig::builder()
        .workers(2)
        .queue_depth(64)
        .rebalance(true)
        .rebalance_interval_ms(5)
        .artifacts_dir(dir.to_string_lossy().into_owned())
        .kv_budget(1)
        .build();
    let h = ServerHandle::start(c).unwrap();
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            h.submit(
                Request::new(format!("def f{i}(x):\n    return x"))
                    .max_tokens(12)
                    .method("autoregressive"),
            )
            .unwrap()
        })
        .collect();
    for rx in rxs {
        let r = rx.wait().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let report = h.report();
    assert!(report.contains("queue_depth"), "{report}");
    h.shutdown();
    let ranks = lookahead::util::sync::exercised_ranks();
    if cfg!(debug_assertions) {
        assert!(ranks.len() >= 5,
                "a served burst must exercise >= 5 distinct lock ranks, \
                 got {ranks:?}");
    }
}
