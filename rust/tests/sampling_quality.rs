//! Sampling-mode quality through the REAL runtime (paper §5.3 / Appendix B):
//! Algorithm 4 must preserve the output distribution. We verify with a
//! first-token chi-square-style check: the distribution of the first
//! generated token under lookahead sampling must match autoregressive
//! sampling across many seeds, and both must be non-degenerate.

use std::collections::HashMap;

use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::{Decoder, GenParams, SamplingParams};
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::tokenizer::ByteTokenizer;

/// Skip (returning true) when the AOT artifacts are not built.
fn no_artifacts() -> bool {
    lookahead::bench::skip_without_artifacts(module_path!())
}

fn first_token_hist(engine: &mut dyn Decoder, rt: &ModelRuntime, prompt: &[u32],
                    seeds: u64, temp: f64) -> HashMap<u32, usize> {
    let mut h = HashMap::new();
    for seed in 0..seeds {
        let params = GenParams {
            max_new_tokens: 2,
            sampling: SamplingParams { temperature: temp, ..Default::default() },
            stop_at_eos: false,
            seed,
        };
        let out = engine.generate(rt, prompt, &params).unwrap();
        if let Some(&t) = out.tokens.first() {
            *h.entry(t).or_insert(0) += 1;
        }
    }
    h
}

#[test]
fn algorithm4_preserves_first_token_distribution() {
    if no_artifacts() {
        return;
    }
    let manifest = Manifest::load("artifacts").unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("user: how does the ");
    let seeds = 300;
    let temp = 1.0;

    let ar = first_token_hist(&mut AutoRegressive::new(), &rt, &prompt, seeds, temp);
    let la = first_token_hist(&mut Lookahead::with_wng(5, 3, 5), &rt, &prompt,
                              seeds, temp);

    // union support, compare empirical frequencies
    let mut keys: Vec<u32> = ar.keys().chain(la.keys()).copied().collect();
    keys.sort();
    keys.dedup();
    assert!(keys.len() >= 2, "degenerate distribution? {ar:?}");
    let mut max_diff = 0.0f64;
    for k in keys {
        let pa = *ar.get(&k).unwrap_or(&0) as f64 / seeds as f64;
        let pl = *la.get(&k).unwrap_or(&0) as f64 / seeds as f64;
        max_diff = max_diff.max((pa - pl).abs());
    }
    // 300 samples -> ~3 sigma tolerance for p in [0,1] is about 0.09
    assert!(max_diff < 0.12,
            "first-token distributions diverge (max diff {max_diff:.3})\nAR: {ar:?}\nLA: {la:?}");
}

#[test]
fn sampling_speedup_below_greedy_speedup() {
    if no_artifacts() {
        return;
    }
    // paper Tab. 2: sampling lowers the acceptance ratio, hence S.
    let manifest = Manifest::load("artifacts").unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos(
        "def pad_ab(a, b):\n    result = a + b\n    return result\n\ndef pad_xy(x, y):\n    result = x");

    let run = |temp: f64, seed: u64| {
        let mut e = Lookahead::with_wng(15, 5, 15);
        let params = GenParams {
            max_new_tokens: 64,
            sampling: SamplingParams { temperature: temp, ..Default::default() },
            stop_at_eos: false,
            seed,
        };
        e.generate(&rt, &prompt, &params).unwrap().stats.compression()
    };
    let greedy = run(0.0, 0);
    let sampled: f64 = (0..4).map(|s| run(1.0, s)).sum::<f64>() / 4.0;
    assert!(greedy > 1.2, "greedy S {greedy:.2}");
    assert!(sampled <= greedy + 0.25,
            "sampling S {sampled:.2} unexpectedly above greedy {greedy:.2}");
}

#[test]
fn generation_stops_at_cache_capacity() {
    if no_artifacts() {
        return;
    }
    // ask for far more tokens than the cache can hold; engine must stop
    // cleanly without error
    let manifest = Manifest::load("artifacts").unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("for a in range(10):\n");
    let mut e = Lookahead::with_wng(5, 3, 5);
    let params = GenParams { max_new_tokens: 100_000, stop_at_eos: false,
                             ..Default::default() };
    let out = e.generate(&rt, &prompt, &params).unwrap();
    let cap = rt.mm.capacity();
    assert!(out.tokens.len() <= cap);
    assert!(out.tokens.len() > cap / 2, "stopped far too early: {}", out.tokens.len());
}

#[test]
fn oversized_prompt_rejected_cleanly() {
    if no_artifacts() {
        return;
    }
    let manifest = Manifest::load("artifacts").unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let prompt: Vec<u32> = (0..300).map(|i| (i % 256) as u32).collect();
    let err = match rt.prefill(&prompt) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("oversized prompt accepted"),
    };
    assert!(err.contains("prefill capacity"), "{err}");
}

#[test]
fn zero_g_config_still_exact() {
    if no_artifacts() {
        return;
    }
    // G = 0: lookahead branch only, no verification candidates — every step
    // falls back to the model's own next token (AR-equivalent).
    let manifest = Manifest::load("artifacts").unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let tok = ByteTokenizer::new();
    let prompt = tok.encode_with_bos("Q: what is 3 + 4?\n");
    let params = GenParams { max_new_tokens: 24, ..Default::default() };

    let want = AutoRegressive::new().generate(&rt, &prompt, &params).unwrap().tokens;
    let mut cfg = lookahead::engine::lookahead::LookaheadConfig::new(4, 3, 0);
    cfg.force_generic = true;
    let got = Lookahead::new(cfg).generate(&rt, &prompt, &params).unwrap().tokens;
    assert_eq!(got, want);
}
