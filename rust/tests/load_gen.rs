//! Serving load-generator integration suite (simulated artifacts — runs
//! without PJRT).
//!
//! Pins the ISSUE-6 contracts end-to-end:
//!   1. Seeded determinism: the same `LoadSpec` replays a byte-identical
//!      schedule, and two driven runs agree on every schedule-derived
//!      aggregate in the BENCH record (latencies may differ; the request
//!      set and its counters never do).
//!   2. Builder equivalence: `ServerConfig::default()` is pinned field by
//!      field to the documented defaults, and the builders reproduce it.
//!   3. `Request::new` is exactly `Default` plus the prompt.
//!   4. A driven run — in-process and over TCP — folds into a
//!      schema-valid `lookahead-serve-bench/v1` record, with the server's
//!      `{"report": true}` scrape carried along.

use lookahead::bench::load::{bench_json, drive_inprocess, drive_tcp,
                             validate_bench_json, LoadSpec, Schedule};
use lookahead::runtime::sim::ensure_sim_artifacts;
use lookahead::server::{serve_tcp, Policy, Request, ServerConfig, ServerHandle,
                        WorkerConfig};
use lookahead::util::json::Json;

fn sim_dir() -> String {
    ensure_sim_artifacts().unwrap().to_string_lossy().into_owned()
}

fn sim_server_cfg() -> ServerConfig {
    ServerConfig::builder()
        .queue_depth(64)
        .artifacts_dir(sim_dir())
        .time_slice(2)
        .build()
}

/// A small, fast spec: ~200 req/s over 10 requests keeps the whole replay
/// under ~100ms of planned arrivals on the instant sim artifacts.
fn small_spec(seed: u64) -> LoadSpec {
    LoadSpec::new(seed).requests(10).rate_per_s(200.0).max_tokens(4, 8)
}

#[test]
fn schedule_replay_is_byte_identical() {
    let a = Schedule::generate(&small_spec(7));
    let b = Schedule::generate(&small_spec(7));
    assert_eq!(a.dump(), b.dump(), "same seed must replay byte-identically");
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.counts(), b.counts());
    assert_eq!(a, b);
    let c = Schedule::generate(&small_spec(8));
    assert_ne!(a.dump(), c.dump(), "different seeds must diverge");
}

#[test]
fn server_config_default_is_pinned() {
    // the documented defaults — a deliberate compatibility surface: changing
    // any of these is a behavior change for every builder call site
    let d = ServerConfig::default();
    assert_eq!(d.workers, 1);
    assert_eq!(d.policy, Policy::Fifo);
    assert_eq!(d.queue_depth, 256);
    assert!(d.share_ngrams);
    assert_eq!(d.ngram_ttl_ms, None);
    assert!(d.batch_decode);
    assert!(!d.rebalance);
    assert_eq!(d.rebalance_interval_ms, 50);
    assert!(d.peers.is_empty());
    assert_eq!(d.peer_addr, None);
    assert_eq!(d.heartbeat_ms, 100);
    assert!(!d.trace);
    assert_eq!(d.trace_sample, 1);
    assert_eq!(d.trace_buf, 65_536);
    assert_eq!(d.trace_out, None);
    let w = &d.worker;
    assert_eq!(w.artifacts_dir, "artifacts");
    assert_eq!(w.model, "tiny");
    assert_eq!(w.wng, (5, 3, 5));
    assert_eq!(w.draft_model, "draft");
    assert_eq!(w.time_slice, 4);
    assert_eq!(w.max_live, 4);
    assert!(w.batch_decode);
    assert_eq!(w.kv_budget, 0);
    assert!(w.prefix_cache);
    assert_eq!(w.controller, "static");
    assert!(!w.prefill_only);

    // builders over untouched defaults reproduce Default exactly
    assert_eq!(ServerConfig::builder().build(), d);
    assert_eq!(WorkerConfig::builder().build(), d.worker);
    // a builder chain touches only the fields it was told to
    let mut built = ServerConfig::builder().workers(2).queue_depth(64).build();
    assert_eq!((built.workers, built.queue_depth), (2, 64));
    built.workers = d.workers;
    built.queue_depth = d.queue_depth;
    assert_eq!(built, d, "builder must leave every other field at its default");
}

#[test]
fn request_new_is_default_plus_prompt() {
    let r = Request::new("hello");
    let mut want = Request::default();
    assert_eq!(want.prompt, "", "default prompt must be empty");
    want.prompt = "hello".into();
    assert_eq!(r, want);
    // chained setters touch only their field
    let r = Request::new("hello").max_tokens(9).method("autoregressive");
    assert_eq!(r.max_tokens, 9);
    assert_eq!(r.method, "autoregressive");
    assert_eq!(r.prompt, "hello");
    assert_eq!(r.tenant, None);
}

#[test]
fn inprocess_load_run_emits_schema_valid_bench() {
    let spec = small_spec(7);
    let sched = Schedule::generate(&spec);

    let h = ServerHandle::start(sim_server_cfg()).unwrap();
    let run1 = drive_inprocess(&h, &sched);
    h.shutdown();
    let h = ServerHandle::start(sim_server_cfg()).unwrap();
    let run2 = drive_inprocess(&h, &sched);
    h.shutdown();

    let j1 = bench_json(6, &spec, &sched, &run1);
    let j2 = bench_json(6, &spec, &sched, &run2);
    validate_bench_json(&j1.dump()).unwrap();
    validate_bench_json(&j2.dump()).unwrap();

    // schedule-derived aggregates are identical across runs; latencies vary
    assert_eq!(j1.path("schedule").unwrap().dump(),
               j2.path("schedule").unwrap().dump(),
               "schedule section must be run-invariant");
    assert_eq!(j1.path("config").unwrap().dump(), j2.path("config").unwrap().dump());
    assert_eq!(j1.path("requests.sent").unwrap().as_usize(), Some(10));
    assert_eq!(j2.path("requests.sent").unwrap().as_usize(), Some(10));

    // no churn in this spec: every request completes
    assert_eq!(j1.path("requests.ok").unwrap().as_usize(), Some(10),
               "all requests must succeed: {}", j1.dump());
    assert!(j1.path("throughput_tok_per_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(j1.path("goodput_tok_per_s").unwrap().as_f64().unwrap() > 0.0);
    // the scraped report flowed through into the server-side sections
    assert!(run1.report.path("counters.responses_ok").is_some(),
            "report scrape must carry counters: {}", run1.report.dump());
    assert!(run1.report.path("histograms.ttft_ms.p50").is_some(),
            "report histograms must be summarized: {}", run1.report.dump());
}

#[test]
fn tcp_load_run_scrapes_report_and_validates() {
    let spec = small_spec(11).cancel_frac(0.25);
    let sched = Schedule::generate(&spec);
    let addr = "127.0.0.1:17921";
    let conns = sched.tcp_conns();
    let cfg = sim_server_cfg();
    let server = std::thread::spawn(move || serve_tcp(addr, cfg, Some(conns)));
    // wait for bind (same idiom as rust/tests/serving.rs)
    lookahead::util::sync::nap(std::time::Duration::from_millis(300));

    let run = drive_tcp(addr, &sched).unwrap();
    server.join().unwrap().unwrap();

    assert_eq!(run.outcomes.len(), sched.items.len());
    // instant sim decodes: cancels land after natural completion, so every
    // request still yields a well-formed ok record
    assert!(run.outcomes.iter().all(|o| o.ok),
            "every TCP request must get a final record");
    let j = bench_json(6, &spec, &sched, &run);
    validate_bench_json(&j.dump()).unwrap();
    // the report scrape is the real server's: responses_ok covers the run
    assert_eq!(run.report.path("counters.responses_ok").and_then(Json::as_usize),
               Some(sched.items.len()),
               "scraped report must count this run: {}", run.report.dump());
    // every cancel mark was swept on retirement — the CancelSet must not
    // leak ids across a run with 25% planned cancels
    assert_eq!(run.report.path("counters.cancel_marks").and_then(Json::as_usize),
               Some(0),
               "cancel marks must return to zero at quiescence: {}",
               run.report.dump());
}
