//! Wire-protocol hand-off suite (simulated artifacts — runs without PJRT).
//!
//! Two layers of coverage for the network-transparent session transfer:
//!
//!   1. Mock-gateway fault matrix: `net::send_session` against a
//!      `net::spawn_listener` whose `Adopt` impl records payloads, driven
//!      through seeded mid-stream cuts (`TransferOpts::cuts`). Pins the
//!      resume math (only checksummed chunks count), the adopted-or-bounced
//!      contract, duplicate suppression after a lost ack, and the reply
//!      tunnel's donor-id rewrite.
//!   2. Two-process loopback topologies: a prefill-only front shipping every
//!      admitted session to a decode peer, clean and under injected cuts,
//!      with migrated output byte-identical to a solo server.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;

use lookahead::metrics::Registry;
use lookahead::net::{self, SendOutcome, TransferOpts};
use lookahead::util::sync::{rank, RankedMutex};
use lookahead::server::{Reply, Request, Response, ServerConfig, ServerHandle,
                        StreamChunk};
use lookahead::util::json::Json;

/// Records every adopted payload and answers each adoption with one chunk
/// and a final record (ids 0 — the listener pump must rewrite them to the
/// donor id carried in the offer meta). Adopter-local ids are handed out
/// from 40 so cancel routing is distinguishable from the donor ids.
struct MockGate {
    payloads: RankedMutex<Vec<Vec<u8>>>,
    adopts: AtomicUsize,
    cancelled: RankedMutex<Vec<u64>>,
}

impl Default for MockGate {
    fn default() -> Self {
        MockGate {
            payloads: RankedMutex::new(rank::LEAF, "test.payloads", Vec::new()),
            adopts: AtomicUsize::new(0),
            cancelled: RankedMutex::new(rank::LEAF, "test.cancelled", Vec::new()),
        }
    }
}

impl net::Adopt for MockGate {
    fn adopt(&self, _meta: &Json, payload: Vec<u8>)
             -> Result<(u64, Receiver<Reply>), String> {
        self.payloads.lock().push(payload);
        let n = self.adopts.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        tx.send(Reply::Chunk(StreamChunk { id: 0, seq: 1, delta: "ok".into() }))
            .unwrap();
        tx.send(Reply::Done(Response::err(0, "mock-served".into()))).unwrap();
        Ok((40 + n as u64, rx))
    }

    fn cancel_local(&self, id: u64) {
        self.cancelled.lock().push(id);
    }

    fn load_json(&self) -> Json {
        Json::obj(vec![
            ("live", Json::num(0.0)),
            ("parked", Json::num(0.0)),
            ("prefill_only", Json::Bool(false)),
        ])
    }
}

type Listener = (Arc<MockGate>, Arc<RankedMutex<Registry>>, Arc<AtomicBool>,
                 std::thread::JoinHandle<()>);

fn mock_listener(addr: &str) -> Listener {
    let gate = Arc::new(MockGate::default());
    let metrics =
        Arc::new(RankedMutex::new(rank::LEAF, "metrics.registry", Registry::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let join = net::spawn_listener(addr, gate.clone(), metrics.clone(), stop.clone())
        .unwrap();
    (gate, metrics, stop, join)
}

fn patterned_payload(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i % 251) as u8).collect()
}

fn opts_with_cuts(attempts: usize, chunk: usize, cuts: Vec<usize>) -> TransferOpts {
    TransferOpts {
        attempts,
        chunk,
        backoff: Duration::from_millis(5),
        cuts: Arc::new(RankedMutex::new(rank::LEAF, "net.cuts", cuts)),
    }
}

/// Drain the reply tunnel after adoption: one chunk then the final record,
/// both rewritten to the donor-side id.
fn read_tunnel(mut lines: net::NetLines, donor_id: u64) -> Response {
    let first = lines.next_deadline(Duration::from_secs(5)).unwrap();
    let c = StreamChunk::from_json_line(&first).unwrap();
    assert_eq!(c.id, donor_id, "tunnel chunk must carry the donor id");
    assert_eq!(c.delta, "ok");
    let last = lines.next_deadline(Duration::from_secs(5)).unwrap();
    let r = Response::from_json_line(&last).unwrap();
    assert_eq!(r.id, donor_id, "final record must carry the donor id");
    r
}

#[test]
fn seeded_cuts_resume_to_byte_identical_adoption() {
    let addr = "127.0.0.1:18801";
    let (gate, _metrics, stop, join) = mock_listener(addr);
    let payload = patterned_payload(1000);
    let meta = Json::obj(vec![
        ("id", Json::num(7.0)),
        ("stream", Json::Bool(true)),
    ]);
    // Three mid-stream cuts with a 64-byte chunk: each attempt loses the
    // in-flight chunk but keeps every verified one, so the resume offsets
    // climb (64, 256, 640) and the fourth attempt completes the payload.
    let opts = opts_with_cuts(4, 64, vec![100, 300, 700]);
    let report = net::send_session(addr, &meta, &payload, &opts);
    let lines = match report.outcome {
        SendOutcome::Adopted(lines) => lines,
        SendOutcome::Bounced(why) => panic!("transfer bounced: {why}"),
    };
    assert_eq!(report.resumes, 3, "each retry must resume, not restart");
    assert_eq!(gate.adopts.load(Ordering::SeqCst), 1);
    let got = gate.payloads.lock();
    assert_eq!(got.len(), 1);
    assert_eq!(got[0], payload, "resumed payload must be byte-identical");
    drop(got);
    let resp = read_tunnel(lines, 7);
    assert!(resp.error.as_deref().unwrap_or("").contains("mock-served"));
    stop.store(true, Ordering::SeqCst);
    join.join().unwrap();
}

#[test]
fn exhausted_attempts_bounce_without_adoption() {
    let addr = "127.0.0.1:18803";
    let (gate, _metrics, stop, join) = mock_listener(addr);
    let payload = patterned_payload(500);
    let meta = Json::obj(vec![("id", Json::num(3.0))]);
    // Every attempt is cut inside the first chunk: no bytes ever verify,
    // attempts exhaust, and the donor gets a bounce — never a hang.
    let opts = opts_with_cuts(3, 64, vec![10, 10, 10]);
    let report = net::send_session(addr, &meta, &payload, &opts);
    match report.outcome {
        SendOutcome::Bounced(why) => {
            assert!(why.contains("exhausted"), "unexpected bounce reason: {why}")
        }
        SendOutcome::Adopted(_) => panic!("cut transfer must not be adopted"),
    }
    assert_eq!(report.resumes, 2, "retries 2 and 3 still reach a handshake");
    assert_eq!(gate.adopts.load(Ordering::SeqCst), 0,
               "no attempt completed; nothing may be adopted");
    stop.store(true, Ordering::SeqCst);
    join.join().unwrap();
}

#[test]
fn lost_ack_retry_is_dropped_as_duplicate() {
    let addr = "127.0.0.1:18805";
    let (gate, metrics, stop, join) = mock_listener(addr);
    let payload = patterned_payload(300);
    let meta = Json::obj(vec![("id", Json::num(9.0))]);
    // The cut lands past the payload end: the full payload is delivered and
    // adopted, but the socket drops before the donor reads the ack. The
    // retry must be answered `dup` — adopted exactly once, tunnel intact.
    let opts = opts_with_cuts(3, 64, vec![payload.len() + 1]);
    let report = net::send_session(addr, &meta, &payload, &opts);
    let lines = match report.outcome {
        SendOutcome::Adopted(lines) => lines,
        SendOutcome::Bounced(why) => panic!("dup retry bounced: {why}"),
    };
    assert_eq!(report.resumes, 1);
    assert_eq!(gate.adopts.load(Ordering::SeqCst), 1,
               "duplicate delivery must not re-adopt");
    assert_eq!(metrics.lock().counter("net_dup_dropped"), 1);
    let resp = read_tunnel(lines, 9);
    assert!(resp.error.as_deref().unwrap_or("").contains("mock-served"));
    stop.store(true, Ordering::SeqCst);
    join.join().unwrap();
}

#[test]
fn cancel_frame_resolves_the_adopter_local_id_or_reports_gone() {
    let addr = "127.0.0.1:18807";
    let (gate, metrics, stop, join) = mock_listener(addr);
    let payload = patterned_payload(200);
    let meta = Json::obj(vec![("id", Json::num(11.0))]);
    let report = net::send_session(addr, &meta, &payload, &opts_with_cuts(1, 64, vec![]));
    let lines = match report.outcome {
        SendOutcome::Adopted(lines) => lines,
        SendOutcome::Bounced(why) => panic!("transfer bounced: {why}"),
    };
    // the cancel frame names the transfer; the listener must translate it
    // to the ADOPTER-LOCAL id the gateway returned from adopt()
    let xfer = lookahead::kv::snapshot::fnv64(&payload);
    assert!(net::cancel_session(addr, xfer).unwrap());
    assert_eq!(gate.cancelled.lock().as_slice(), &[40]);
    assert_eq!(metrics.lock().counter("net_cancels"), 1);
    // an unknown transfer answers `gone` instead of hanging or erroring
    assert!(!net::cancel_session(addr, xfer ^ 0xdead).unwrap());
    assert_eq!(gate.cancelled.lock().len(), 1);
    let resp = read_tunnel(lines, 11);
    assert!(resp.error.as_deref().unwrap_or("").contains("mock-served"));
    stop.store(true, Ordering::SeqCst);
    join.join().unwrap();
}

// ---------------------------------------------------------------------------
// Loopback topologies over real servers (simulated artifacts).
// ---------------------------------------------------------------------------

fn sim_dir() -> String {
    lookahead::runtime::sim::ensure_sim_artifacts()
        .unwrap()
        .to_string_lossy()
        .into_owned()
}

fn wait_for_peer(front: &ServerHandle) {
    let peers = front.peers.clone().expect("peer table");
    for _ in 0..400 {
        if peers.snapshot().iter().any(|p| p.alive) {
            return;
        }
        lookahead::util::sync::nap(Duration::from_millis(5));
    }
    panic!("peer never reported alive");
}

fn run_prompts(h: &ServerHandle, prompts: &[String]) -> Vec<String> {
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| {
            h.submit(Request::new(p.clone()).max_tokens(16).method("autoregressive"))
                .unwrap()
        })
        .collect();
    rxs.into_iter()
        .map(|rx| {
            let r = rx.wait().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            r.text
        })
        .collect()
}

fn solo_texts(dir: &str, prompts: &[String]) -> Vec<String> {
    let solo = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.to_string())
            .build(),
    )
    .unwrap();
    let texts = run_prompts(&solo, prompts);
    solo.shutdown();
    texts
}

#[test]
fn prefill_only_front_ships_every_session_to_decode_peer() {
    let dir = sim_dir();
    let back = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .peer_addr(Some("127.0.0.1:18821".into()))
            .build(),
    )
    .unwrap();
    let front = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .peers(vec!["127.0.0.1:18821".into()])
            .heartbeat_ms(5)
            .prefill_only(true)
            .build(),
    )
    .unwrap();
    wait_for_peer(&front);

    let prompts: Vec<String> = (0..3)
        .map(|i| format!("def net{i}(x):\n    return x + {i}"))
        .collect();
    let texts = run_prompts(&front, &prompts);

    let (transfers, adopted, bounced, beats) = {
        let m = front.metrics.lock();
        (m.counter("net_transfers"), m.counter("net_adopted"),
         m.counter("net_bounced"), m.counter("net_heartbeats"))
    };
    assert_eq!(transfers, 3, "a prefill-only front must ship every session");
    assert_eq!(adopted, 3);
    assert_eq!(bounced, 0);
    assert!(beats >= 1, "heartbeat thread never ran");
    assert_eq!(back.metrics.lock().counter("net_adopted"), 3,
               "adopter must count each inbound adoption");
    front.shutdown();
    back.shutdown();

    assert_eq!(texts, solo_texts(&dir, &prompts),
               "migrated decode must match the solo run byte for byte");
}

#[test]
fn injected_cuts_settle_adopted_or_bounced_with_correct_output() {
    let dir = sim_dir();
    let back = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .peer_addr(Some("127.0.0.1:18831".into()))
            .build(),
    )
    .unwrap();
    let front = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .peers(vec!["127.0.0.1:18831".into()])
            .heartbeat_ms(5)
            .prefill_only(true)
            .build(),
    )
    .unwrap();
    wait_for_peer(&front);
    // Three seeded mid-stream disconnects, consumed one per attempt by the
    // serial transport. Whatever mix of resume / duplicate / bounce-and-
    // redonate they force, every session must settle and decode correctly.
    front.inject_net_cuts(vec![64, 128, 256]);

    let prompts: Vec<String> = (0..3)
        .map(|i| format!("def cut{i}(x):\n    return x * {i}"))
        .collect();
    let texts = run_prompts(&front, &prompts);

    let (transfers, adopted, bounced, resumes) = {
        let m = front.metrics.lock();
        (m.counter("net_transfers"), m.counter("net_adopted"),
         m.counter("net_bounced"), m.counter("net_resumes"))
    };
    assert!(transfers >= 3, "every session must go over the wire");
    assert_eq!(adopted + bounced, transfers,
               "every transfer must settle as adopted or bounced");
    assert!(resumes >= 1, "seeded cuts must exercise the resume path");
    front.shutdown();
    back.shutdown();

    assert_eq!(texts, solo_texts(&dir, &prompts),
               "faulted hand-off must not corrupt decode output");
}

/// PR 8 leftover: a client cancel issued on the DONOR after its session was
/// adopted by a peer must land on the adopter (via the `cancel` frame) —
/// the session retires with `"finish":"cancelled"`, and the cancel
/// bookkeeping returns to zero on both processes.
#[test]
fn donor_side_cancel_lands_on_the_adopting_peer() {
    // slow sim (~ms per decode launch): the 64-token decode is still
    // running on the adopter when the cancel goes over the wire
    let dir = lookahead::runtime::sim::ensure_slow_sim_artifacts()
        .unwrap()
        .to_string_lossy()
        .into_owned();
    let back = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .peer_addr(Some("127.0.0.1:18841".into()))
            .build(),
    )
    .unwrap();
    let front = ServerHandle::start(
        ServerConfig::builder()
            .queue_depth(64)
            .artifacts_dir(dir.clone())
            .peers(vec!["127.0.0.1:18841".into()])
            .heartbeat_ms(5)
            .prefill_only(true)
            .build(),
    )
    .unwrap();
    wait_for_peer(&front);

    let rx = front
        .submit(
            Request::new("def spin(x):\n    while x: x -= 1\n    return x")
                .max_tokens(64)
                .method("autoregressive")
                .stream(true),
        )
        .unwrap();
    // the first relayed chunk proves the adopter is decoding the session
    let first = rx.recv().unwrap();
    assert!(matches!(first, Reply::Chunk(_)), "expected a streamed chunk first");
    assert!(front.cancel(rx.id), "cancel must report the request as known");
    let resp = loop {
        match rx.recv().unwrap() {
            Reply::Done(r) => break r,
            Reply::Chunk(_) => {}
        }
    };
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.finish, "cancelled",
               "donor-side cancel must stop the adopted session");

    // both processes must sweep their cancel bookkeeping: the adopter's
    // dispatcher clears its mark before relaying the final record, the
    // donor's when that record passes through its own dispatcher
    let marks = |h: &ServerHandle| {
        h.report_json()
            .path("counters.cancel_marks")
            .and_then(Json::as_f64)
            .unwrap_or(-1.0)
    };
    assert_eq!(marks(&back), 0.0, "adopter-side cancel mark must be swept");
    assert_eq!(marks(&front), 0.0, "donor-side cancel mark must be swept");

    front.shutdown();
    back.shutdown();
}
