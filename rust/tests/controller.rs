//! Adaptive-controller exactness suite (simulated artifacts — runs without
//! PJRT).
//!
//! The controller's safety contract: under greedy sampling, switching a
//! live session between engines at commit boundaries NEVER changes the
//! committed bytes — every engine is byte-exact w.r.t. autoregressive
//! greedy decoding, so the controller can only change how many steps the
//! output costs, not the output itself.
//!
//! Claims pinned here:
//!   1. Every ordered (start engine, target engine) pair over all five
//!      engines, switched mid-stream via `control::switch_session`, ends
//!      byte-identical to a pure autoregressive greedy run — including
//!      spec_decode promotion from draft-less engines (the draft cache is
//!      rebuilt from token history) and demotion away from it.
//!   2. Property: random multi-switch chains at random commit boundaries
//!      stay byte-exact.

use std::rc::Rc;

use lookahead::control::{switch_session, EngineLevel};
use lookahead::engine::autoregressive::AutoRegressive;
use lookahead::engine::jacobi::Jacobi;
use lookahead::engine::lookahead::Lookahead;
use lookahead::engine::prompt_lookup::PromptLookup;
use lookahead::engine::spec_decode::SpecDecode;
use lookahead::engine::{Decoder, GenParams, StepOutcome};
use lookahead::ngram::PoolHandle;
use lookahead::runtime::sim::ensure_sim_artifacts;
use lookahead::runtime::{cpu_client, Manifest, ModelRuntime};
use lookahead::tokenizer::ByteTokenizer;
use lookahead::util::prop::forall;
use lookahead::util::rng::Rng;

fn sim_dir() -> String {
    ensure_sim_artifacts().unwrap().to_string_lossy().into_owned()
}

// prompts that decode to non-trivial outputs on the sim LM (no instant EOS)
const PROMPTS: [&str; 2] =
    ["def add_ab(a, b):\n    result = a", "the quick brown fox jumps over"];

/// The five controller levels this suite swaps between — each has its
/// executable on the sim artifacts (decode_gen_20, decode_lin_{5,8}).
fn levels() -> Vec<EngineLevel> {
    vec![
        EngineLevel::Autoregressive,
        EngineLevel::Lookahead { w: 5, n: 3, g: 5 },
        EngineLevel::Jacobi { k: 8 },
        EngineLevel::PromptLookup { k: 8, match_len: 1 },
        EngineLevel::SpecDecode { gamma: 4 },
    ]
}

fn engine_for(level: &EngineLevel, rt: &ModelRuntime, manifest: &Manifest)
              -> Box<dyn Decoder> {
    match level {
        EngineLevel::Autoregressive => Box::new(AutoRegressive::new()),
        EngineLevel::Lookahead { w, n, g } => {
            Box::new(Lookahead::with_wng(*w, *n, *g))
        }
        EngineLevel::Jacobi { k } => Box::new(Jacobi::new(*k)),
        EngineLevel::PromptLookup { k, match_len } => {
            Box::new(PromptLookup::new(*k, *match_len))
        }
        EngineLevel::SpecDecode { gamma } => Box::new(SpecDecode::new(
            ModelRuntime::load(&rt.client, manifest, "draft").unwrap(),
            *gamma,
        )),
    }
}

/// Drive a session opened under `engine` to completion, applying each
/// `(after_commits, target)` switch at its commit boundary. Returns the
/// committed token stream.
fn run_switched(rt: &ModelRuntime, draft: &Rc<ModelRuntime>, engine: &dyn Decoder,
                ids: &[u32], params: &GenParams,
                switches: &[(usize, EngineLevel)]) -> Vec<u32> {
    let pool = PoolHandle::for_spec(engine.pool_spec());
    let mut sess = engine.begin(rt, ids, params, pool).unwrap();
    let mut commits = 0usize;
    let mut pending = switches.to_vec();
    loop {
        match sess.step().unwrap() {
            StepOutcome::Committed { .. } => {
                commits += 1;
                while let Some((at, target)) = pending.first().cloned() {
                    if commits < at {
                        break;
                    }
                    let d = matches!(target, EngineLevel::SpecDecode { .. })
                        .then(|| draft.clone());
                    switch_session(&mut sess, rt, &target, Some(ids), d)
                        .unwrap_or_else(|e| {
                            panic!("switch to {} failed: {e}", target.tag())
                        });
                    pending.remove(0);
                }
            }
            StepOutcome::Finished { .. } => break,
        }
    }
    let (out, _) = sess.into_output();
    out.tokens
}

#[test]
fn every_engine_pair_switch_is_byte_exact() {
    let manifest = Manifest::load(sim_dir()).unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let draft = Rc::new(ModelRuntime::load(&client, &manifest, "draft").unwrap());
    let tok = ByteTokenizer::new();
    let params = GenParams { max_new_tokens: 32, ..Default::default() };
    let levels = levels();
    for prompt in PROMPTS {
        let ids = tok.encode_with_bos(prompt);
        let want = AutoRegressive::new().generate(&rt, &ids, &params).unwrap();
        assert!(!want.tokens.is_empty(), "reference run must generate tokens");
        for start in &levels {
            let engine = engine_for(start, &rt, &manifest);
            for target in &levels {
                let got = run_switched(&rt, &draft, engine.as_ref(), &ids,
                                       &params, &[(2, target.clone())]);
                assert_eq!(got, want.tokens,
                           "switch {} -> {} changed committed bytes",
                           start.tag(), target.tag());
            }
        }
    }
}

#[test]
fn prop_random_switch_chains_stay_byte_exact() {
    let manifest = Manifest::load(sim_dir()).unwrap();
    let client = cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &manifest, "tiny").unwrap();
    let draft = Rc::new(ModelRuntime::load(&client, &manifest, "draft").unwrap());
    let tok = ByteTokenizer::new();
    let params = GenParams { max_new_tokens: 40, ..Default::default() };
    let levels = levels();
    let refs: Vec<Vec<u32>> = PROMPTS
        .iter()
        .map(|p| {
            let ids = tok.encode_with_bos(p);
            AutoRegressive::new().generate(&rt, &ids, &params).unwrap().tokens
        })
        .collect();

    forall(
        12,
        0xC011_7801,
        |r: &mut Rng| -> (usize, usize, Vec<(usize, usize)>) {
            // (prompt, start level, [(commit boundary, target level)...])
            // with strictly increasing switch points
            let n = r.range(1, 4);
            let mut at = 0usize;
            let switches = (0..n)
                .map(|_| {
                    at += r.range(1, 4);
                    (at, r.below(5))
                })
                .collect();
            (r.below(PROMPTS.len()), r.below(5), switches)
        },
        |(pi, si, script)| {
            let ids = tok.encode_with_bos(PROMPTS[*pi]);
            let engine = engine_for(&levels[*si], &rt, &manifest);
            let switches: Vec<(usize, EngineLevel)> = script
                .iter()
                .map(|&(at, ti)| (at, levels[ti].clone()))
                .collect();
            let got =
                run_switched(&rt, &draft, engine.as_ref(), &ids, &params, &switches);
            if got != refs[*pi] {
                let tags: Vec<String> = switches
                    .iter()
                    .map(|(at, l)| format!("@{at}->{}", l.tag()))
                    .collect();
                return Err(format!(
                    "chain {} from {} diverged from greedy reference",
                    tags.join(" "),
                    levels[*si].tag()
                ));
            }
            Ok(())
        },
    );
}
