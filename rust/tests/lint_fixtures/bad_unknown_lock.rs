// Fixture: a `.lock()` receiver that is not in the declared inventory.
// Expect: lock-inventory at line 5.

fn stray(&self) {
    let g = self.mystery.lock();
    g.poke();
}
