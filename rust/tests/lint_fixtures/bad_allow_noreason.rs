// Fixture: an allow directive without the mandatory reason clause. The
// bare allow still suppresses its target lint, but is itself a finding.
// Expect: lint-allow at line 6 (and no wall-clock finding).

fn warm() {
    // lint: allow(wall-clock)
    let t0 = Instant::now();
    run_warmup(t0);
}
