// Fixture: host-clock read inside a deterministic module (checked as if
// it lived under src/engine/). Expect: wall-clock at line 5.

fn step_time() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
