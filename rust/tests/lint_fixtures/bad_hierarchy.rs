// Fixture: inter-procedural hierarchy violation — report() holds the
// leaf-rank metrics registry (80) and calls a method whose body acquires
// sched.state (20). Checked as if it lived in server/scheduler.rs.
// Expect: lock-order at line 12 (the call site, not the callee).

fn touch_sched(&self) {
    self.state.lock().bump();
}

fn report(&self) {
    let m = metrics.lock();
    self.sched.touch_sched();
    m.observe("latency_ms", 1);
}
