// Fixture: clean code — ascending lock order, early drop before taking a
// leaf, closure scanned with its own empty held-set, and a wall-clock
// read waived with a reasoned allow. Expect: no findings from any lint.

fn orderly(&self) {
    let st = self.state.lock();
    self.ids.lock().insert(7);
    drop(st);
    let m = metrics.lock();
    m.set("queue_depth", 1);
}

fn deferred(&self) {
    let m = metrics.lock();
    spawn(move || {
        let st = self.state.lock();
        st.touch();
    });
    m.inc("requests", 1);
}

fn timed(&self) -> f64 {
    // lint: allow(wall-clock) reason=fixture demonstrates the escape hatch
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
