// Fixture: ABBA deadlock half — thread_a ascends sched.state(20) →
// cancel.ids(40), thread_b closes the cycle by acquiring in the opposite
// order. Checked as if it lived in server/scheduler.rs.
// Expect: lock-order at line 14 (the descending acquisition).

fn thread_a(&self) {
    let st = self.state.lock();
    self.ids.lock().insert(1);
    st.touch();
}

fn thread_b(&self) {
    let ids = self.ids.lock();
    let st = self.state.lock();
    st.touch();
    ids.remove(&1);
}
