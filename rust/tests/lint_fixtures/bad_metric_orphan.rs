// Fixture: a net_-family counter registered in shipping code that no test
// or bench section ever reads. Expect: metrics-name at line 5.

fn publish(m: &mut Registry) {
    m.inc("net_fixture_orphan", 1);
}
