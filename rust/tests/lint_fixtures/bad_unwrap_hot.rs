// Fixture: unwrap/expect/panic on the hot path (checked as if it lived in
// server/worker.rs with a baseline budget of 0).
// Expect: hot-unwrap at lines 6, 7, and 9.

fn decode_step(q: &mut Queue) -> u32 {
    let head = q.pop_front().unwrap();
    let slot = head.slot.expect("slot assigned at admission");
    if slot.age > 1000 {
        panic!("slot leak");
    }
    head.token
}
