// Fixture: ServerConfig built as a struct literal outside its defining
// module — adding a config field would silently change this call site.
// Expect: struct-literal at line 6.

fn make() -> ServerConfig {
    ServerConfig { workers: 2, queue_capacity: 8 }
}
